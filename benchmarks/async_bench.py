"""Event-driven async timeline: delay regimes, frontiers, aircomp (ISSUE 9).

Sweeps a heterogeneous lte/edge fleet across DELAY REGIMES — the same
protocol at round budgets that make the edge links synchronous, one
round in flight, or seven rounds in flight — for both the cadence
(``periodic``) and divergence (``dynamic``) triggers, each run recorded
through the telemetry plane so the comm-vs-loss frontier reconstructs
from the JSONL stream alone (``repro.telemetry.observatory``). Each
stream lands at experiments/bench/async_bench_<regime>_<preset>.jsonl
and the representative run card at
experiments/bench/async_bench_frontier.json, all uploaded nightly as
the BENCH_async artifact.

Three claims ride in ``check``:

* the covering-budget regime is the synchronous engine BITWISE (the
  zero-delay reduction, measured here on a real training run, not a
  unit fixture);
* harsher budgets actually put messages in flight (mean in-flight > 0)
  while the int64 counters stay exact in the stream;
* aircomp's shared-medium pricing beats the digital uplink by exactly
  the fleet size at equal sync cadence (c(f) = 2 payloads per sync vs
  2m) — the analog superposition physics, visible in bytes.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import OUT_DIR, save_rows
from repro.config import (
    AsyncConfig, NetworkConfig, ProtocolConfig, TelemetryConfig,
    TrainConfig, get_arch,
)
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.telemetry.observatory import frontier, load_run, summarize
from repro.train.loop import run_protocol_training

NAME = "async_bench"
PAPER_REF = "ISSUE 9 tentpole (event-driven async network timeline)"

M = 8
PAYLOAD = 100_000
NET = NetworkConfig(link_classes=("lte", "edge"))

# round budgets (simulated seconds per scanned round) against the
# lte/edge round trips at the 100 kB payload: lte flies 0.14 s, edge
# 2.0 s, so the budgets put the edge links 0, 1 and 7 rounds in flight
REGIMES = (
    ("sync", 60.0),      # covers every round trip: the synchronous limit
    ("mild", 1.0),       # edge exchanges fly 1 round
    ("harsh", 0.25),     # edge exchanges fly 7 rounds
)
PRESETS = (
    ("periodic", dict(kind="periodic", b=2)),
    ("dynamic", dict(kind="dynamic", b=2, delta=0.5)),
)


def _train(proto_kw: dict, rounds: int, jsonl: str,
           async_net=None):
    cfg = get_arch("drift_mlp", smoke=True)
    dl, _ = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        GraphicalModelStream(seed=0, drift_prob=0.0),
        m=M, rounds=rounds, protocol=ProtocolConfig(**proto_kw),
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=0, record_every=max(1, rounds // 10),
        network=NET, async_net=async_net,
        telemetry=TelemetryConfig(path=jsonl, per_link=True))
    dl.recorder.close()
    return dl


def run(quick: bool = True):
    os.makedirs(OUT_DIR, exist_ok=True)
    rounds = 48 if quick else 240
    rows = []
    for pname, proto_kw in PRESETS:
        # the no-AsyncConfig baseline the zero-delay reduction must hit
        base_jsonl = os.path.normpath(
            os.path.join(OUT_DIR, f"{NAME}_base_{pname}.jsonl"))
        base = _train(proto_kw, rounds, base_jsonl)
        base_fp = (dict(base.comm_totals),
                   np.asarray(base.link_bytes_totals).tolist(),
                   float(base.network_time))
        for regime, budget in REGIMES:
            jsonl = os.path.normpath(
                os.path.join(OUT_DIR, f"{NAME}_{regime}_{pname}.jsonl"))
            dl = _train(proto_kw, rounds, jsonl,
                        AsyncConfig(round_budget=budget,
                                    payload_bytes=PAYLOAD))
            # everything below comes from the stream alone — the
            # frontier reconstruction the BENCH_async artifact exists for
            card = summarize(load_run(jsonl))
            fp = (dict(dl.comm_totals),
                  np.asarray(dl.link_bytes_totals).tolist(),
                  float(dl.network_time))
            inflight = [p[1] for p in card.get("inflight", [])]
            rows.append({
                "preset": pname, "regime": regime, "budget": budget,
                "m": M, "rounds": rounds,
                "cum_bytes": card["cum_bytes"],
                "cum_loss": round(card["cum_loss"], 4),
                "cum_syncs": card["cum_syncs"],
                "net_time_s": round(card["net_time_s"], 3),
                "inflight_mean": round(float(np.mean(inflight)), 3)
                if inflight else 0.0,
                "max_age_last": card.get("max_age_last", 0),
                "frontier_points": len(card["frontier"]),
                "stream_exact": bool(
                    card["cum_bytes"] == dl.comm_bytes()
                    and card["cum_syncs"] == dl.comm_totals["syncs"]
                    and card["cum_loss"] == dl.cumulative_loss),
                "zero_delay_exact": fp == base_fp
                if regime == "sync" else None,
                "jsonl": jsonl,
            })
    rows.append(_aircomp_vs_digital(rounds))

    # the representative run card: the harsh dynamic frontier, rebuilt
    # from its JSONL after the fact (nothing cached from the run)
    harsh = os.path.join(OUT_DIR, f"{NAME}_harsh_dynamic.jsonl")
    with open(os.path.join(OUT_DIR, f"{NAME}_frontier.json"), "w") as f:
        json.dump(summarize(load_run(harsh)), f, indent=1, sort_keys=True)
    save_rows(NAME, rows)
    return rows


def _aircomp_vs_digital(rounds: int) -> dict:
    """Same fleet, same cadence, same rounds: the digital coordinator
    moves 2m payloads per sync, the analog channel 2 — the uplink-bytes
    ratio is exactly m when the sync counts agree."""
    digital_jsonl = os.path.normpath(
        os.path.join(OUT_DIR, f"{NAME}_digital.jsonl"))
    air_jsonl = os.path.normpath(
        os.path.join(OUT_DIR, f"{NAME}_aircomp.jsonl"))
    digital = _train(dict(kind="periodic", b=2), rounds, digital_jsonl)
    air = _train(dict(kind="periodic", b=2), rounds, air_jsonl,
                 AsyncConfig(round_budget=60.0, aircomp=True,
                             snr_db=20.0))
    d_card = summarize(load_run(digital_jsonl))
    a_card = summarize(load_run(air_jsonl))
    return {
        "preset": "periodic", "regime": "aircomp", "m": M,
        "rounds": rounds, "snr_db": 20.0,
        "digital_bytes": d_card["cum_bytes"],
        "aircomp_bytes": a_card["cum_bytes"],
        "bytes_ratio": round(d_card["cum_bytes"]
                             / max(1, a_card["cum_bytes"]), 2),
        "cum_syncs": a_card["cum_syncs"],
        "syncs_equal": a_card["cum_syncs"] == d_card["cum_syncs"],
        "cum_loss": round(a_card["cum_loss"], 4),
        "digital_loss": round(d_card["cum_loss"], 4),
        "jsonl": air_jsonl,
    }


def check(rows) -> str:
    regime_rows = [r for r in rows if r["regime"] in
                   ("sync", "mild", "harsh")]
    air = next(r for r in rows if r["regime"] == "aircomp")
    ok = (
        # the covering budget IS the synchronous engine, bitwise
        all(r["zero_delay_exact"] for r in regime_rows
            if r["regime"] == "sync")
        # every stream's totals equal the live counters (int64 exact)
        and all(r["stream_exact"] for r in regime_rows)
        # harsher budgets put real messages in flight
        and all(r["inflight_mean"] > 0 for r in regime_rows
                if r["regime"] == "harsh")
        # frontiers reconstruct from the JSONL alone
        and all(r["frontier_points"] >= 2 for r in regime_rows)
        # analog superposition: one shared exchange vs m digital uplinks
        and air["syncs_equal"] and air["bytes_ratio"] == float(M))
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
