"""Operating regimes under connectivity constraints: act_prob × topology.

The paper's simulator models a perfect always-on star; this sweep runs the
same learning problem inside the network environment subsystem
(``repro.network``): dynamic averaging (coordinator protocol, constrained
by availability) and gossip (coordinator-free, constrained by availability
AND topology) across dropout levels and peer overlays, plus a
``network=None`` baseline.

Claim checked: the ideal-network row (act_prob=1.0, star) reproduces the
pre-network engine's comm counters BITWISE and its cumulative loss exactly
— the regression half of the ISSUE-2 acceptance criteria — and every
constrained run stays finite. Each run executes through
``DecentralizedLearner.run_chunk``: availability masks, mobility re-draws
and cost accounting all happen inside the scanned round, one compiled
program per chunk.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_rows
from repro.config import NetworkConfig, ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training

NAME = "fig_network_regimes"
PAPER_REF = "ISSUE 2 tentpole (network environment subsystem)"

M = 8
ACT_PROBS = (1.0, 0.7, 0.4)
TOPOLOGIES = ("star", "ring", "geometric")


def _run(proto: ProtocolConfig, network, rounds: int, seed: int = 0):
    cfg = get_arch("drift_mlp", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = GraphicalModelStream(seed=1, drift_prob=0.0)
    dl, _ = run_protocol_training(
        loss_fn, init_fn, src, m=M, rounds=rounds, protocol=proto,
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=seed, network=network)
    return dl


def run(quick: bool = True):
    rounds = 120 if quick else 600
    dyn = ProtocolConfig(kind="dynamic", b=5, delta=0.5)
    gsp = ProtocolConfig(kind="gossip", b=5)

    rows = []
    baseline = _run(dyn, None, rounds)
    rows.append({
        "protocol": "dynamic", "topology": "none", "act_prob": 1.0,
        "cumulative_loss": round(baseline.cumulative_loss, 3),
        "comm_bytes": baseline.comm_bytes(),
        "syncs": baseline.comm_totals["syncs"],
        "mean_active": 1.0, "sim_net_s": 0.0,
    })

    for topo in TOPOLOGIES:
        for act in ACT_PROBS:
            net = NetworkConfig(
                topology=topo, act_prob=act, geo_radius=0.6,
                redraw_every=20 if topo == "geometric" else 0,
                link_classes=("wifi", "lte"))
            for pname, proto in (("dynamic", dyn), ("gossip", gsp)):
                dl = _run(proto, net, rounds)
                rows.append({
                    "protocol": pname, "topology": topo, "act_prob": act,
                    "cumulative_loss": round(dl.cumulative_loss, 3),
                    "comm_bytes": dl.comm_bytes(),
                    "syncs": dl.comm_totals["syncs"],
                    "mean_active": round(dl.mean_active(), 3),
                    "sim_net_s": round(dl.network_time, 4),
                })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    base = rows[0]
    ideal = next(r for r in rows
                 if r["topology"] == "star" and r["act_prob"] == 1.0
                 and r["protocol"] == "dynamic")
    # bitwise comm + exact loss vs the pre-network engine (full availability
    # takes the mask-free fast path inside the same scanned program)
    regression_ok = (ideal["comm_bytes"] == base["comm_bytes"]
                     and ideal["syncs"] == base["syncs"]
                     and ideal["cumulative_loss"] == base["cumulative_loss"])
    finite = all(np.isfinite(r["cumulative_loss"]) for r in rows)
    # constrained coordinator rounds can't move MORE models than ideal ones
    dyn_rows = [r for r in rows if r["protocol"] == "dynamic"
                and r["topology"] != "none"]
    bounded = all(r["comm_bytes"] <= base["comm_bytes"] * 1.5
                  for r in dyn_rows)
    return "PASS" if (regression_ok and finite and bounded) else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
