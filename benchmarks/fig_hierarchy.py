"""Two-tier hierarchy sweep: clusters × inter-tier delta vs flat dynamic.

The staged sync kernel's hierarchical coordinator (ISSUE 3): m learners
partitioned into g clusters, dynamic averaging inside each cluster (own
Delta/b against the cluster's edge aggregator) and dynamic averaging among
the g aggregators (its own, looser Delta). The sweep runs the synthetic
drift task with a mid-run concept drift and compares against single-tier
dynamic averaging at the same intra settings.

Claims checked:
  * the bytes ledger balances on every run — per-link sums equal the
    global byte total (``sum(per_link_bytes()) == comm_bytes()``);
  * the edge tier absorbs traffic: some hierarchy setup moves strictly
    fewer coordinator-uplink bytes (the aggregator↔top rows of the
    ledger) than single-tier dynamic's coordinator uplinks, at
    comparable loss. Intra-cluster chatter stays on cheap local links;
    only the aggregators talk to the top coordinator.

Every run executes through ``DecentralizedLearner.run_chunk`` — both tiers
(per-cluster intra state, inter-tier state, the down-push commit, and the
ledger) live inside the scanned round, one compiled program per segment.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_rows
from repro.config import HierarchyConfig, ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_drift_segments

NAME = "fig_hierarchy"
PAPER_REF = "ISSUE 3 tentpole (staged sync kernel, two-tier coordinators)"

M = 12
B, DELTA = 2, 0.3                       # intra tier == flat baseline
CLUSTERS = (3, 4)
INTER_DELTAS = (0.3, 0.6)


def _run_one(proto, rounds, drift_rounds, seed=0):
    cfg = get_arch("drift_mlp", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = GraphicalModelStream(seed=1, drift_prob=0.0)
    streams = LearnerStreams(src, M, batch=10, seed=seed)
    dl = DecentralizedLearner(
        loss_fn, init_fn, M, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05), seed=seed)
    _, loss_curve = run_drift_segments(dl, streams, src, rounds, drift_rounds)
    return dl, float(loss_curve[-1])


def _row(name, dl, loss, clusters=0, inter_delta=None):
    ledger = dl.per_link_bytes()
    uplink = int(ledger[M:].sum()) if clusters else int(ledger.sum())
    return {
        "protocol": name, "clusters": clusters,
        "inter_delta": inter_delta,
        "cumulative_loss": round(loss, 2),
        "comm_bytes": dl.comm_bytes(),
        "coordinator_uplink_bytes": uplink,
        "member_link_bytes": int(ledger[:M].sum()),
        "ledger_balanced": bool(int(ledger.sum()) == dl.comm_bytes()),
        "syncs": dl.comm_totals["syncs"],
    }


def run(quick: bool = True):
    rounds = 160 if quick else 600
    drift_rounds = {rounds // 2}

    rows = []
    flat = ProtocolConfig(kind="dynamic", b=B, delta=DELTA)
    dl, loss = _run_one(flat, rounds, drift_rounds)
    rows.append(_row("dynamic_flat", dl, loss))

    for g in CLUSTERS:
        for d_inter in INTER_DELTAS:
            proto = ProtocolConfig(
                kind="dynamic", b=B, delta=DELTA,
                tiers=HierarchyConfig(
                    num_clusters=g,
                    inter=ProtocolConfig(kind="dynamic", b=B,
                                         delta=d_inter)))
            dl, loss = _run_one(proto, rounds, drift_rounds)
            rows.append(_row(f"two_tier_g{g}_d{d_inter}", dl, loss,
                             clusters=g, inter_delta=d_inter))
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    flat = rows[0]
    hier = [r for r in rows if r["clusters"]]
    balanced = all(r["ledger_balanced"] for r in rows)
    finite = all(np.isfinite(r["cumulative_loss"]) for r in rows)
    # the edge tier absorbs traffic: some two-tier setup beats the flat
    # coordinator's uplink bytes strictly, at matched loss
    absorbed = any(
        r["coordinator_uplink_bytes"] < flat["coordinator_uplink_bytes"]
        and r["cumulative_loss"] <= 1.15 * flat["cumulative_loss"]
        for r in hier)
    return "PASS" if (balanced and finite and absorbed) else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
