"""Fig. 5.2/5.3 (+ A.2/A.3): dynamic averaging vs FedAvg.

Paper setting: m=30, B=10, b=50, FedAvg C in {0.3,0.5,0.7},
sigma_Delta in {0.1,...,0.8}. Claim: the best dynamic configs beat the
strongest FedAvg config on cumulative communication at comparable loss
(paper: >50% less comm at +8.3% loss / -1.9% accuracy).
"""
from __future__ import annotations

from benchmarks.common import run_mnist_protocol, save_rows
from repro.config import ProtocolConfig

NAME = "fig5_2_fedavg"
PAPER_REF = "Figures 5.2/5.3, Appendix A.2"


def run(quick: bool = True):
    m = 10 if quick else 30
    # long enough that the learners approach quiescence — the regime where
    # dynamic averaging stops paying while FedAvg's bill keeps growing
    # linearly (the paper's Fig. 5.2 shape)
    rounds = 260 if quick else 800
    b = 10 if quick else 50
    protos = [
        ("periodic_b", ProtocolConfig(kind="periodic", b=b)),
        ("fedavg_C0.3", ProtocolConfig(kind="fedavg", b=b, fedavg_c=0.3)),
        ("fedavg_C0.5", ProtocolConfig(kind="fedavg", b=b, fedavg_c=0.5)),
        ("fedavg_C0.7", ProtocolConfig(kind="fedavg", b=b, fedavg_c=0.7)),
        ("dynamic_d0.4", ProtocolConfig(kind="dynamic", b=b, delta=0.4)),
        ("dynamic_d0.8", ProtocolConfig(kind="dynamic", b=b, delta=0.8)),
        ("dynamic_d1.2", ProtocolConfig(kind="dynamic", b=b, delta=1.2)),
        ("dynamic_d1.6", ProtocolConfig(kind="dynamic", b=b, delta=1.6)),
    ]
    rows = []
    for name, proto in protos:
        dl, traj, acc = run_mnist_protocol(proto, m=m, rounds=rounds)
        rows.append({
            "protocol": name,
            "cumulative_loss": round(dl.cumulative_loss, 2),
            "comm_bytes": dl.comm_bytes(),
            "accuracy": round(acc, 4),
            "comm_curve": traj.cumulative_bytes[::3],
        })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    """Some dynamic config communicates less than the cheapest FedAvg config
    at <= 1.15x its loss."""
    fed = [r for r in rows if r["protocol"].startswith("fedavg")]
    dyn = [r for r in rows if r["protocol"].startswith("dynamic")]
    best_fed = min(fed, key=lambda r: r["comm_bytes"])
    ok = any(d["comm_bytes"] < best_fed["comm_bytes"] and
             d["cumulative_loss"] < 1.15 * best_fed["cumulative_loss"]
             for d in dyn)
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print({k: v for k, v in r.items() if k != "comm_curve"})
