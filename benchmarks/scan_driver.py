"""Scanned round driver vs per-round dispatch (the ISSUE-1 tentpole claim).

Runs the dynamic-averaging protocol for 200 rounds twice from identical
state: once through the per-round ``DecentralizedLearner.step`` loop (one
jitted dispatch + host counter sync + m host-side sample calls per round)
and once through ``run_chunk`` + ``LearnerStreams.next_chunk`` (the whole
run as two ``lax.scan`` programs). Asserts the two drivers are equivalent —
bitwise-equal communication counters, losses equal to float32 summation
tolerance — and reports cold (includes jit compile) and steady-state
wall-clock for both.

The steady-state speedup is the headline number: per-round Python dispatch
was the simulator's bottleneck, not the arithmetic.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import save_rows
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_loss, init_cnn_params

NAME = "scan_driver"
PAPER_REF = "ISSUE 1 tentpole (scanned protocol engine)"

M, B_CHECK, DELTA, CHUNK = 8, 5, 0.7, 100


def _streams():
    return LearnerStreams(
        SyntheticMNIST(seed=0, image_size=14), M, batch=10, seed=0)


def _make(loss_fn, init_fn):
    streams = _streams()
    dl = DecentralizedLearner(
        loss_fn, init_fn, M,
        ProtocolConfig(kind="dynamic", b=B_CHECK, delta=DELTA),
        TrainConfig(optimizer="sgd", learning_rate=0.1))
    return streams, dl


def _loop_rounds(streams, dl, rounds):
    for _ in range(rounds):
        dl.step(streams.next())
    jax.block_until_ready(dl.params)


def _scan_rounds(streams, dl, rounds):
    t = 0
    while t < rounds:
        n = min(CHUNK, rounds - t)
        dl.run_chunk(streams.next_chunk(n))
        t += n
    jax.block_until_ready(dl.params)


def run(quick: bool = True):
    rounds = 200
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)

    # --- cold runs (jit compile included) + equivalence check -----------
    streams_loop, dl_loop = _make(loss_fn, init_fn)
    t0 = time.perf_counter()
    _loop_rounds(streams_loop, dl_loop, rounds)
    cold_loop = time.perf_counter() - t0

    streams_scan, dl_scan = _make(loss_fn, init_fn)
    t0 = time.perf_counter()
    _scan_rounds(streams_scan, dl_scan, rounds)
    cold_scan = time.perf_counter() - t0

    comm_equal = dl_loop.comm_totals == dl_scan.comm_totals
    loss_rel = abs(dl_loop.cumulative_loss - dl_scan.cumulative_loss) / max(
        1.0, abs(dl_loop.cumulative_loss))
    params_close = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(dl_loop.params),
                        jax.tree.leaves(dl_scan.params)))

    # --- steady state: each driver keeps running on ITS OWN stream (same
    # seed, identical history, jit + sampler caches warm), so both time
    # the same per-round workload from numerically equivalent states
    t0 = time.perf_counter()
    _loop_rounds(streams_loop, dl_loop, rounds)
    warm_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    _scan_rounds(streams_scan, dl_scan, rounds)
    warm_scan = time.perf_counter() - t0

    rows = [{
        "rounds": rounds,
        "m": M,
        "chunk": CHUNK,
        "cold_loop_s": round(cold_loop, 2),
        "cold_scan_s": round(cold_scan, 2),
        "cold_speedup": round(cold_loop / cold_scan, 2),
        "warm_loop_s": round(warm_loop, 2),
        "warm_scan_s": round(warm_scan, 2),
        "warm_speedup": round(warm_loop / warm_scan, 2),
        "comm_totals_equal": bool(comm_equal),
        "params_close": bool(params_close),
        "loss_rel_err": float(loss_rel),
    }]
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    r = rows[0]
    return "PASS" if (r["warm_speedup"] >= 5.0 and r["comm_totals_equal"]
                      and r["params_close"]
                      and r["loss_rel_err"] < 1e-5) else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
