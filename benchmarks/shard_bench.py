"""Device-sharded fleet plane vs single-device flat plane (ISSUE 8).

Sweeps the scanned engine under ``layout="sharded"`` across 1/2/4/8
simulated host devices (``XLA_FLAGS=--xla_force_host_platform_device_count``
— the benchmark re-execs itself in a subprocess when the parent jax
initialized with fewer devices, since the flag must precede jax init)
on two fleet members:

* the drift MLP at m ∈ {200, 2000} (quick) + 10000 (--full) — the
  production-scale regime the sharded plane exists for, and
* the paper's 1,199,882-parameter MNIST CNN at m = 200 only: the
  (m, P) plane at m = 2000 × 1.2M params is ~19 GB of carry, beyond the
  CI runner — the memory bound is exactly why the m axis shards; the
  row documents it rather than silently skipping.

Every sharded run asserts counter equality against a ``layout="flat"``
run of the identical fixture (same seeds, same chunks, same number of
``run_chunk`` dispatches) — comm counters and the per-link transfer
totals must match bitwise. Reported per row: steady-state rounds/sec
(best-of-reps over a warm chunk), speedup vs the 1-device sharded run,
and bytes-crossing-devices per round — measured at the largest device
count by parsing the compiled round's collectives
(``repro.analysis.hlo.parse_collectives``: the gated all-reduce is the
worst-case sync the paper's bound prices), with the ring-all-reduce
estimate ``2 (n-1)/n · P · 4`` per device alongside.

Where scaling does NOT show: forced host devices time-slice the same
CPU cores, so rounds/sec only scales when the runner has spare physical
cores (the meta row records ``cores``; with cores < 2 the sweep is a
correctness sweep, and ``check`` does not demand speedup it cannot
observe). Worse, at large P the host backend's cross-shard collectives
are thread rendezvous on those shared cores: the 1.2M CNN at d=2 on a
1-core host measured 0.01 rounds/sec (~60x slower than flat), so quick
mode runs the CNN's sharded config at d=1 only and ``--full`` owns the
CNN multi-device sweep. Real scaling needs real devices — the point of
the sweep is that the SAME engine program is what runs there.

Rows persist to experiments/bench/shard_bench.json (nightly
``BENCH_shard`` artifact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import save_rows, timed

NAME = "shard_bench"
PAPER_REF = "ISSUE 8 tentpole (device-sharded fleet plane)"

FORCED_DEVICES = 8
DEVICE_SWEEP = (1, 2, 4, 8)
_CHILD_FLAG = "--emit-rows"


def _engine(arch_smoke, m, layout, devices, rounds, batch, reps):
    """Run one fixture: warm-up chunk + best-of-reps timed chunks.
    Returns (row, comm_totals, link_xfers, dl)."""
    import numpy as np
    from repro.config import ProtocolConfig, TrainConfig, get_arch
    from repro.core.divergence import flat_size
    from repro.core.protocol import DecentralizedLearner
    from repro.data.pipeline import LearnerStreams
    from repro.data.synthetic import GraphicalModelStream, SyntheticMNIST
    from repro.models.cnn import cnn_loss, init_cnn_params

    arch, smoke = arch_smoke
    cfg = get_arch(arch, smoke=smoke)
    if arch == "mnist_cnn":
        src = SyntheticMNIST(seed=0, image_size=14 if smoke else 28)
    else:
        src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=batch, seed=0)
    print(f"[shard_bench] {arch} m={m} {layout} devices={devices}...",
          file=sys.stderr, flush=True)
    proto = ProtocolConfig(kind="dynamic", b=2, delta=0.5, layout=layout,
                           shard_devices=devices)
    dl = DecentralizedLearner(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k), m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05))
    chunk = streams.next_chunk(rounds)
    dl.run_chunk(chunk)                       # compile + steady state
    best = float("inf")
    for _ in range(reps):
        _, dt = timed(lambda: dl.run_chunk(chunk))
        best = min(best, dt)
    row = {
        "arch": arch, "m": m, "layout": layout, "devices": devices,
        "params": flat_size(dl.sync_state.ref),
        "rounds_per_sec": round(rounds / best, 2),
    }
    return row, dict(dl.comm_totals), np.asarray(dl.link_xfer_totals), dl


def _wire_bytes(dl, streams_batch):
    """Static collective bytes of ONE compiled round on the fleet mesh —
    the gated worst-case sync (both branches lower)."""
    import jax
    from repro.analysis.hlo import parse_collectives
    from repro.core import shard

    with shard.use_fleet(dl.fleet):
        compiled = jax.jit(dl._make_step()).lower(
            dl.params, dl.opt_state, dl.sync_state,
            streams_batch).compile()
    stats = parse_collectives(compiled.as_text(), dl.fleet.n_devices)
    return stats.summary()


def _sweep(quick: bool):
    import jax
    import numpy as np

    n_dev = len(jax.devices())
    rows = [{
        "layout": "meta", "visible_devices": n_dev,
        "cores": os.cpu_count(),
        "scaling_expected": (os.cpu_count() or 1) >= 4,
        "note": ("forced host devices share the runner's cores; "
                 "rounds/sec scales only with spare physical cores"),
    }]
    # (arch, m, rounds, batch, reps, device subset). Quick mode keeps the
    # full 1/2/4/8 sweep on the MLP; the 1.2M CNN's multi-device configs
    # are gated behind --full: on forced host devices every cross-shard
    # collective is a thread rendezvous on the runner's core(s), and at
    # 1.2M params that rendezvous dominates — measured 0.01 rounds/sec at
    # d=2 on a 1-core host (~100 s/round, 60x slower than flat) with XLA
    # repeatedly logging stuck-participant warnings. Real meshes pay a
    # NIC, not a mutex; quick mode proves CNN counter equality at d=1
    # and leaves the d>1 wall-clock to hardware that has devices.
    cases = [(("drift_mlp", True), 200, 16, 10, 1, DEVICE_SWEEP),
             (("drift_mlp", True), 2000, 4, 10, 1, DEVICE_SWEEP)]
    if not quick:
        cases.append((("drift_mlp", True), 10000, 4, 10, 1, DEVICE_SWEEP))
    # the paper's 1.2M CNN: m = 200 only — the (m, P) carry at m = 2000
    # is ~19 GB (params + opt state + plane), past the runner; noted in
    # the meta row above and the module docstring
    cases.append((("mnist_cnn", False), 200, 2, 2, 1,
                  (1,) if quick else DEVICE_SWEEP))
    rows[0]["cnn_memory_bound"] = (
        "mnist_cnn swept at m=200 only: the (m, P) carry at m=2000 x "
        "1.2M params is ~19 GB")
    rows[0]["cnn_host_collective_bound"] = (
        "quick mode runs mnist_cnn sharded at d=1 only: host-device "
        "collectives rendezvous on shared cores — 0.01 rounds/sec "
        "measured at d=2 on 1 core; --full sweeps 1/2/4/8")

    for arch_smoke, m, rounds, batch, reps, devs in cases:
        base_row, base_comm, base_xf, base_dl = _engine(
            arch_smoke, m, "flat", 0, rounds, batch, reps)
        rows.append(base_row)
        del base_dl
        one_dev_rps = None
        sweep = [d for d in devs if d <= n_dev and m % d == 0]
        for d in sweep:
            row, comm, xf, dl = _engine(
                arch_smoke, m, "sharded", d, rounds, batch, reps)
            row["counters_equal"] = bool(
                comm == base_comm and np.array_equal(xf, base_xf))
            if d == 1:
                one_dev_rps = row["rounds_per_sec"]
            if one_dev_rps:
                row["speedup_vs_1dev"] = round(
                    row["rounds_per_sec"] / one_dev_rps, 2)
            if d == max(sweep):
                P = row["params"]
                row["ring_allreduce_bytes_per_dev"] = int(
                    2 * (d - 1) / d * P * 4)
                if arch_smoke[0] == "drift_mlp":
                    # measured collective bytes need one extra compile of
                    # the bare step — minutes for the 1.2M CNN on a CI
                    # core, so the CNN row carries the ring estimate only
                    summ = _wire_bytes(dl, jax.tree.map(
                        lambda x: x[0], _chunk_batch(arch_smoke, m,
                                                     batch)))
                    row["hlo_collective_ops"] = summ["num_ops"]
                    row["hlo_wire_bytes_per_round"] = int(
                        summ["total_wire_bytes"])
            rows.append(row)
            del dl
    return rows


def _chunk_batch(arch_smoke, m, batch):
    """One (1, m, B, ...) chunk of the fixture's stream — the step
    program's batch argument shape for the HLO probe."""
    from repro.data.pipeline import LearnerStreams
    from repro.data.synthetic import GraphicalModelStream, SyntheticMNIST

    arch, smoke = arch_smoke
    if arch == "mnist_cnn":
        src = SyntheticMNIST(seed=0, image_size=14 if smoke else 28)
    else:
        src = GraphicalModelStream(seed=0, drift_prob=0.0)
    return LearnerStreams(src, m, batch=batch, seed=0).next_chunk(1)


def run(quick: bool = True):
    import jax

    if len(jax.devices()) >= FORCED_DEVICES:
        rows = _sweep(quick)
    else:
        # jax is already initialized with too few devices — the forced
        # device count only takes effect before init, so re-exec the
        # sweep in a child process
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{FORCED_DEVICES}").strip()
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        src = os.path.join(repo, "src")
        if src not in env.get("PYTHONPATH", ""):
            env["PYTHONPATH"] = (src + os.pathsep +
                                 env.get("PYTHONPATH", "")).rstrip(
                                     os.pathsep)
        cmd = [sys.executable, "-m", "benchmarks.shard_bench", _CHILD_FLAG]
        if not quick:
            cmd.append("--full")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=repo, timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(
                f"shard_bench child failed:\n{r.stderr[-3000:]}")
        rows = json.loads(r.stdout.split("ROWS:")[1])
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    meta = rows[0]
    sharded = [r for r in rows if r.get("layout") == "sharded"]
    if not sharded or not all(r["counters_equal"] for r in sharded):
        return "MIXED"
    if not meta.get("scaling_expected", False):
        return "PASS"      # correctness sweep: no spare cores to scale on
    big = [r for r in sharded
           if r["m"] >= 2000 and r.get("speedup_vs_1dev")]
    ok = any(r["speedup_vs_1dev"] >= 1.2 for r in big)
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        out = _sweep(quick="--full" not in sys.argv)
        print("ROWS:" + json.dumps(out))
    else:
        for r in run():
            print(r)
