"""Byzantine-robust aggregation under injected faults (ISSUE 10).

Sweeps the adversary fraction of a sign-flipping fleet across the three
aggregation defenses — plain ``mean`` (the dynamic preset), the
``trimmed_mean`` pipeline (``robust_dynamic``) and the ``median``
pipeline (a directly-composed ``ProtocolSpec``: same robust trigger and
quarantine commit, maximal trim) — on one synthetic linear-regression
fleet, and scores each run by the mean per-round loss of the HONEST
learners over the last quarter of training (the stacked
``loss_per_learner`` metric; the adversary subset comes back out of the
pure fault plane, ``byzantine_mask``).

Three claims ride in ``check``:

* at a 20% sign-flipping adversary fraction the robust pipelines land
  within 10% of the fault-free loss — the trimmed order statistics
  simply drop the flipped rows;
* the same adversaries drag plain ``mean`` beyond 2x the fault-free
  loss — every sync averages the sign-flipped rows straight into the
  committed configuration;
* ``faults=None`` and an inert ``FaultConfig()`` are BITWISE identical
  through the robust pipeline (comm counters, ledger, net-time, and
  parameter bytes), measured on a real training run.

Results land at experiments/bench/robust_bench.json, uploaded nightly
as the BENCH_robust artifact.
"""
from __future__ import annotations

import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_rows
from repro.config import FaultConfig, TrainConfig
from repro.core.protocol import DecentralizedLearner
from repro.core.sync import PROTOCOLS, ProtocolSpec
from repro.network import faults as nf

NAME = "robust_bench"
PAPER_REF = ("ISSUE 10 tentpole (fault-injection plane + "
             "Byzantine-robust aggregation)")

M = 10
DIM = 8

# one divergence-triggered composition per defense, all at b=1 so the
# gate is checked every round (the default b=10 would let adversaries
# drift uncontested between checks) and a delta low enough that the
# fleet actually resynchronizes while it converges
_DYN = dict(b=1, delta=0.05)
DEFENSES = (
    ("mean", PROTOCOLS["dynamic"].with_params(**_DYN)),
    ("trimmed_mean", PROTOCOLS["robust_dynamic"].with_params(**_DYN)),
    ("median", ProtocolSpec(
        name="robust_median", trigger="robust_divergence",
        cohort="all_reachable", aggregate="median",
        commit="quarantine").with_params(**_DYN)),
)
FRACS = (0.0, 0.1, 0.2)


def _batches(n: int, seed: int = 0):
    # label noise puts the Bayes loss floor at ~2e-2, so "within 10% of
    # fault-free" compares converged plateaus instead of ratios of
    # machine-epsilon-scale residuals
    kx, ke = jax.random.split(jax.random.PRNGKey(seed))
    xs = jax.random.normal(kx, (n, M, 48, DIM))
    ys = (jnp.sum(xs, axis=-1) * 0.5
          + 0.15 * jax.random.normal(ke, (n, M, 48)))
    return (xs, ys)


def _loss(p, batch):
    x, y = batch
    return jnp.mean((x @ p["w"] - y) ** 2)


def _init(key):
    return {"w": jax.random.normal(key, (DIM,)) * 0.1}


def _digest(params) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _train(spec, rounds: int, faults=None):
    dl = DecentralizedLearner(
        _loss, _init, M, spec,
        train=TrainConfig(optimizer="sgd", learning_rate=0.05), seed=0,
        faults=faults)
    metrics = dl.run_chunk(_batches(rounds))
    return dl, metrics


def _honest_tail_loss(metrics, faults, rounds: int) -> float:
    """Mean per-round loss of the honest learners over the last quarter
    of training — adversaries train on flipped params by design, so
    their own loss says nothing about fleet health."""
    losses = np.asarray(metrics.loss_per_learner)          # (rounds, m)
    honest = ~np.asarray(nf.byzantine_mask(faults, M)) if faults \
        else np.ones((M,), bool)
    tail = losses[-(rounds // 4):, honest]
    return float(np.mean(tail))


def run(quick: bool = True):
    rounds = 64 if quick else 240
    rows = []
    fault_free = None
    for frac in FRACS:
        faults = (FaultConfig(fault_seed=11, byzantine_frac=frac,
                              byzantine_mode="sign_flip")
                  if frac > 0 else None)
        n_adv = int(round(frac * M))
        for dname, spec in DEFENSES:
            dl, metrics = _train(spec, rounds, faults)
            loss = _honest_tail_loss(metrics, faults, rounds)
            if frac == 0.0 and dname == "mean":
                fault_free = loss
            rows.append({
                "defense": dname, "adv_frac": frac, "n_adv": n_adv,
                "m": M, "rounds": rounds,
                "honest_tail_loss": round(loss, 6),
                "vs_fault_free": round(loss / fault_free, 3),
                "syncs": int(dl.comm_totals["syncs"]),
                "quarantined_total":
                    int(np.asarray(metrics.num_quarantined).sum())
                    if dname != "mean" else None,
            })
    rows.append(_fault_off_bitwise(rounds))
    save_rows(NAME, rows)
    return rows


def _fault_off_bitwise(rounds: int) -> dict:
    """faults=None vs an inert FaultConfig() through the robust
    pipeline: every counter and every parameter byte must agree."""
    def fp(faults):
        dl, _ = _train(DEFENSES[1][1], rounds, faults)
        return (dict(dl.comm_totals),
                np.asarray(dl.link_bytes_totals).tolist(),
                float(dl.network_time), _digest(dl.params))
    return {"defense": "trimmed_mean", "adv_frac": None, "m": M,
            "rounds": rounds,
            "fault_off_bitwise": fp(None) == fp(FaultConfig())}


def check(rows) -> str:
    at = {(r["defense"], r["adv_frac"]): r for r in rows
          if r["adv_frac"] is not None}
    bitwise = next(r for r in rows if r["adv_frac"] is None)
    ok = (
        # 20% sign-flippers: the robust pipelines stay within 10% of
        # the fault-free loss...
        at[("trimmed_mean", 0.2)]["vs_fault_free"] <= 1.10
        and at[("median", 0.2)]["vs_fault_free"] <= 1.10
        # ...while the plain mean is dragged past 2x
        and at[("mean", 0.2)]["vs_fault_free"] >= 2.0
        # honest fleet: the defenses cost (essentially) nothing
        and at[("trimmed_mean", 0.0)]["vs_fault_free"] <= 1.10
        # the fault plane is a bitwise no-op when off
        and bitwise["fault_off_bitwise"])
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(check(rows))
