"""Roofline table: aggregates experiments/dryrun/*.json into the
per-(arch x shape x mesh) three-term table (EXPERIMENTS.md §Roofline),
plus the analytic per-tile roofline of the protocol's Pallas kernels
(VMEM working set + HBM traffic per pass)."""
from __future__ import annotations

import glob
import json
import os

NAME = "roofline_table"
PAPER_REF = "deliverable (g)"

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

# the monitoring kernels at protocol scale: the paper's 1.2M-param CNN,
# m = 200 learners (the scale-out sweeps' fleet size)
_P, _M = 1_199_882, 200

# analytic per-kernel roofline: VMEM bytes resident per grid step and HBM
# bytes moved in one full pass. sqdist stages a (1, 65536) tile of model
# and reference; sqdist_rows (the flat fleet-plane's batched local
# condition) stages an (8, 65536) plane tile + the matching (1, 65536)
# reference slice and reads the whole (m, P) plane ONCE for all m
# learners — vs m single-model passes re-reading the reference m times.
KERNEL_ROOFLINES = [
    {"kernel": "sqdist", "tile": "(1, 65536) x2",
     "vmem_tile_bytes": 2 * 65536 * 4,
     "hbm_bytes_one_pass": 2 * _P * 4,
     "note": f"per learner; x{_M} launches for the fleet"},
    {"kernel": "sqdist_rows", "tile": "(8, 65536) + (1, 65536)",
     "vmem_tile_bytes": 9 * 65536 * 4,
     "hbm_bytes_one_pass": (_M * _P + _P) * 4,
     "note": f"whole fleet (m={_M}) in one grid; reference read once"},
]


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def format_markdown(recs) -> str:
    lines = [
        "| arch | shape | mesh | mode | compute_s | memory_s | coll_s | "
        "bottleneck | useful | arg GB/chip | temp GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('mode')}"
                f" | FAILED: {r.get('error', '?')[:60]} | | | | | | |")
            continue
        rl = r["roofline"]
        mem = rl.get("memory_stats", {})
        uf = rl.get("useful_fraction")
        lines.append(
            "| {a} | {s} | {m} | {mo} | {c:.3e} | {me:.3e} | {co:.3e} | "
            "{b} | {u} | {ag:.2f} | {tg:.2f} |".format(
                a=r["arch"], s=r["shape"], m=r["mesh"], mo=r["mode"],
                c=rl["compute_s"], me=rl["memory_s"], co=rl["collective_s"],
                b=rl["bottleneck"],
                u=f"{uf:.3f}" if uf else "-",
                ag=mem.get("argument_bytes", 0) / 1e9,
                tg=mem.get("temp_bytes", 0) / 1e9))
    lines.append("")
    lines.append("| kernel | tile | VMEM bytes/step | HBM bytes/pass | "
                 "note |")
    lines.append("|---|---|---|---|---|")
    for r in KERNEL_ROOFLINES:
        lines.append(
            f"| {r['kernel']} | {r['tile']} | {r['vmem_tile_bytes']} | "
            f"{r['hbm_bytes_one_pass']} | {r['note']} |")
    return "\n".join(lines)


def run(quick: bool = True):
    recs = load_records()
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "ok": False})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "mode": r["mode"], "ok": True,
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "bottleneck": rl["bottleneck"],
            "useful_fraction": rl.get("useful_fraction"),
        })
    rows.extend(dict(r) for r in KERNEL_ROOFLINES)
    return rows


def check(rows) -> str:
    dry = [r for r in rows if "kernel" not in r]
    done = [r for r in rows if r.get("ok")]
    return f"{len(done)}/{len(dry)} compiled" if dry else "NO-DATA"


if __name__ == "__main__":
    print(format_markdown(load_records()))
