"""Roofline table: aggregates experiments/dryrun/*.json into the
per-(arch x shape x mesh) three-term table (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os

NAME = "roofline_table"
PAPER_REF = "deliverable (g)"

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def format_markdown(recs) -> str:
    lines = [
        "| arch | shape | mesh | mode | compute_s | memory_s | coll_s | "
        "bottleneck | useful | arg GB/chip | temp GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('mode')}"
                f" | FAILED: {r.get('error', '?')[:60]} | | | | | | |")
            continue
        rl = r["roofline"]
        mem = rl.get("memory_stats", {})
        uf = rl.get("useful_fraction")
        lines.append(
            "| {a} | {s} | {m} | {mo} | {c:.3e} | {me:.3e} | {co:.3e} | "
            "{b} | {u} | {ag:.2f} | {tg:.2f} |".format(
                a=r["arch"], s=r["shape"], m=r["mesh"], mo=r["mode"],
                c=rl["compute_s"], me=rl["memory_s"], co=rl["collective_s"],
                b=rl["bottleneck"],
                u=f"{uf:.3f}" if uf else "-",
                ag=mem.get("argument_bytes", 0) / 1e9,
                tg=mem.get("temp_bytes", 0) / 1e9))
    return "\n".join(lines)


def run(quick: bool = True):
    recs = load_records()
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "ok": False})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "mode": r["mode"], "ok": True,
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "bottleneck": rl["bottleneck"],
            "useful_fraction": rl.get("useful_fraction"),
        })
    return rows


def check(rows) -> str:
    done = [r for r in rows if r.get("ok")]
    return f"{len(done)}/{len(rows)} compiled" if rows else "NO-DATA"


if __name__ == "__main__":
    print(format_markdown(load_records()))
