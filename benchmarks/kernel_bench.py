"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock favors the jnp reference — the kernels target TPU. What we CAN
measure structurally is reported instead: correctness deltas vs the oracle
and the analytic VMEM working set / HBM traffic per BlockSpec tile, plus
reference wall times for the jnp oracles at protocol-realistic sizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_rows
from repro.kernels import ops, ref

NAME = "kernel_bench"
PAPER_REF = "kernels/ (sqdist = the protocol's local-condition hot spot)"


def _time(fn, *args, iters=5):
    fn(*args)                      # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6     # us


def run(quick: bool = True):
    rows = []
    k = jax.random.PRNGKey(0)

    # sqdist at model scale (1.2M params, the paper's CNN)
    n = 1_199_882
    x = jax.random.normal(k, (n,))
    r = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    t_ref = _time(jax.jit(lambda a, b: ref.sqdist_ref(a, b)), x, r)
    err = abs(float(ops.sqdist(x, r)) - float(ref.sqdist_ref(x, r)))
    rows.append({
        "kernel": "sqdist", "size": n, "ref_us": round(t_ref, 1),
        "abs_err_vs_oracle": err,
        "vmem_tile_bytes": 2 * 65536 * 4,
        "hbm_bytes_one_pass": 2 * n * 4,
    })

    # batched sqdist over the flat fleet-plane: the whole fleet's local
    # conditions in one (m, P) x (P,) grid (layout="flat"'s hot path)
    mm = 64
    Xp = jax.random.normal(jax.random.fold_in(k, 9), (mm, n))
    # pass the tile sizes explicitly so the reported VMEM/HBM math below
    # can never drift from what the measured kernel actually staged
    tile_m, tile_n = 8, 65536
    t_ref = _time(jax.jit(
        lambda a, b: jnp.sum(jnp.square(a - b[None]), axis=1)), Xp, r)
    got_rows = np.asarray(ops.sqdist_rows(Xp, r, block_m=tile_m,
                                          block=tile_n))
    want_rows = np.asarray(jax.vmap(lambda a: ref.sqdist_ref(a, r))(Xp))
    rows.append({
        "kernel": "sqdist_rows", "size": f"{mm}x{n}",
        "ref_us": round(t_ref, 1),
        "max_err_vs_oracle": float(
            np.max(np.abs(got_rows - want_rows)
                   / np.maximum(np.abs(want_rows), 1.0))),
        # one (tile_m, tile) plane tile + the matching (1, tile)
        # reference slice staged per grid step
        "vmem_tile_bytes": (tile_m + 1) * tile_n * 4,
        "hbm_bytes_one_pass": (mm * n + n) * 4,
    })

    # flash attention, one head at prefill-like block
    B, S, d = 1, 512, 64
    q = jax.random.normal(k, (B, S, d), jnp.bfloat16)
    kk = jax.random.normal(jax.random.fold_in(k, 2), (B, S, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(k, 3), (B, S, d), jnp.bfloat16)
    t_ref = _time(jax.jit(
        lambda a, b, c: ref.flash_attention_ref(a, b, c)), q, kk, v)
    got = np.asarray(ops.flash_attention(q, kk, v), np.float32)
    want = np.asarray(ref.flash_attention_ref(q, kk, v), np.float32)
    rows.append({
        "kernel": "flash_attention", "size": f"{B}x{S}x{d}",
        "ref_us": round(t_ref, 1),
        "max_err_vs_oracle": float(np.max(np.abs(got - want))),
        "vmem_tile_bytes": (128 * d + 2 * 128 * d + 128 * d) * 2,
        "hbm_bytes_one_pass": int(q.size + kk.size + v.size) * 2,
    })

    # ssd scan at mamba2-like head shape
    BH, S2, P, N = 8, 256, 64, 16
    xs = jax.random.normal(k, (BH, S2, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 4), (BH, S2)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 5), (BH,)))
    b_ = jax.random.normal(jax.random.fold_in(k, 6), (BH, S2, N))
    c_ = jax.random.normal(jax.random.fold_in(k, 7), (BH, S2, N))
    t_ref = _time(jax.jit(
        lambda *aa: ref.ssd_scan_ref(*aa)), xs, dt, a, b_, c_)
    y, h = ops.ssd_scan(xs, dt, a, b_, c_, chunk=64)
    yr, hr = ref.ssd_scan_ref(xs, dt, a, b_, c_)
    rows.append({
        "kernel": "ssd_scan", "size": f"{BH}x{S2}x{P}x{N}",
        "ref_us": round(t_ref, 1),
        "max_err_vs_oracle": float(np.max(np.abs(np.asarray(y - yr)))),
        "vmem_tile_bytes": (64 * P + 64 + 2 * 64 * N + P * N) * 4,
        "hbm_bytes_one_pass": int(xs.size + dt.size + b_.size + c_.size) * 4,
    })

    # rmsnorm at residual-stream shape
    x2 = jax.random.normal(k, (4096, 1024), jnp.bfloat16)
    s2 = jax.random.normal(jax.random.fold_in(k, 8), (1024,))
    t_ref = _time(jax.jit(lambda a, b: ref.rmsnorm_ref(a, b)), x2, s2)
    got = np.asarray(ops.rmsnorm(x2, s2), np.float32)
    want = np.asarray(ref.rmsnorm_ref(x2, s2), np.float32)
    rows.append({
        "kernel": "rmsnorm", "size": "4096x1024", "ref_us": round(t_ref, 1),
        "max_err_vs_oracle": float(np.max(np.abs(got - want))),
        "vmem_tile_bytes": 128 * 1024 * 2 * 2,
        "hbm_bytes_one_pass": int(x2.size) * 2 * 2,
    })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    ok = all(r.get("abs_err_vs_oracle", r.get("max_err_vs_oracle", 1)) < 0.1
             for r in rows)
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
