"""Fig. A.6: the protocol is black-box in the learning algorithm phi —
dynamic averaging's advantage over periodic holds for SGD, ADAM, RMSprop."""
from __future__ import annotations

from benchmarks.common import run_mnist_protocol, save_rows
from repro.config import ProtocolConfig

NAME = "figA6_optimizers"
PAPER_REF = "Appendix A.5, Figure A.6"


def run(quick: bool = True):
    m = 6
    rounds = 80 if quick else 300
    rows = []
    for opt, lr in (("sgd", 0.1), ("adam", 1e-3), ("rmsprop", 1e-3)):
        for name, proto in [
            ("periodic_b10", ProtocolConfig(kind="periodic", b=10)),
            ("dynamic_d0.7", ProtocolConfig(kind="dynamic", b=10, delta=0.7)),
        ]:
            dl, traj, acc = run_mnist_protocol(
                proto, m=m, rounds=rounds, optimizer=opt, lr=lr)
            rows.append({
                "optimizer": opt, "protocol": name,
                "cumulative_loss": round(dl.cumulative_loss, 2),
                "comm_bytes": dl.comm_bytes(), "accuracy": round(acc, 4),
            })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    ok = True
    for opt in ("sgd", "adam", "rmsprop"):
        p = next(r for r in rows
                 if r["optimizer"] == opt and "periodic" in r["protocol"])
        d = next(r for r in rows
                 if r["optimizer"] == opt and "dynamic" in r["protocol"])
        ok &= d["comm_bytes"] <= p["comm_bytes"]
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
