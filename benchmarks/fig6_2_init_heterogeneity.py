"""Fig. 6.2 / A.8: heterogeneous initializations x averaging frequency.

Paper: noise scale eps in {0,1,...,20} on top of a Glorot init, b/B local
batches between averagings; averaged-model performance relative to
(eps=0, b/B=1). Claims: (i) homogeneous init tolerates large b/B; (ii) mild
heterogeneity (eps ~ 1-3) does NOT hurt (can help); (iii) large eps fails.
"""
from __future__ import annotations

from benchmarks.common import run_mnist_protocol, save_rows
from repro.config import ProtocolConfig

NAME = "fig6_2_init_heterogeneity"
PAPER_REF = "Figure 6.2, Appendix A.7"


def run(quick: bool = True):
    m = 6
    rounds = 80 if quick else 300
    rows = []
    base_acc = None
    for eps in (0.0, 2.0, 10.0):
        for b in (1, 10, 40):
            for kind in ("periodic", "dynamic"):
                proto = (ProtocolConfig(kind="periodic", b=b) if kind ==
                         "periodic" else
                         ProtocolConfig(kind="dynamic", b=b, delta=0.7))
                dl, traj, acc = run_mnist_protocol(
                    proto, m=m, rounds=rounds, init_heterogeneity=eps)
                if eps == 0.0 and b == 1 and kind == "periodic":
                    base_acc = acc
                rows.append({
                    "eps": eps, "b": b, "protocol": kind,
                    "accuracy": round(acc, 4),
                    "cumulative_loss": round(dl.cumulative_loss, 2),
                })
    for r in rows:
        r["relative_accuracy"] = round(r["accuracy"] / max(base_acc, 1e-9), 3)
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    # mild heterogeneity with frequent averaging stays near baseline;
    # large heterogeneity with rare averaging degrades
    mild = [r for r in rows if r["eps"] == 2.0 and r["b"] == 1]
    harsh = [r for r in rows if r["eps"] == 10.0 and r["b"] == 40]
    ok = (min(r["relative_accuracy"] for r in mild) > 0.8 and
          min(r["relative_accuracy"] for r in harsh)
          <= min(r["relative_accuracy"] for r in mild) + 0.05)
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
