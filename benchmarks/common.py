"""Shared benchmark utilities.

Every benchmark module exposes ``run(quick: bool) -> list[dict]`` rows and a
``NAME``/``PAPER_REF``; ``benchmarks.run`` orchestrates them and emits CSV.
Benchmarks reproduce the paper's *experiment structure* at CPU scale
(reduced m / rounds / model size — the protocol dynamics, not wall-clock,
are the object of study; the knobs are the same as the paper's).
"""
from __future__ import annotations

import json
import os
from typing import Callable, List

from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn_params
from repro.telemetry.trace import timed as _timed_blocked
from repro.train.loop import run_protocol_training

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def mnist_setup(image_size: int = 14):
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    return cfg, loss_fn, init_fn


def run_mnist_protocol(proto: ProtocolConfig, m: int, rounds: int,
                       lr: float = 0.1, optimizer: str = "sgd",
                       seed: int = 0, batch: int = 10,
                       init_heterogeneity: float = 0.0,
                       image_size: int = 14):
    cfg, loss_fn, init_fn = mnist_setup(image_size)
    src = SyntheticMNIST(seed=0, image_size=image_size)
    dl, traj = run_protocol_training(
        loss_fn, init_fn, src, m=m, rounds=rounds, protocol=proto,
        train=TrainConfig(optimizer=optimizer, learning_rate=lr),
        batch=batch, seed=seed, init_heterogeneity=init_heterogeneity)
    import jax
    test = src.sample(jax.random.PRNGKey(10_000), 512)
    acc = float(cnn_accuracy(cfg, dl.mean_model(), test))
    return dl, traj, acc


def save_rows(name: str, rows: List[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def timed(fn: Callable):
    """``(result, seconds)`` — ``perf_counter`` around a call that blocks
    on its result (``jax.block_until_ready``). The old ``time.time()``
    version returned before async dispatch finished, so it timed the
    Python overhead of launching the work, not the work."""
    return _timed_blocked(fn)


def fmt_bytes(n) -> str:
    n = float(n)
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if n < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"
