"""Amortized collective cost of dynamic averaging (DESIGN.md §2 promise).

The dry-run's collective term for ``train_dynamic`` is a WORST CASE: the
sync all-reduce sits on a ``lax.cond`` branch that both lowers but only
executes on violation. The executed cost per step is

    amortized = local_step_collectives + sync_rate * sync_collective

where ``sync_rate`` = syncs / condition checks, measured by running the
protocol. This benchmark measures sync_rate across a Delta grid on the
protocol simulator (the rate depends on the loss landscape, not the model
size — the paper's adaptivity claim) and reports the multiplier that scales
the dry-run's worst-case sync term.
"""
from __future__ import annotations

import jax

from benchmarks.common import save_rows
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_loss, init_cnn_params

NAME = "dynamic_amortized"
PAPER_REF = "DESIGN.md §2 (expected-case collective term)"


def run(quick: bool = True):
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    m, b = 8, 5
    rounds = 200 if quick else 600
    rows = []
    for delta in (0.1, 0.3, 0.7, 1.5, 3.0):
        src = SyntheticMNIST(seed=0, image_size=14)
        streams = LearnerStreams(src, m, batch=10, seed=0)
        dl = DecentralizedLearner(
            loss_fn, init_fn, m,
            ProtocolConfig(kind="dynamic", b=b, delta=delta),
            TrainConfig(optimizer="sgd", learning_rate=0.1))
        # scanned driver: two equal chunks, capturing syncs at the midpoint
        dl.run_chunk(streams.next_chunk(rounds // 2 + 1))
        half_syncs = dl.comm_totals["syncs"]
        dl.run_chunk(streams.next_chunk(rounds - rounds // 2 - 1))
        checks = rounds // b
        syncs = dl.comm_totals["syncs"]
        rows.append({
            "delta": delta,
            "sync_rate": round(syncs / checks, 3),
            "sync_rate_late_half": round(
                (syncs - half_syncs) / (checks / 2), 3),
            "amortized_sync_multiplier": round(syncs / checks, 3),
            "cumulative_loss": round(dl.cumulative_loss, 1),
        })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    """Sync rate must fall monotonically with Delta, giving an amortized
    sync-collective multiplier << 1 for the loose-Delta operating points.
    (Decay *within* a run toward quiescence additionally needs a decaying
    learning rate — with constant lr the SGD noise floor keeps the
    divergence rate steady, matching the paper's discussion.)"""
    rates = [r["sync_rate"] for r in rows]
    monotone = all(a >= b - 0.05 for a, b in zip(rates, rates[1:]))
    saves = rows[-1]["sync_rate"] < 0.5
    return "PASS" if (monotone and saves) else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
