"""Flat fleet-plane vs tree-layout sync kernel (the ISSUE-5 tentpole).

Times ONE staged round of the dynamic-averaging protocol — the paper's
hot loop: divergence monitoring over every learner plus the balancing
augmentation — on the paper's 1,199,882-parameter MNIST CNN, for
``layout="tree"`` (per-leaf pytree expressions, the pre-flat engine) and
``layout="flat"`` (one (m, P) matrix through the stages,
``repro.core.flatten``), from IDENTICAL state.

The fleet is constructed so the balancing loop does real work and the
augmentation count is exact: ``v = m/8`` violators drift a distance
``sqrt(D0)`` along one shared direction, everyone else sits on the
reference, so the cohort balances at exactly ``4v = m/2`` members
(``||mean_B - r||^2 = D0 (v/|B|)^2``, and ``DELTA_HALF`` sits strictly
between the ``|B| = 4v`` and ``4v - 1`` values). On the tree
layout every augmentation step re-aggregates the whole fleet —
O(m*P) per iteration, O(m^2*P) per round; the flat layout's
incremental running sum pays O(P) per iteration. A second flat timing at
a delta forcing a FULL augmentation (8v = m members) isolates the
per-iteration cost — the claim checked is that it stays flat in m.

Equivalence is asserted, not assumed: both layouts must produce a
bitwise-equal CommRecord and per-link transfer counts, and parameters
within float-reassociation tolerance.

Rows (persisted as experiments/bench/sync_bench.json, uploaded nightly
as the BENCH_sync artifact): m, layout, steady-state round_ms, cohort,
speedup (flat rows, vs the tree round), per_iter_ms (flat rows).

The run also records a SHORT instrumented drift-MLP protocol run with
the telemetry plane attached (``repro.telemetry``): the raw JSONL
stream lands at experiments/bench/sync_bench_telemetry.jsonl and its
observatory run card at experiments/bench/sync_bench_frontier.json —
both uploaded nightly as the TELEM_sync artifact. The ``telemetry``
row carries the exactness check (stream totals == engine counters).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_rows
from repro.config import get_arch
from repro.core.divergence import flat_size
from repro.core.sync import PROTOCOLS, init_state
from repro.models.cnn import init_cnn_params

NAME = "sync_bench"
PAPER_REF = "ISSUE 5 tentpole (flat fleet-plane sync path)"

D0 = 16.0          # violators' squared distance to the reference
# the balanced distance at |B| = k is D0 * (v/k)^2: 1.0 at k = 4v,
# ~1.02 at k = 4v - 1 (v = 25). DELTA_HALF sits strictly INSIDE that
# open interval, so the loop stops at exactly 4v = m/2 members in both
# layouts regardless of float association — a delta exactly on the
# 1.0 boundary would let an ulp of reassociation flip the trip count
DELTA_HALF = 1.01
M_LIST = (8, 64, 200)


def _fleet(m: int):
    """(stacked, ref, v): v = m/8 learners drifted sqrt(D0) along one
    shared unit direction, the rest exactly on the reference."""
    cfg = get_arch("mnist_cnn")            # the paper's 1.2M-param CNN
    base = init_cnn_params(cfg, jax.random.PRNGKey(0))
    leaves, treedef = jax.tree.flatten(base)
    keys = jax.random.split(jax.random.PRNGKey(1), len(leaves))
    u = [jax.random.normal(k, x.shape, jnp.float32)
         for k, x in zip(keys, leaves)]
    norm = jnp.sqrt(sum(jnp.sum(x * x) for x in u))
    v = max(1, m // 8)
    scale = jnp.where(jnp.arange(m) < v, jnp.float32(np.sqrt(D0)), 0.0)
    stacked = jax.tree.unflatten(treedef, [
        b[None] + scale.reshape((m,) + (1,) * b.ndim) * (uu / norm)[None]
        for b, uu in zip(leaves, u)])
    stacked = jax.tree.map(jax.block_until_ready, stacked)
    return stacked, base, v


def _round_fn(layout: str, delta: float):
    spec = PROTOCOLS["dynamic"].with_params(b=1, delta=delta,
                                            layout=layout)
    fn = spec.compile()
    return jax.jit(lambda s, st: fn(s, st))


def _time(fn, stacked, state, reps: int) -> float:
    """Best-of-reps seconds for one round from fixed state (fixed state =
    identical augmentation trip count every rep)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = fn(stacked, state)
        jax.block_until_ready(res.params)
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    # the m sweep is the point (the acceptance claim lives at m=200), so
    # quick mode keeps M_LIST and trims repetitions instead — ~2 min
    # total on the 2-core CI runner, in line with the other benchmarks
    rows = []
    for m in M_LIST:
        stacked, ref, v = _fleet(m)
        state = init_state(ref, 0)
        reps_tree = (2 if quick else 3) if m <= 8 else 1   # O(m^2 P)!
        reps_flat = 2 if quick else 4
        results = {}
        for layout in ("tree", "flat"):
            fn = _round_fn(layout, DELTA_HALF)
            res = fn(stacked, state)          # warm the jit cache
            jax.block_until_ready(res.params)
            results[layout] = res
            dt = _time(fn, stacked, state,
                       reps_tree if layout == "tree" else reps_flat)
            rows.append({
                "m": m, "layout": layout,
                "params": flat_size(ref),
                "round_ms": round(dt * 1e3, 2),
                "cohort": int(res.rec.model_up),
                "violators": v,
            })
        t_row, f_row = rows[-2], rows[-1]
        tr, fr = results["tree"], results["flat"]
        f_row["speedup"] = round(t_row["round_ms"] / f_row["round_ms"], 2)
        f_row["counters_equal"] = bool(
            all(int(getattr(tr.rec, k)) == int(getattr(fr.rec, k))
                for k in tr.rec._fields)
            and np.array_equal(np.asarray(tr.xfers), np.asarray(fr.xfers)))
        f_row["params_close"] = bool(all(
            np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
            for a, b in zip(jax.tree.leaves(tr.params),
                            jax.tree.leaves(fr.params))))
        del results, tr, fr

        # per-iteration probe (flat only, largest m only): a delta low
        # enough to force a FULL augmentation adds exactly (m - 4v) loop
        # iterations over the half-fleet run; the time difference per
        # extra iteration is the marginal cost of one balancing step —
        # the quantity that must not grow with m. At small m the
        # difference sits below 2-core timing noise (a handful of O(P)
        # iterations inside a ~150 ms round), so the probe would record
        # garbage — it only runs, and the claim is only checked, at the
        # largest m, where ~100 extra iterations give a clean signal.
        if m == max(M_LIST):
            delta_full = D0 * (v / m) ** 2 * 0.9
            fn_full = _round_fn("flat", float(delta_full))
            res = fn_full(stacked, state)
            jax.block_until_ready(res.params)
            assert int(res.rec.full_syncs) == 1   # probe really went full
            dt_full = _time(fn_full, stacked, state, reps_flat)
            extra_iters = (m - v) - 3 * v     # 8v - 4v = 4v when 8v == m
            f_row["per_iter_ms"] = round(
                (dt_full - f_row["round_ms"] / 1e3)
                / max(1, extra_iters) * 1e3, 3)
            del res
        del stacked, ref
    rows.append(_telemetry_run(quick))
    save_rows(NAME, rows)
    return rows


def _telemetry_run(quick: bool) -> dict:
    """Record a short instrumented protocol run and summarize it from the
    JSONL alone — the comm-vs-loss observatory over the same sync path
    the kernel rows time. Returns one ``layout="telemetry"`` row whose
    ``stream_exact`` asserts the stream's cumulative totals equal the
    engine's host counters bitwise."""
    import json
    import os

    from repro.config import (ProtocolConfig, TelemetryConfig, TrainConfig,
                              get_arch)
    from repro.data.synthetic import GraphicalModelStream
    from repro.models.cnn import cnn_loss, init_cnn_params
    from repro.telemetry.observatory import load_run, summarize
    from repro.train.loop import run_protocol_training

    from benchmarks.common import OUT_DIR

    os.makedirs(OUT_DIR, exist_ok=True)
    jsonl = os.path.normpath(
        os.path.join(OUT_DIR, "sync_bench_telemetry.jsonl"))
    card = os.path.normpath(
        os.path.join(OUT_DIR, "sync_bench_frontier.json"))
    m, rounds = 8, (60 if quick else 400)
    cfg = get_arch("drift_mlp", smoke=True)
    dl, _ = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        GraphicalModelStream(seed=0, drift_prob=0.0),
        m=m, rounds=rounds,
        protocol=ProtocolConfig(kind="dynamic", b=2, delta=0.5),
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=0, record_every=max(1, rounds // 10),
        telemetry=TelemetryConfig(path=jsonl, per_link=True, profile=True))
    dl.recorder.close()
    run_card = summarize(load_run(jsonl))
    with open(card, "w") as f:
        json.dump(run_card, f, indent=1, sort_keys=True)
    return {
        "m": m, "layout": "telemetry", "rounds": rounds,
        "cum_bytes": run_card["cum_bytes"],
        "cum_syncs": run_card["cum_syncs"],
        "stream_exact": bool(
            run_card["cum_bytes"] == dl.comm_bytes()
            and run_card["cum_syncs"] == dl.comm_totals["syncs"]
            and run_card["cum_loss"] == dl.cumulative_loss),
        "jsonl": jsonl, "card": card,
    }


def check(rows) -> str:
    flat = {r["m"]: r for r in rows if r["layout"] == "flat"}
    big = flat[max(flat)]
    ok = (big["speedup"] >= 2.0
          and all(r["counters_equal"] and r["params_close"]
                  for r in flat.values())
          # balancing cost per augmentation step independent of m: the
          # marginal iteration must cost less than 1/m of the round's
          # fixed O(m*P) work (ravel + dists + commit + unravel are ~7
          # full-plane passes) — an O(m*P) iteration, like the tree
          # layout's full re-aggregation, would cost ~1/7 of the round.
          # The probe must also come out POSITIVE: a negative difference
          # of the two timings means noise swamped the signal and the
          # claim was not actually measured — fail loudly, don't pass
          # vacuously
          and 0.0 < big["per_iter_ms"] <= big["round_ms"] / big["m"])
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
