"""Fig. 6.1 / A.7: scale-out in the number of learners m.

Paper setting: m in {10, 100, 200} on MNIST. Claim: per-learner loss keeps
improving with m (more aggregate data) and the dynamic protocols' advantage
over periodic grows with m. CPU-scale: m in {4, 8, 16}.
"""
from __future__ import annotations

from benchmarks.common import run_mnist_protocol, save_rows
from repro.config import ProtocolConfig

NAME = "fig6_1_scaleout"
PAPER_REF = "Figure 6.1, Appendix A.6"


def run(quick: bool = True):
    rounds = 100 if quick else 400
    rows = []
    for m in (4, 8, 16):
        for name, proto in [
            ("periodic_b10", ProtocolConfig(kind="periodic", b=10)),
            ("dynamic_d0.7", ProtocolConfig(kind="dynamic", b=10, delta=0.7)),
        ]:
            dl, traj, acc = run_mnist_protocol(proto, m=m, rounds=rounds)
            rows.append({
                "m": m, "protocol": name,
                "loss_per_learner": round(dl.cumulative_loss / m, 3),
                "comm_bytes": dl.comm_bytes(),
                "accuracy": round(acc, 4),
            })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    ok = True
    for m in (4, 8, 16):
        p = next(r for r in rows if r["m"] == m and "periodic" in r["protocol"])
        d = next(r for r in rows if r["m"] == m and "dynamic" in r["protocol"])
        ok &= d["comm_bytes"] <= p["comm_bytes"]
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
