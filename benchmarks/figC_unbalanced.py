"""Appendix C / Algorithm 2: unbalanced sampling rates B^i with weighted
model averaging. Claim: the weighted protocol handles unbalanced streams
(stable training, bounded divergence) and reduces to Algorithm 1 when all
B^i are equal."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_rows
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params

NAME = "figC_unbalanced"
PAPER_REF = "Appendix C, Algorithm 2"


def run(quick: bool = True):
    m = 6
    rounds = 100 if quick else 400
    sizes = [2, 4, 8, 8, 16, 32]
    cfg = get_arch("drift_mlp", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    rows = []
    for name, weighted in (("weighted_alg2", True), ("unweighted", False)):
        src = GraphicalModelStream(seed=2, drift_prob=0.0)
        streams = LearnerStreams(src, m, batch=10, seed=0,
                                 batch_sizes=sizes)
        dl = DecentralizedLearner(
            loss_fn, init_fn, m,
            ProtocolConfig(kind="dynamic", b=5, delta=0.3, weighted=weighted),
            TrainConfig(optimizer="sgd", learning_rate=0.05),
            sample_weights=streams.weights if weighted else None)
        # unbalanced B^i keeps host-side sampling, but the rounds themselves
        # run as one scanned chunk
        dl.run_chunk(streams.next_chunk(rounds))
        rows.append({
            "variant": name,
            "cumulative_loss": round(dl.cumulative_loss, 2),
            "comm_bytes": dl.comm_bytes(),
            "syncs": dl.comm_totals["syncs"],
        })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    return "PASS" if all(np.isfinite(r["cumulative_loss"])
                         for r in rows) else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
