"""Fig. 5.1: cumulative loss & communication — dynamic vs periodic vs
nosync vs serial, CNN on (synthetic) MNIST.

Paper setting: m=100, B=10, T=14000, sigma_b in {10,20,40},
sigma_Delta in {0.3,0.7,1.0}. CPU-scale: m=10, T=150 rounds, same grid.
Claim reproduced: for every periodic setup there is a dynamic setup with
comparable loss and less communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import mnist_setup, run_mnist_protocol, save_rows
from repro.config import ProtocolConfig, TrainConfig
from repro.core.protocol import SerialLearner
from repro.data.synthetic import SyntheticMNIST

NAME = "fig5_1_dynamic_vs_periodic"
PAPER_REF = "Figure 5.1 / Appendix A.1"


def run(quick: bool = True):
    m = 10
    rounds = 120 if quick else 600
    protos = [
        ("nosync", ProtocolConfig(kind="nosync")),
        ("periodic_b10", ProtocolConfig(kind="periodic", b=10)),
        ("periodic_b20", ProtocolConfig(kind="periodic", b=20)),
        ("periodic_b40", ProtocolConfig(kind="periodic", b=40)),
        ("dynamic_d0.3", ProtocolConfig(kind="dynamic", b=10, delta=0.3)),
        ("dynamic_d0.7", ProtocolConfig(kind="dynamic", b=10, delta=0.7)),
        ("dynamic_d1.0", ProtocolConfig(kind="dynamic", b=10, delta=1.0)),
        # the loose end of the grid pairs against sigma_b=40 (the paper's
        # claim is existential: for EACH periodic setup SOME dynamic setup)
        ("dynamic_d2.5", ProtocolConfig(kind="dynamic", b=10, delta=2.5)),
    ]
    rows = []
    for name, proto in protos:
        dl, traj, acc = run_mnist_protocol(proto, m=m, rounds=rounds)
        rows.append({
            "protocol": name,
            "cumulative_loss": round(dl.cumulative_loss, 2),
            "comm_bytes": dl.comm_bytes(),
            "syncs": dl.comm_totals["syncs"],
            "accuracy": round(acc, 4),
        })

    # serial baseline: observes m*T samples centrally — scanned like the
    # fleet engine (SerialLearner.run_chunk: per-round keys identical to
    # the old per-step loop, one jitted dispatch for the whole sweep)
    cfg, loss_fn, init_fn = mnist_setup()
    src = SyntheticMNIST(seed=0, image_size=14)
    sl = SerialLearner(loss_fn, init_fn,
                       TrainConfig(optimizer="sgd", learning_rate=0.1))
    key = jax.random.PRNGKey(123)
    keys = jax.vmap(lambda t: jax.random.fold_in(key, t))(
        jnp.arange(rounds))
    sl.run_chunk(jax.vmap(lambda k: src.sample(k, 10 * m))(keys))
    rows.append({"protocol": "serial", "cumulative_loss":
                 round(sl.cumulative_loss * m, 2),   # paper sums over mT inputs
                 "comm_bytes": 0, "syncs": 0, "accuracy": None})
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    """For each periodic setup, some dynamic setup has <= 1.15x loss with
    < 1.0x communication (the paper's Fig. 5.1 claim)."""
    per = [r for r in rows if r["protocol"].startswith("periodic")]
    dyn = [r for r in rows if r["protocol"].startswith("dynamic")]
    ok = all(any(d["comm_bytes"] < p["comm_bytes"] and
                 d["cumulative_loss"] < 1.15 * p["cumulative_loss"]
                 for d in dyn) for p in per)
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
