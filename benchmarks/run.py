"""Benchmark orchestrator: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--list]

Prints one CSV block per benchmark plus a summary line
``name,seconds,claim_check`` and persists per-benchmark JSON under
experiments/bench/. ``--list`` enumerates the registered benchmarks
(name + paper reference) without running anything — the registry contract
CI and humans can check cheaply.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    dynamic_amortized,
    fig5_1_dynamic_vs_periodic,
    fig5_2_fedavg,
    fig5_4_drift,
    fig5_5_deepdrive,
    fig6_1_scaleout,
    fig6_2_init_heterogeneity,
    figA6_optimizers,
    figC_unbalanced,
    fig_hierarchy,
    fig_network_regimes,
    kernel_bench,
    roofline_table,
    scan_driver,
)

ALL = [
    scan_driver,
    fig5_1_dynamic_vs_periodic,
    dynamic_amortized,
    fig5_2_fedavg,
    fig5_4_drift,
    fig5_5_deepdrive,
    fig6_1_scaleout,
    fig6_2_init_heterogeneity,
    figA6_optimizers,
    figC_unbalanced,
    fig_network_regimes,
    fig_hierarchy,
    kernel_bench,
    roofline_table,
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--list", action="store_true",
                    help="enumerate registered benchmarks and exit")
    args = ap.parse_args()

    if args.list:
        for mod in ALL:
            print(f"{mod.NAME}\t{mod.PAPER_REF}")
        return

    summary = []
    for mod in ALL:
        if args.only and args.only not in mod.NAME:
            continue
        t0 = time.time()
        print(f"\n=== {mod.NAME}  [{mod.PAPER_REF}] ===", flush=True)
        try:
            rows = mod.run(quick=not args.full)
            verdict = mod.check(rows)
            for r in rows:
                print("  " + ",".join(
                    f"{k}={v}" for k, v in r.items()
                    if not isinstance(v, (list, dict))))
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            verdict = f"ERROR:{e!r}"
        dt = time.time() - t0
        print(f"  -> {verdict} ({dt:.1f}s)")
        summary.append((mod.NAME, dt, verdict))

    print("\n==== SUMMARY (name,seconds,claim_check) ====")
    ok = True
    for name, dt, verdict in summary:
        print(f"{name},{dt:.1f},{verdict}")
        ok &= not str(verdict).startswith("ERROR")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
