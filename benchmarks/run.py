"""Benchmark orchestrator: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--list]
    PYTHONPATH=src python -m benchmarks.run --protocol spec.json

Prints one CSV block per benchmark plus a summary line
``name,seconds,claim_check`` and persists per-benchmark JSON under
experiments/bench/. ``--list`` enumerates the registered benchmarks
(name + paper reference) without running anything — the registry contract
CI and humans can check cheaply.

``--protocol`` runs an ARBITRARY serialized ``ProtocolSpec`` (the JSON
written by ``ProtocolSpec.to_json`` / saved next to checkpoints) through
the scan driver on the drift-MLP task and reports loss / communication —
new stage compositions are benchmarkable without writing a fig module.
Combine with ``--full`` for paper-scale rounds.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    async_bench,
    dynamic_amortized,
    fig5_1_dynamic_vs_periodic,
    fig5_2_fedavg,
    fig5_4_drift,
    fig5_5_deepdrive,
    fig6_1_scaleout,
    fig6_2_init_heterogeneity,
    figA6_optimizers,
    figC_unbalanced,
    fig_hierarchy,
    fig_network_regimes,
    kernel_bench,
    robust_bench,
    roofline_table,
    scan_driver,
    shard_bench,
    sync_bench,
)

ALL = [
    scan_driver,
    fig5_1_dynamic_vs_periodic,
    dynamic_amortized,
    fig5_2_fedavg,
    fig5_4_drift,
    fig5_5_deepdrive,
    fig6_1_scaleout,
    fig6_2_init_heterogeneity,
    figA6_optimizers,
    figC_unbalanced,
    fig_network_regimes,
    fig_hierarchy,
    sync_bench,
    shard_bench,
    async_bench,
    robust_bench,
    kernel_bench,
    roofline_table,
]


def run_protocol_spec(path: str, full: bool = False, m: int = 8,
                      seed: int = 0, telemetry: str = None) -> dict:
    """Drive one serialized ``ProtocolSpec`` through the scanned engine
    (drift-MLP smoke task) and report loss/communication. ``telemetry``
    streams the run's per-round records to that JSONL path
    (``repro.telemetry``)."""
    from repro.config import TelemetryConfig, TrainConfig, get_arch
    from repro.core.sync.spec import ProtocolSpec
    from repro.data.synthetic import GraphicalModelStream
    from repro.models.cnn import cnn_loss, init_cnn_params
    from repro.train.loop import run_protocol_training

    spec = ProtocolSpec.from_file(path)
    rounds = 2000 if full else 200
    cfg = get_arch("drift_mlp", smoke=True)
    telem = (TelemetryConfig(path=telemetry, per_link=True, profile=True)
             if telemetry else None)
    dl, traj = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        GraphicalModelStream(seed=0, drift_prob=0.0),
        m=m, rounds=rounds, protocol=spec,
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=seed, record_every=max(1, rounds // 10),
        telemetry=telem)
    if dl.recorder is not None:
        dl.recorder.close()
    row = {
        "spec": spec.to_dict(),
        "m": m,
        "rounds": rounds,
        "cumulative_loss": dl.cumulative_loss,
        "mean_round_loss": dl.cumulative_loss / (rounds * m),
        "syncs": dl.comm_totals["syncs"],
        "full_syncs": dl.comm_totals["full_syncs"],
        "model_up": dl.comm_totals["model_up"],
        "messages": dl.comm_totals["messages"],
        "comm_bytes": dl.comm_bytes(),
        "loss_curve": traj.cumulative_loss,
        "bytes_curve": traj.cumulative_bytes,
    }
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--list", action="store_true",
                    help="enumerate registered benchmarks and exit")
    ap.add_argument("--protocol", default=None, metavar="SPEC_JSON",
                    help="run a serialized ProtocolSpec through the scan "
                         "driver and report loss/comm")
    ap.add_argument("--telemetry", default=None, metavar="JSONL",
                    help="with --protocol: stream per-round telemetry "
                         "records to this JSONL file (repro.telemetry)")
    args = ap.parse_args()

    if args.telemetry and not args.protocol:
        ap.error("--telemetry requires --protocol (it instruments the "
                 "spec run)")

    if args.list:
        for mod in ALL:
            print(f"{mod.NAME}\t{mod.PAPER_REF}")
        return

    if args.protocol:
        import re
        from benchmarks.common import save_rows
        t0 = time.perf_counter()
        row = run_protocol_spec(args.protocol, full=args.full,
                                telemetry=args.telemetry)
        name = re.sub(r"[^\w.-]", "_", row["spec"]["name"]) or "custom"
        print(f"=== protocol_spec  [{args.protocol}] ===")
        for k, v in row.items():
            if not isinstance(v, (list, dict)):
                print(f"  {k}={v}")
        path = save_rows(f"protocol_spec_{name}", [row])
        if args.telemetry:
            print(f"  -> telemetry {args.telemetry}")
        print(f"  -> saved {path} ({time.perf_counter() - t0:.1f}s)")
        return

    summary = []
    for mod in ALL:
        if args.only and args.only not in mod.NAME:
            continue
        t0 = time.perf_counter()
        print(f"\n=== {mod.NAME}  [{mod.PAPER_REF}] ===", flush=True)
        try:
            rows = mod.run(quick=not args.full)
            verdict = mod.check(rows)
            for r in rows:
                print("  " + ",".join(
                    f"{k}={v}" for k, v in r.items()
                    if not isinstance(v, (list, dict))))
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            verdict = f"ERROR:{e!r}"
        dt = time.perf_counter() - t0
        print(f"  -> {verdict} ({dt:.1f}s)")
        summary.append((mod.NAME, dt, verdict))

    print("\n==== SUMMARY (name,seconds,claim_check) ====")
    ok = True
    for name, dt, verdict in summary:
        print(f"{name},{dt:.1f},{verdict}")
        ok &= not str(verdict).startswith("ERROR")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
