"""Fig. 5.5 / A.4: the deep-driving case study (in-fleet learning).

The paper trains PilotNet (Bojarski et al.) on human-driving frames and
evaluates trained models in a driving simulator with a custom loss

    L_dd = lambda (t_max - t)/t_max + mu c/c_max + (1-mu-lambda) t_line/t

(t = time on road, c = sideline-crossing frequency, t_line = time on line).
The Udacity simulator is not available offline; we reproduce the evaluation
SEMANTICS with a procedural driving stream: a model "drives" a simulated
episode where the car leaves the road when its steering error exceeds a
threshold for several consecutive frames, and touches the sideline when the
error exceeds a smaller threshold. lambda=0.8, mu=0.15 as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_rows
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import DeepDriveStream
from repro.models.cnn import cnn_apply, cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training

NAME = "fig5_5_deepdrive"
PAPER_REF = "Figure 5.5, Appendix A.4"

LAM, MU = 0.8, 0.15
OFF_ROAD_ERR = 0.6       # sustained error -> crash / off-road
SIDELINE_ERR = 0.3       # momentary error -> sideline touch
EPISODE = 300


def drive_episode(cfg, params, seed: int = 0):
    """Returns (t_on_road, crossings, t_line) for one simulated episode."""
    src = DeepDriveStream(seed=seed, height=cfg.input_shape[0],
                          width=cfg.input_shape[1])
    key = jax.random.PRNGKey(seed)
    errs = []
    for step in range(EPISODE // 50):
        b = src.sample(jax.random.fold_in(key, step), 50)
        pred = cnn_apply(cfg, params, b["x"])[:, 0]
        errs.append(np.abs(np.asarray(pred - b["y"])))
    err = np.concatenate(errs)
    off = err > OFF_ROAD_ERR
    # crash at the first window of 3 consecutive off-road frames
    t = len(err)
    for i in range(len(err) - 2):
        if off[i] and off[i + 1] and off[i + 2]:
            t = i
            break
    line = err[:t] > SIDELINE_ERR
    return t, int(np.sum(np.diff(line.astype(int)) == 1)), int(np.sum(line))


def custom_loss(t, c, t_line, t_max, c_max):
    cf = (c / max(t, 1)) / max(c_max, 1e-9)
    return (LAM * (t_max - t) / t_max + MU * cf
            + (1 - MU - LAM) * t_line / max(t, 1))


def run(quick: bool = True):
    cfg = get_arch("deepdrive_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    m = 5
    rounds = 80 if quick else 400
    protos = [
        ("periodic_b10", ProtocolConfig(kind="periodic", b=10)),
        ("periodic_b40", ProtocolConfig(kind="periodic", b=40)),
        ("dynamic_d0.1", ProtocolConfig(kind="dynamic", b=10, delta=0.1)),
        ("dynamic_d0.3", ProtocolConfig(kind="dynamic", b=10, delta=0.3)),
        ("nosync", ProtocolConfig(kind="nosync")),
    ]
    results = []
    for name, proto in protos:
        src = DeepDriveStream(seed=3, height=cfg.input_shape[0],
                              width=cfg.input_shape[1])
        dl, traj = run_protocol_training(
            loss_fn, init_fn, src, m=m, rounds=rounds, protocol=proto,
            train=TrainConfig(optimizer="sgd", learning_rate=0.05),
            batch=10, seed=0)
        t, c, t_line = drive_episode(cfg, dl.mean_model(), seed=77)
        results.append((name, dl, t, c, t_line))
    t_max = max(r[2] for r in results)
    c_max = max(r[3] / max(r[2], 1) for r in results)
    rows = []
    for name, dl, t, c, t_line in results:
        rows.append({
            "protocol": name,
            "custom_loss_Ldd": round(custom_loss(t, c, t_line, t_max, c_max), 4),
            "time_on_road": t, "crossings": c,
            "comm_bytes": dl.comm_bytes(),
        })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    """Some dynamic protocol matches the best periodic's driving loss with
    less communication (Fig. 5.5 claim)."""
    per = [r for r in rows if r["protocol"].startswith("periodic")]
    dyn = [r for r in rows if r["protocol"].startswith("dynamic")]
    best_per = min(per, key=lambda r: r["custom_loss_Ldd"])
    ok = any(d["custom_loss_Ldd"] <= best_per["custom_loss_Ldd"] + 0.1 and
             d["comm_bytes"] < best_per["comm_bytes"] for d in dyn)
    return "PASS" if ok else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
