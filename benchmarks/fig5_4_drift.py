"""Fig. 5.4 / A.4: adaptivity to concept drift (random graphical model).

Paper setting: m=100, 5000 samples/learner, drift prob 0.001. Claim:
dynamic averaging matches periodic's loss with up to an order of magnitude
less communication, and its communication concentrates right after drifts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_rows
from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_drift_segments

NAME = "fig5_4_drift"
PAPER_REF = "Figure 5.4, Appendix A.3"


def _run_one(proto, m, rounds, drift_rounds, seed=0):
    cfg = get_arch("drift_mlp", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = GraphicalModelStream(seed=1, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=10, seed=seed)
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05), seed=seed)
    # drift rounds are known: scan the segments between them
    sync_curve, loss_curve = run_drift_segments(
        dl, streams, src, rounds, drift_rounds)
    return dl, sync_curve, loss_curve


def run(quick: bool = True):
    m = 8
    rounds = 180 if quick else 600
    drift_rounds = {rounds // 3, 2 * rounds // 3}
    rows = []
    for name, proto in [
        ("periodic_b10", ProtocolConfig(kind="periodic", b=10)),
        ("dynamic_d0.3", ProtocolConfig(kind="dynamic", b=2, delta=0.3)),
    ]:
        dl, syncs, losses = _run_one(proto, m, rounds, drift_rounds)
        # syncs in the 20 rounds after each drift vs 20 calm rounds before
        w = 20
        post = sum(int(syncs[min(d + w, rounds - 1)] - syncs[d])
                   for d in drift_rounds)
        pre = sum(int(syncs[d] - syncs[d - w]) for d in drift_rounds)
        rows.append({
            "protocol": name,
            "cumulative_loss": round(float(losses[-1]), 2),
            "comm_bytes": dl.comm_bytes(),
            "syncs_total": int(syncs[-1]),
            "syncs_post_drift_window": post,
            "syncs_pre_drift_window": pre,
        })
    save_rows(NAME, rows)
    return rows


def check(rows) -> str:
    dyn = next(r for r in rows if r["protocol"].startswith("dynamic"))
    per = next(r for r in rows if r["protocol"].startswith("periodic"))
    adaptive = dyn["syncs_post_drift_window"] >= dyn["syncs_pre_drift_window"]
    cheaper = dyn["comm_bytes"] < per["comm_bytes"]
    similar = dyn["cumulative_loss"] < 1.2 * per["cumulative_loss"]
    return "PASS" if (adaptive and cheaper and similar) else "MIXED"


if __name__ == "__main__":
    for r in run():
        print(r)
