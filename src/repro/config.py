"""Configuration system for the repro framework.

Dataclass-based, no external deps. A ``ModelConfig`` fully describes one of
the supported architecture families:

* dense decoder (GQA, optional QKV bias, optional sliding window)
* MoE decoder (top-k routing, optional shared experts, optional MLA)
* SSM decoder (Mamba2 / SSD)
* hybrid decoder (parallel attention + SSM heads, Hymba-style)
* CNN classifiers / regressors (the paper's own MNIST and deep-driving nets)

``ShapeConfig`` describes one of the assigned input shapes, ``MeshConfig``
the device mesh, ``ProtocolConfig`` the paper's synchronization protocol and
``TrainConfig`` the optimizer/loop settings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

BLOCK_ATTN = "attn"
BLOCK_SSM = "ssm"
BLOCK_HYBRID = "hybrid"

ATTN_FULL = "full"
ATTN_SLIDING = "sliding"

MODALITY_TEXT = "text"
MODALITY_VISION = "vision"   # VLM: stub patch embeddings + text tokens
MODALITY_AUDIO = "audio"     # audio: decoder over codec tokens (stub frontend)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for the FFN of a block."""
    num_experts: int = 8
    num_experts_per_tok: int = 2
    num_shared_experts: int = 0          # DeepSeek-style always-on experts
    expert_d_ff: int = 0                 # per-expert hidden dim (0 -> use d_ff)
    capacity_factor: float = 1.25        # dispatch capacity per expert
    router_aux_loss_coef: float = 0.01   # load-balance loss weight


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 -> full-rank queries
    rope_head_dim: int = 64              # decoupled rope dims per head


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD settings."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 64
    ngroups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                           # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int = 0                    # 0 for attention-free
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                     # 0 -> d_model // num_heads
    block_type: str = BLOCK_ATTN          # attn | ssm | hybrid
    attn_type: str = ATTN_FULL            # full | sliding
    sliding_window: int = 4096
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    modality: str = MODALITY_TEXT
    moe: Optional[MoEConfig] = None
    moe_layer_period: int = 1             # every k-th layer is MoE (1 = all)
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # CNN-only fields (paper's MNIST / deep-driving nets)
    cnn_spec: Optional[Tuple[Any, ...]] = None
    input_shape: Optional[Tuple[int, ...]] = None   # per-example, CNN/MLP only
    num_outputs: int = 0                            # CNN/MLP head size
    dtype: str = "float32"
    source: str = ""                      # citation for the config
    # scan layers with lax.scan (small HLO). False -> unrolled python loop;
    # used by the roofline tooling to calibrate per-layer costs, since XLA
    # cost_analysis counts a while-loop body ONCE regardless of trip count.
    scan_layers: bool = True

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def is_attention_free(self) -> bool:
        return self.block_type == BLOCK_SSM

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k+ tokens is sub-quadratic / bounded-state."""
        return (
            self.block_type in (BLOCK_SSM, BLOCK_HYBRID)
            or self.attn_type == ATTN_SLIDING
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        if self.family == "cnn":
            return -1  # computed from the actual pytree instead
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = 0
        # embeddings
        n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        # norms
        per_layer += 2 * d
        if self.block_type in (BLOCK_ATTN, BLOCK_HYBRID):
            if self.mla is not None:
                r, rh = self.mla.kv_lora_rank, self.mla.rope_head_dim
                per_layer += d * (r + rh)                       # kv down + shared rope k
                per_layer += r * self.num_heads * (hd + hd)     # k/v up
                if self.mla.q_lora_rank:
                    per_layer += d * self.mla.q_lora_rank
                    per_layer += self.mla.q_lora_rank * self.num_heads * (hd + rh)
                else:
                    per_layer += d * self.num_heads * (hd + rh)
                per_layer += self.num_heads * hd * d            # out proj
            else:
                per_layer += d * self.num_heads * hd            # q
                per_layer += 2 * d * self.num_kv_heads * hd     # k, v
                per_layer += self.num_heads * hd * d            # o
                if self.qkv_bias:
                    per_layer += (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.block_type in (BLOCK_SSM, BLOCK_HYBRID):
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per_layer += d * 2 * d_in                           # in proj (x, z)
            per_layer += d * (2 * s.ngroups * s.d_state + nheads)  # B, C, dt proj
            per_layer += s.d_conv * (d_in + 2 * s.ngroups * s.d_state)
            per_layer += nheads * 2                             # A_log, D
            per_layer += d_in * d                               # out proj
        # FFN
        if self.is_moe:
            eff = self.moe.expert_d_ff or self.d_ff
            n_moe_layers = L // self.moe_layer_period
            n_dense_layers = L - n_moe_layers
            per_moe = (self.moe.num_experts + self.moe.num_shared_experts) * 3 * d * eff
            per_moe += d * self.moe.num_experts                 # router
            n += n_moe_layers * per_moe + n_dense_layers * (3 * d * self.d_ff)
            n += L * per_layer
        else:
            if self.d_ff:
                per_layer += 3 * d * self.d_ff                  # swiglu
            n += L * per_layer
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        eff = self.moe.expert_d_ff or self.d_ff
        total = self.param_count()
        n_moe_layers = L // self.moe_layer_period
        all_exp = (self.moe.num_experts + self.moe.num_shared_experts) * 3 * d * eff
        act_exp = (self.moe.num_experts_per_tok + self.moe.num_shared_experts) * 3 * d * eff
        return total - n_moe_layers * (all_exp - act_exp)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# The paper's protocol
# ---------------------------------------------------------------------------

PROTO_NOSYNC = "nosync"
PROTO_PERIODIC = "periodic"
PROTO_CONTINUOUS = "continuous"
PROTO_FEDAVG = "fedavg"
PROTO_DYNAMIC = "dynamic"
PROTO_GOSSIP = "gossip"


@dataclass(frozen=True)
class ProtocolConfig:
    """Synchronization protocol Π = (φ, σ).

    ``kind`` selects the operator σ; ``b`` is the check/sync period in local
    steps; ``delta`` the divergence threshold Δ for σ_Δ; ``fedavg_c`` the
    subsampled fraction C for FedAvg; ``augmentation`` selects the
    coordinator's balancing strategy for dynamic averaging. ``gossip`` is
    the coordinator-free baseline: neighborhood averaging over the network
    topology (``NetworkConfig``) every ``b`` rounds.

    ``tiers`` turns the flat protocol into a two-tier star-of-stars
    (``HierarchyConfig``): THIS config becomes the intra-tier operator
    (learners ↔ their cluster's edge aggregator, own ``b``/``delta``) and
    ``tiers.inter`` runs among the edge aggregators. ``tiers=None`` is the
    flat single-coordinator protocol, bitwise-identical to the
    pre-hierarchy engine.

    ``layout`` selects the sync arithmetic: ``"tree"`` (default) runs the
    per-leaf pytree expressions, bitwise-identical to the pre-flat
    engine; ``"flat"`` carries the fleet through the sync stages as one
    contiguous ``(m, P)`` matrix (``repro.core.flatten``) — parameters
    equal to float-reassociation tolerance, identical sync decisions
    (hence bitwise comm counters) unless a distance lands within
    reassociation error of the Delta threshold, and the balancing
    augmentation drops from O(m^2 P) to O(m P); ``"sharded"`` is the
    flat plane with the learner axis split over a device mesh
    (``repro.core.shard``) — same arithmetic as flat, the engine places
    the scan carry so per-learner updates, distances, and commits run
    per-shard and only trigger votes + cohort means cross devices.
    ``shard_devices`` caps how many visible devices the fleet mesh uses
    (0 = all); ``m % n_devices`` must be 0 — every device owns the same
    number of learner rows.
    """
    kind: str = PROTO_DYNAMIC
    b: int = 10
    delta: float = 0.5
    fedavg_c: float = 0.3
    augmentation: str = "max_distance"   # max_distance | random | all
    weighted: bool = False               # Algorithm 2 (unbalanced B^i)
    bytes_per_param: int = 4
    layout: str = "tree"                 # tree | flat | sharded
    shard_devices: int = 0               # sharded: device cap, 0 = all
    tiers: Optional[HierarchyConfig] = None   # two-tier hierarchy on top

    def __post_init__(self):
        if self.b < 1:
            raise ValueError(f"sync period b must be >= 1, got {self.b!r}")
        if not 0.0 < self.fedavg_c <= 1.0:
            raise ValueError(
                f"fedavg_c must be in (0, 1], got {self.fedavg_c!r}")
        # resolving against the protocol preset registry validates the
        # kind (unknown kinds raise with the known list — registering a
        # new protocol makes it a valid kind) and the parameters the
        # preset's stages consume: delta > 0 rejects a dynamic config but
        # never a periodic one, which doesn't read it
        spec = self._spec()
        if self.tiers is not None and not spec.uses_coordinator:
            raise ValueError(
                f"{self.kind} cannot be the intra-tier operator of a "
                "hierarchy: it has no coordinator — a cluster's members "
                "talk to their edge aggregator over uplinks. Use a "
                "coordinator protocol (periodic/fedavg/dynamic) per tier.")

    def _spec(self):
        """The ``ProtocolSpec`` this config resolves to (its preset with
        this config's parameter fields overlaid)."""
        from repro.core.sync.spec import resolve_spec
        return resolve_spec(self)


@dataclass(frozen=True)
class HierarchyConfig:
    """Two-tier star-of-stars coordinator hierarchy.

    The fleet is partitioned into ``num_clusters`` contiguous, equal-size
    clusters (the engine rejects ``m % num_clusters != 0`` at construction
    with a clear message). Each round the enclosing ``ProtocolConfig`` runs
    as the *intra-tier* operator inside every cluster (members ↔ their edge
    aggregator, with per-cluster reference/violation state), the edge
    aggregator model is the availability-masked cluster mean, and
    ``inter`` runs among the ``num_clusters`` aggregator models (its own
    cadence ``b``, threshold ``delta``, and payload ``bytes_per_param`` —
    e.g. a quantized backhaul). When the inter tier synchronizes a set of
    clusters, their reachable members receive the inter-tier adjustment.

    ``link_class`` is the aggregator↔top-coordinator uplink class used by
    the network cost model (edge servers usually sit on wired backhaul);
    member links keep their ``NetworkConfig.link_classes`` assignment.
    """
    num_clusters: int
    inter: ProtocolConfig
    link_class: str = "wired"

    def __post_init__(self):
        if self.num_clusters < 2:
            raise ValueError(
                f"a hierarchy needs >= 2 clusters, got {self.num_clusters} "
                "(one cluster is just the flat protocol — drop tiers=)")
        if not self.inter._spec().uses_coordinator:
            raise ValueError(
                f"the inter-tier operator cannot be {self.inter.kind}: "
                "edge aggregators talk to the top coordinator over a star "
                "of uplinks, not a peer overlay. Use a coordinator "
                "protocol (periodic/fedavg/dynamic/nosync).")
        if self.inter.tiers is not None:
            raise ValueError(
                "hierarchies do not nest: tiers.inter must have tiers=None "
                "(the hierarchy is exactly two tiers).")
        if self.link_class not in LINK_CLASS_NAMES:
            raise KeyError(
                f"unknown aggregator link class {self.link_class!r}; "
                f"known: {sorted(LINK_CLASS_NAMES)}")


# ---------------------------------------------------------------------------
# Network environment (topology, availability, link costs)
# ---------------------------------------------------------------------------

TOPO_STAR = "star"
TOPO_RING = "ring"
TOPO_TORUS = "torus"
TOPO_ERDOS_RENYI = "erdos_renyi"
TOPO_GEOMETRIC = "geometric"

TOPOLOGIES = (
    TOPO_STAR, TOPO_RING, TOPO_TORUS, TOPO_ERDOS_RENYI, TOPO_GEOMETRIC,
)

# Link-class registry contract: the names configs may reference. The
# bandwidth/latency numbers live in ``repro.network.cost.LINK_CLASSES``
# (which asserts it covers exactly these names) — configs validate
# membership HERE so a typo fails at construction, not at trace time.
LINK_CLASS_NAMES = ("wired", "wifi", "lte", "edge")


@dataclass(frozen=True)
class NetworkConfig:
    """Simulated network environment for a fleet of learners.

    Three orthogonal aspects (see ``repro.network``):

    * **topology** — the peer overlay as an (m, m) symmetric adjacency
      matrix: ``star`` | ``ring`` | ``torus`` | ``erdos_renyi`` |
      ``geometric``. ``geometric`` with ``redraw_every=k`` models mobility:
      node positions (hence edges) are re-drawn every k rounds, as a pure
      function of ``(seed, t)`` so it evaluates inside ``lax.scan``.
      Coordinator operators (periodic/fedavg/dynamic) talk over
      learner↔coordinator uplinks and are constrained by *availability*
      only; the overlay governs the coordinator-free ``gossip`` operator.
    * **availability** — per-round (m,) active masks: i.i.d. Bernoulli
      ``act_prob`` dropout, a ``straggler_frac`` subset with its own lower
      ``straggler_act_prob``, and scheduled outages (every ``outage_every``
      rounds a random ``outage_frac`` of the fleet goes dark for
      ``outage_length`` rounds). Unavailable learners keep training
      locally but neither violate, get polled, nor receive averages.
    * **link costs** — per-learner bandwidth/latency classes
      (``repro.network.cost.LINK_CLASSES``) assigned round-robin from
      ``link_classes``; model transfers convert to simulated per-round
      wall-clock and per-link bytes.
    """
    topology: str = TOPO_STAR
    er_p: float = 0.3                    # Erdős–Rényi edge probability
    geo_radius: float = 0.5              # geometric connection radius in [0,1]^2
    redraw_every: int = 0                # >0: re-draw geometric graph every k rounds
    act_prob: float = 1.0                # Bernoulli availability per learner/round
    straggler_frac: float = 0.0          # fraction of learners that straggle
    straggler_act_prob: float = 0.5      # their (lower) availability
    outage_every: int = 0                # 0 = no scheduled outages
    outage_length: int = 1               # rounds an outage lasts
    outage_frac: float = 0.25            # fraction of fleet taken down
    link_classes: Tuple[str, ...] = ("wired",)
    msg_bytes: int = 64                  # control-message size for time accounting
    seed: int = 0

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise KeyError(
                f"unknown topology {self.topology!r}; "
                f"known: {sorted(TOPOLOGIES)}")
        if not 0.0 <= self.er_p <= 1.0:
            raise ValueError(
                f"er_p is an edge probability, must be in [0, 1]: "
                f"got {self.er_p!r}")
        if not self.geo_radius > 0.0:
            raise ValueError(
                f"geo_radius must be > 0, got {self.geo_radius!r}")
        if self.redraw_every < 0:
            raise ValueError(
                f"redraw_every must be >= 0 (0 = static graph), "
                f"got {self.redraw_every!r}")
        # mobility is a property of the geometric graph (positions move);
        # reject the combo instead of silently keeping other overlays static
        if self.redraw_every != 0 and self.topology != TOPO_GEOMETRIC:
            raise ValueError(
                f"redraw_every only applies to topology='geometric', "
                f"got {self.topology!r}")
        if not 0.0 < self.act_prob <= 1.0:
            raise ValueError(
                f"act_prob is a per-round availability probability, must "
                f"be in (0, 1]: got {self.act_prob!r}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(
                f"straggler_frac must be in [0, 1], "
                f"got {self.straggler_frac!r}")
        if not 0.0 < self.straggler_act_prob <= 1.0:
            raise ValueError(
                f"straggler_act_prob must be in (0, 1], "
                f"got {self.straggler_act_prob!r}")
        if self.outage_every < 0:
            raise ValueError(
                f"outage_every must be >= 0 (0 = no outages), "
                f"got {self.outage_every!r}")
        if self.outage_length < 1:
            raise ValueError(
                f"outage_length must be >= 1 round, "
                f"got {self.outage_length!r}")
        # an outage longer than its period is a permanent blackout, not a
        # scheduled one — reject rather than silently darken the fleet
        if self.outage_every != 0 and self.outage_length > self.outage_every:
            raise ValueError(
                f"outage_length ({self.outage_length}) must not exceed "
                f"outage_every ({self.outage_every}) — that is a permanent "
                f"blackout, not a scheduled outage")
        if not 0.0 <= self.outage_frac <= 1.0:
            raise ValueError(
                f"outage_frac must be in [0, 1], got {self.outage_frac!r}")
        if len(self.link_classes) < 1:
            raise ValueError(
                "link_classes must name at least one link class "
                "(assigned round-robin over the learner index)")
        unknown = [c for c in self.link_classes if c not in LINK_CLASS_NAMES]
        if unknown:
            raise KeyError(
                f"unknown link class(es) {unknown}; "
                f"known: {sorted(LINK_CLASS_NAMES)}")

    @property
    def full_availability(self) -> bool:
        """True when every learner is reachable every round (the engine
        then skips mask sampling entirely — the pre-network fast path)."""
        return (self.act_prob >= 1.0 and self.straggler_frac == 0.0
                and self.outage_every == 0)


# ---------------------------------------------------------------------------
# Asynchronous timeline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AsyncConfig:
    """The event-driven network timeline (``repro.network.events`` +
    ``repro.core.sync.async_sync``), threaded through the engine like
    ``NetworkConfig``.

    Attaching one to a ``DecentralizedLearner`` (directly or via
    ``run_protocol_training(async_net=...)``) rewrites the protocol's
    trigger onto per-learner local clocks with messages in flight: each
    sync exchange flies ``k = ceil(round_trip / round_budget) - 1``
    whole rounds, with the round trip priced from the ``NetworkConfig``
    link classes and the payload size (``payload_bytes``; None = the
    model's own byte size). ``round_budget`` is the simulated seconds
    one scanned round represents — a budget covering the slowest link's
    round trip reproduces the synchronous engine bitwise.

    ``aircomp`` additionally swaps the coordinator's mean/average pair
    for the over-the-air analog-superposition stages: the cohort mean
    arrives through one shared-medium transmission with Gaussian
    receiver noise ``snr_db`` below the aggregate's RMS (draw pure in
    ``(air_seed, t)``).
    """
    round_budget: float = 1.0     # simulated seconds per scanned round
    max_delay: int = 8            # arrival-ring depth (max flight rounds + 1)
    payload_bytes: Optional[int] = None   # None = the engine's model_bytes
    aircomp: bool = False         # swap mean/average -> over-the-air stages
    snr_db: float = 20.0          # receiver SNR below the aggregate's RMS
    air_seed: int = 0             # noise stream seed (pure in (seed, t))

    def __post_init__(self):
        if not self.round_budget > 0:
            raise ValueError(
                f"round_budget must be > 0 simulated seconds, "
                f"got {self.round_budget!r}")
        if self.max_delay < 1:
            raise ValueError(
                f"max_delay must be >= 1 round, got {self.max_delay!r}")
        if self.payload_bytes is not None and self.payload_bytes < 0:
            raise ValueError(
                f"payload_bytes must be >= 0 (or None for the model's "
                f"size), got {self.payload_bytes!r}")


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------

FAULT_BYZANTINE_MODES = ("sign_flip", "scale")


@dataclass(frozen=True)
class FaultConfig:
    """The fault-injection plane (``repro.network.faults``), threaded
    through the engine like ``NetworkConfig``/``AsyncConfig``.

    Attaching one to a ``DecentralizedLearner`` (directly or via
    ``run_protocol_training(faults=...)``) injects faults INSIDE the
    scanned round, every mask a pure function of ``(fault_seed, t)``:

    * **crash/restart episodes** — time is cut into windows of
      ``crash_every`` rounds; in each window a learner crashes with
      probability ``crash_prob`` at a sampled offset for a sampled
      ``outage_min..outage_max``-round outage. While crashed it neither
      trains nor participates (the crash mask composes with the
      availability mask); on restart it rejoins COLD — params, optimizer
      state, and per-learner sync state (staleness counters, arrival
      rings, health) are zeroed, modeling a node that lost local state.
    * **payload corruption** — each round each learner's parameters go
      non-finite with probability ``corrupt_prob`` (NaN on odd rounds,
      Inf on even), the silent poison a plain ``mean`` spreads forever.
    * **Byzantine adversaries** — a fixed ``byzantine_frac`` subset
      (drawn once from ``fault_seed``) replaces its parameters every
      round: ``sign_flip`` negates them, ``scale`` multiplies by
      ``byzantine_scale``.
    * **straggler bursts** — in each ``straggler_every``-round window,
      with probability ``straggler_prob``, a random ``straggler_frac``
      of the fleet goes dark for the window (AND-composed with the
      availability mask like a crash, but without state loss).

    ``faults=None`` leaves the engine bitwise-identical to the
    fault-free path (no fault code is traced at all); a default
    ``FaultConfig()`` has every fault disabled and produces bitwise
    identical results through the traced fault ops. Defenses are
    registered stages (``repro.core.sync.robust``): the
    ``trimmed_mean``/``median`` aggregates, the ``quarantine`` commit,
    the ``robust_periodic``/``robust_dynamic`` presets and the
    ``hardened(spec)`` rewriter.
    """
    fault_seed: int = 0
    crash_prob: float = 0.0       # per-learner per-window crash probability
    crash_every: int = 16         # episode window length (rounds)
    outage_min: int = 1           # shortest outage (rounds)
    outage_max: int = 4           # longest outage (rounds)
    corrupt_prob: float = 0.0     # per-learner per-round NaN/Inf corruption
    byzantine_frac: float = 0.0   # fraction of the fleet that is adversarial
    byzantine_mode: str = "sign_flip"   # sign_flip | scale
    byzantine_scale: float = 10.0       # multiplier for mode="scale"
    straggler_prob: float = 0.0   # per-window burst probability
    straggler_every: int = 8      # burst window length (rounds)
    straggler_frac: float = 0.5   # fraction straggling during a burst

    def __post_init__(self):
        for name in ("crash_prob", "corrupt_prob", "straggler_prob",
                     "straggler_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"{name} is a probability/fraction, must be in "
                    f"[0, 1]: got {v!r}")
        if not 0.0 <= self.byzantine_frac < 1.0:
            raise ValueError(
                f"byzantine_frac must be in [0, 1) — a fully adversarial "
                f"fleet has nothing to defend; got {self.byzantine_frac!r}")
        if self.byzantine_mode not in FAULT_BYZANTINE_MODES:
            raise KeyError(
                f"unknown byzantine_mode {self.byzantine_mode!r}; "
                f"known: {sorted(FAULT_BYZANTINE_MODES)}")
        if self.crash_every < 1:
            raise ValueError(
                f"crash_every must be >= 1 round, got {self.crash_every!r}")
        if self.straggler_every < 1:
            raise ValueError(
                f"straggler_every must be >= 1 round, "
                f"got {self.straggler_every!r}")
        if not 1 <= self.outage_min <= self.outage_max:
            raise ValueError(
                f"need 1 <= outage_min <= outage_max, got "
                f"outage_min={self.outage_min!r}, "
                f"outage_max={self.outage_max!r}")
        if self.outage_max > self.crash_every:
            raise ValueError(
                f"outage_max ({self.outage_max}) must not exceed "
                f"crash_every ({self.crash_every}) — a crash outliving its "
                f"episode window is a permanent loss, not a restart")


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TelemetryConfig:
    """The fleet telemetry plane (``repro.telemetry``).

    Attaching one to a ``DecentralizedLearner`` (directly or via
    ``run_protocol_training(telemetry=...)`` /
    ``benchmarks/run.py --telemetry``) streams a schema'd round record
    per executed round — loss, divergence, trigger accounting, cohort
    size, reachability, simulated net-time, exact cumulative bytes — to
    ``path`` as JSONL, with the newest ``ring`` records also held in
    memory. Records are materialized host-side from the per-chunk fold
    the engine already fetches: zero extra device work, zero extra
    transfers. No config (``telemetry=None``) leaves the engine
    bitwise-identical to the untelemetered path.

    ``per_link`` adds the per-link byte vector to every round record
    (L integers per round — sizeable for large fleets, hence opt-in).
    ``profile`` adds wall-clock + recompile accounting per chunk
    (``perf_counter`` around a blocked dispatch). ``jax_profiler`` wraps
    each chunk in a ``jax.profiler`` step annotation so chunks show up
    named in a profiler trace (no-op unless a trace is active).
    """
    path: Optional[str] = None    # JSONL sink; None = ring buffer only
    append: bool = False          # append to path (checkpoint resume)
    ring: int = 1024              # in-memory ring capacity (records)
    per_link: bool = False        # per-link bytes on every round record
    profile: bool = False         # wall-clock + recompile spans per chunk
    jax_profiler: bool = False    # jax.profiler step annotations

    def __post_init__(self):
        if self.ring < 1:
            raise ValueError(
                f"ring must hold >= 1 record, got {self.ring!r}")


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"                 # sgd | momentum | adam | rmsprop
    learning_rate: float = 0.1
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    micro_batch: int = 0                   # 0 -> no microbatching
    remat: bool = True                     # activation checkpointing per layer
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD_MESH
    protocol: ProtocolConfig = ProtocolConfig()
    train: TrainConfig = TrainConfig()
    num_learners: int = 1                  # m; learner axis for dynamic mode
    network: Optional[NetworkConfig] = None  # None = ideal always-on star

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_REGISTRY: dict = {}


def register_arch(name: str, full_fn, smoke_fn) -> None:
    _ARCH_REGISTRY[name] = (full_fn, smoke_fn)


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    if name not in _ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_ARCH_REGISTRY)}")
    full_fn, smoke_fn = _ARCH_REGISTRY[name]
    return smoke_fn() if smoke else full_fn()


def list_archs() -> Sequence[str]:
    import repro.configs  # noqa: F401
    return sorted(_ARCH_REGISTRY)
