from repro.optim.optimizers import (  # noqa: F401
    make_optimizer, sgd, momentum, adam, rmsprop, OptState,
)
