"""Optimizers (pure JAX, optax-like minimal API).

The paper treats the learning algorithm phi as a black box; it evaluates
mini-batch SGD (its main setting, Dekel et al.'s phi^mSGD), ADAM and RMSprop
(Appendix A.5). All three are provided with one interface:

    opt = make_optimizer(train_cfg)
    state = opt.init(params)
    params, state = opt.update(params, grads, state)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]
    name: str


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any = None       # first moment / momentum
    nu: Any = None       # second moment


def _zeros_like_tree(params):
    return jax.tree.map(jnp.zeros_like, params)


def _apply_wd(grads, params, wd: float):
    if wd == 0.0:
        return grads
    return jax.tree.map(lambda g, p: g + wd * p, grads, params)


def sgd(lr: float, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32))

    def update(params, grads, state):
        grads = _apply_wd(grads, params, weight_decay)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new, OptState(step=state.step + 1)

    return Optimizer(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), mu=_zeros_like_tree(params))

    def update(params, grads, state):
        grads = _apply_wd(grads, params, weight_decay)
        mu = jax.tree.map(lambda m, g: beta * m + g, state.mu, grads)
        new = jax.tree.map(lambda p, m: p - lr * m, params, mu)
        return new, OptState(step=state.step + 1, mu=mu)

    return Optimizer(init, update, "momentum")


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_zeros_like_tree(params), nu=_zeros_like_tree(params))

    def update(params, grads, state):
        grads = _apply_wd(grads, params, weight_decay)
        t = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state.nu, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
            params, mu, nu)
        return new, OptState(step=t, mu=mu, nu=nu)

    return Optimizer(init, update, "adam")


def rmsprop(lr: float, decay: float = 0.9, eps: float = 1e-8,
            weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), nu=_zeros_like_tree(params))

    def update(params, grads, state):
        grads = _apply_wd(grads, params, weight_decay)
        nu = jax.tree.map(lambda v, g: decay * v + (1 - decay) * jnp.square(g),
                          state.nu, grads)
        new = jax.tree.map(lambda p, g, v: p - lr * g / (jnp.sqrt(v) + eps),
                           params, grads, nu)
        return new, OptState(step=state.step + 1, nu=nu)

    return Optimizer(init, update, "rmsprop")


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "sgd":
        return sgd(cfg.learning_rate, cfg.weight_decay)
    if cfg.optimizer == "momentum":
        return momentum(cfg.learning_rate, cfg.momentum, cfg.weight_decay)
    if cfg.optimizer == "adam":
        return adam(cfg.learning_rate, cfg.beta1, cfg.beta2, cfg.eps,
                    cfg.weight_decay)
    if cfg.optimizer == "rmsprop":
        return rmsprop(cfg.learning_rate, cfg.momentum, cfg.eps,
                       cfg.weight_decay)
    raise ValueError(cfg.optimizer)
