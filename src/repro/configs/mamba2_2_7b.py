"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD, state-space duality [arXiv:2405.21060]."""
from repro.config import ModelConfig, SSMConfig, register_arch, BLOCK_SSM


def full():
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280, block_type=BLOCK_SSM,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk_size=64, ngroups=1),
        dtype="bfloat16", source="arXiv:2405.21060",
    )


def smoke():
    return ModelConfig(
        name="mamba2-2.7b-smoke", family="ssm",
        num_layers=2, d_model=256, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512, block_type=BLOCK_SSM,
        ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=64,
                      chunk_size=16, ngroups=1),
        source="arXiv:2405.21060",
    )


register_arch("mamba2-2.7b", full, smoke)
