"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-0.5B scaled per assignment]."""
from repro.config import ModelConfig, register_arch


def full():
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=49152, vocab_size=152064, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0, dtype="bfloat16",
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke():
    return ModelConfig(
        name="qwen1.5-110b-smoke", family="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32, qkv_bias=True,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


register_arch("qwen1.5-110b", full, smoke)
