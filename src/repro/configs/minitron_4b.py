"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron [arXiv:2407.14679]."""
from repro.config import ModelConfig, register_arch


def full():
    return ModelConfig(
        name="minitron-4b", family="dense",
        num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=9216, vocab_size=256000, head_dim=128,
        dtype="bfloat16", source="arXiv:2407.14679",
    )


def smoke():
    return ModelConfig(
        name="minitron-4b-smoke", family="dense",
        num_layers=2, d_model=192, num_heads=6, num_kv_heads=2,
        d_ff=384, vocab_size=512, head_dim=32,
        source="arXiv:2407.14679",
    )


register_arch("minitron-4b", full, smoke)
