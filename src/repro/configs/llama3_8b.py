"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.config import ModelConfig, register_arch


def full():
    return ModelConfig(
        name="llama3-8b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=128256, head_dim=128,
        rope_theta=500_000.0, dtype="bfloat16",
        source="arXiv:2407.21783",
    )


def smoke():
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        rope_theta=500_000.0,
        source="arXiv:2407.21783",
    )


register_arch("llama3-8b", full, smoke)
