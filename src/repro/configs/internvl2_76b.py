"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + Llama3-70B-style language backbone
[arXiv:2404.16821].

Backbone only: the InternViT-6B vision tower + MLP projector is the allowed
stub; ``input_specs`` provides projected patch embeddings (B, S_img,
d_model) which are prepended to the text-token embeddings."""
from repro.config import ModelConfig, register_arch, MODALITY_VISION

NUM_PATCHES = 256   # stub vision prefix length per sample


def full():
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256, head_dim=128, modality=MODALITY_VISION,
        rope_theta=500_000.0, dtype="bfloat16",
        source="arXiv:2404.16821",
    )


def smoke():
    return ModelConfig(
        name="internvl2-76b-smoke", family="vlm",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32, modality=MODALITY_VISION,
        source="arXiv:2404.16821",
    )


register_arch("internvl2-76b", full, smoke)
