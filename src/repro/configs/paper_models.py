"""The paper's own model configurations (Appendix A).

* ``mnist_cnn``      — Table 1: Conv32-Conv64-MaxPool-Dense128-Dense10
                       (1,199,882 weights; we assert this in tests).
* ``deepdrive_cnn``  — Table 5 (PilotNet, Bojarski et al.): 348,219 weights.
* ``drift_mlp``      — MLP for the d=50 random-graphical-model drift data.
"""
from repro.config import ModelConfig, register_arch


def mnist_cnn():
    return ModelConfig(
        name="mnist_cnn", family="cnn",
        num_layers=0, d_model=0,
        cnn_spec=(
            ("conv", 32, 3, 1),
            ("conv", 64, 3, 1),
            ("pool", 2),
            ("dropout", 0.25),
            ("flatten",),
            ("dense", 128),
            ("dropout", 0.5),
            ("dense", 10),
        ),
        input_shape=(28, 28, 1), num_outputs=10,
        source="Kamp et al. 2018, Table 1",
    )


def mnist_cnn_smoke():
    return ModelConfig(
        name="mnist_cnn_smoke", family="cnn",
        num_layers=0, d_model=0,
        cnn_spec=(
            ("conv", 4, 3, 1),
            ("pool", 2),
            ("flatten",),
            ("dense", 16),
            ("dense", 10),
        ),
        input_shape=(14, 14, 1), num_outputs=10,
        source="Kamp et al. 2018, Table 1 (reduced)",
    )


def deepdrive_cnn():
    return ModelConfig(
        name="deepdrive_cnn", family="cnn",
        num_layers=0, d_model=0,
        cnn_spec=(
            ("conv", 24, 5, 2),
            ("conv", 36, 5, 2),
            ("conv", 48, 5, 2),
            ("conv", 64, 3, 1),
            ("conv", 64, 3, 1),
            ("flatten",),
            ("dense", 100),
            ("dense", 50),
            ("dense", 10),
            ("dense", 1),
        ),
        input_shape=(68, 320, 3), num_outputs=1,   # (68,320) reproduces Table 5 shapes: conv1 out (32,158), flatten 2112
        source="Kamp et al. 2018, Table 5 / Bojarski et al. 2016",
    )


def deepdrive_cnn_smoke():
    return ModelConfig(
        name="deepdrive_cnn_smoke", family="cnn",
        num_layers=0, d_model=0,
        cnn_spec=(
            ("conv", 8, 5, 2),
            ("conv", 8, 3, 1),
            ("flatten",),
            ("dense", 16),
            ("dense", 1),
        ),
        input_shape=(20, 40, 3), num_outputs=1,
        source="Kamp et al. 2018, Table 5 (reduced)",
    )


def drift_mlp():
    return ModelConfig(
        name="drift_mlp", family="cnn",
        num_layers=0, d_model=0,
        cnn_spec=(
            ("flatten",),
            ("dense", 64),
            ("dense", 32),
            ("dense", 2),
        ),
        input_shape=(50,), num_outputs=2,
        source="Kamp et al. 2018, App. A.3 (Bshouty & Long data)",
    )


register_arch("mnist_cnn", mnist_cnn, mnist_cnn_smoke)
register_arch("deepdrive_cnn", deepdrive_cnn, deepdrive_cnn_smoke)
register_arch("drift_mlp", drift_mlp, drift_mlp)
