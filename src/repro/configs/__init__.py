"""Architecture registry: importing this package registers all configs."""
from repro.configs import (  # noqa: F401
    internvl2_76b,
    minitron_4b,
    musicgen_large,
    mixtral_8x22b,
    qwen1_5_110b,
    mamba2_2_7b,
    llama3_405b,
    llama3_8b,
    llama3_8b_swa,
    hymba_1_5b,
    deepseek_v2_236b,
    paper_models,
)

ASSIGNED_ARCHS = (
    "internvl2-76b",
    "minitron-4b",
    "musicgen-large",
    "mixtral-8x22b",
    "qwen1.5-110b",
    "mamba2-2.7b",
    "llama3-405b",
    "llama3-8b",
    "hymba-1.5b",
    "deepseek-v2-236b",
)
