"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536(per expert)
vocab=102400, MLA kv_lora=512, MoE 2 shared + 160 routed top-6
[arXiv:2405.04434]."""
from repro.config import ModelConfig, MoEConfig, MLAConfig, register_arch


def full():
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=1536, vocab_size=102400, head_dim=128,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64),
        moe=MoEConfig(num_experts=160, num_experts_per_tok=6,
                      num_shared_experts=2, expert_d_ff=1536),
        dtype="bfloat16", source="arXiv:2405.04434",
    )


def smoke():
    return ModelConfig(
        name="deepseek-v2-236b-smoke", family="moe",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        d_ff=128, vocab_size=512, head_dim=32,
        mla=MLAConfig(kv_lora_rank=64, q_lora_rank=48, rope_head_dim=16),
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2,
                      num_shared_experts=1, expert_d_ff=128,
                      capacity_factor=8.0),
        source="arXiv:2405.04434",
    )


register_arch("deepseek-v2-236b", full, smoke)
