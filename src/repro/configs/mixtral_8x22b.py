"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.config import ModelConfig, MoEConfig, register_arch, ATTN_SLIDING


def full():
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        attn_type=ATTN_SLIDING, sliding_window=4096,
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2),
        rope_theta=1_000_000.0, dtype="bfloat16",
        source="arXiv:2401.04088",
    )


def smoke():
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        attn_type=ATTN_SLIDING, sliding_window=16,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2,
                      capacity_factor=8.0),
        source="arXiv:2401.04088",
    )


register_arch("mixtral-8x22b", full, smoke)
