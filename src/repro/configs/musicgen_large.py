"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone only: the EnCodec tokenizer / mel frontend is the allowed stub;
``input_specs`` provides 4 parallel codebook token streams (delay-pattern
interleaving is a data-layout concern outside the backbone)."""
from repro.config import ModelConfig, register_arch, MODALITY_AUDIO


def full():
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, head_dim=64, modality=MODALITY_AUDIO,
        dtype="bfloat16", source="arXiv:2306.05284",
    )


def smoke():
    return ModelConfig(
        name="musicgen-large-smoke", family="audio",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        d_ff=512, vocab_size=256, head_dim=32, modality=MODALITY_AUDIO,
        source="arXiv:2306.05284",
    )


register_arch("musicgen-large", full, smoke)
