"""llama3-8b-swa: the llama3-8b backbone with sliding-window attention
(window 8192) — the variant that makes ``long_500k`` decode tractable for a
dense architecture (bounded ring-buffer KV cache), per the assignment's
carve-out: dense archs run the 524k shape only with a sliding-window or
block-sparse variant. [arXiv:2407.21783 + Mistral-style SWA]"""
import dataclasses

from repro.config import ATTN_SLIDING, register_arch
from repro.configs import llama3_8b


def full():
    return dataclasses.replace(
        llama3_8b.full(), name="llama3-8b-swa",
        attn_type=ATTN_SLIDING, sliding_window=8192)


def smoke():
    return dataclasses.replace(
        llama3_8b.smoke(), name="llama3-8b-swa-smoke",
        attn_type=ATTN_SLIDING, sliding_window=16)


register_arch("llama3-8b-swa", full, smoke)
