"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads
[arXiv:2411.13676]. Sliding-window attention in the attention branch (the
Hymba recipe uses SWA in all but 3 layers); the SSM branch gives global
context, so long_500k decode is bounded-state."""
from repro.config import (
    ModelConfig, SSMConfig, register_arch, BLOCK_HYBRID, ATTN_SLIDING,
)


def full():
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        block_type=BLOCK_HYBRID, attn_type=ATTN_SLIDING, sliding_window=1024,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      chunk_size=64, ngroups=1),
        dtype="bfloat16", source="arXiv:2411.13676",
    )


def smoke():
    return ModelConfig(
        name="hymba-1.5b-smoke", family="hybrid",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=64,
        block_type=BLOCK_HYBRID, attn_type=ATTN_SLIDING, sliding_window=16,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      chunk_size=16, ngroups=1),
        source="arXiv:2411.13676",
    )


register_arch("hymba-1.5b", full, smoke)
