"""Decoder-only LM: embeddings -> scan over stacked blocks -> head.

Handles the three modalities of the assigned pool:
  * text  — tokens (B, S) int32
  * vision (VLM backbone) — stub patch embeddings (B, S_img, D) concatenated
    in front of text-token embeddings (the ViT + projector is the allowed
    frontend stub); loss is masked to text positions
  * audio (MusicGen backbone) — K codebook token streams (B, S, K); the
    embedding is the sum over codebooks, the head predicts K vocabularies

All layers are stacked on a leading L axis and executed with ``lax.scan``
(optionally rematerialized), keeping HLO size independent of depth.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MODALITY_AUDIO, MODALITY_VISION
from repro.models import blocks as blk
from repro.models.layers import embed_init, dense_init, rmsnorm_init, rmsnorm_apply
from repro.pjit_utils import constrain, gather_weight

AUDIO_CODEBOOKS = 4


def init_lm_params(cfg: ModelConfig, key, dtype=jnp.float32):
    k_e, k_b, k_h = jax.random.split(key, 3)
    if cfg.modality == MODALITY_AUDIO:
        embed = jax.vmap(lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dtype))(
            jax.random.split(k_e, AUDIO_CODEBOOKS))          # (K, V, D)
    else:
        embed = embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype)
    block_keys = jax.random.split(k_b, cfg.num_layers)
    stacked = jax.vmap(lambda k: blk.block_init(cfg, k, dtype))(block_keys)
    p = {
        "embed": embed,
        "blocks": stacked,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        out_dim = (cfg.vocab_size * AUDIO_CODEBOOKS
                   if cfg.modality == MODALITY_AUDIO else cfg.vocab_size)
        p["lm_head"] = dense_init(k_h, cfg.d_model, out_dim, dtype)
    return p


def _embed_tokens(cfg: ModelConfig, params, tokens):
    if cfg.modality == MODALITY_AUDIO:
        # tokens: (B, S, K); params["embed"]: (K, V, D); sum over codebooks
        e = 0.0
        for k in range(AUDIO_CODEBOOKS):
            e = e + jnp.take(params["embed"][k], tokens[..., k], axis=0)
        return e
    return jnp.take(params["embed"], tokens, axis=0)


def _head(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings:
        emb = constrain(params["embed"], ("vocab", None))
        return jnp.einsum("bsd,vd->bsv", h, emb)
    # JIT weight-gather: unshard d_model, keep vocab tensor-parallel
    lm_head = gather_weight(params["lm_head"], (None, "vocab"))
    logits = jnp.einsum("bsd,dv->bsv", h, lm_head)
    if cfg.modality == MODALITY_AUDIO:
        B, S, _ = logits.shape
        return logits.reshape(B, S, AUDIO_CODEBOOKS, cfg.vocab_size)
    return logits


def _scan_blocks(cfg: ModelConfig, stacked, x, positions, remat: bool = False):
    def body(carry, layer_params):
        y, aux = blk.block_forward(cfg, layer_params, carry, positions)
        return y, aux

    if remat:
        body = jax.checkpoint(body)
    if not cfg.scan_layers:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[i], stacked)
            x, a = body(x, layer)
            aux = aux + a
        return x, aux
    x, auxs = jax.lax.scan(body, x, stacked)
    return x, jnp.sum(auxs)


def lm_apply(cfg: ModelConfig, params, tokens=None, *, prefix_embeds=None,
             positions=None, remat: bool = False):
    """Forward pass -> (logits, aux_loss).

    ``prefix_embeds`` (B, S_img, D): VLM stub patch embeddings prepended to
    the token embeddings. Returned logits cover the full (prefix + text)
    sequence; callers mask the prefix for the loss.
    """
    if tokens is not None:
        x = _embed_tokens(cfg, params, tokens)
    else:
        x = prefix_embeds
        prefix_embeds = None
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    x = constrain(x, ("batch", None, None))
    x, aux = _scan_blocks(cfg, params["blocks"], x, positions, remat)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = _head(cfg, params, x)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, aux


def _xent(logits, labels):
    """Cross-entropy that stays sharded over a tensor-parallel vocab dim:
    lse(logits) - logit[label], with the label pick as a masked reduction
    (partial-reducible per vocab shard — no (B,S,V) cross-shard gather)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    return lse - picked


def lm_loss(cfg: ModelConfig, params, batch, remat: bool = False):
    """Next-token cross-entropy; batch dict with ``tokens``/``labels`` and
    optional ``prefix_embeds``/``loss_mask``. Returns scalar mean loss."""
    logits, aux = lm_apply(
        cfg, params, batch.get("tokens"),
        prefix_embeds=batch.get("prefix_embeds"), remat=remat)
    labels = batch["labels"]
    if cfg.modality == MODALITY_AUDIO:
        # labels: (B,S,K); logits: (B,S,K,V)
        nll = jnp.mean(_xent(logits, labels))
    else:
        if batch.get("prefix_embeds") is not None:
            npfx = batch["prefix_embeds"].shape[1]
            logits = logits[:, npfx:, :]
        nll = _xent(logits, labels)
        mask = batch.get("loss_mask")
        if mask is not None:
            nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            nll = jnp.mean(nll)
    return nll + aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32):
    """Stacked (L-leading) cache pytree."""
    one = blk.block_cache_init(cfg, batch, max_seq, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape).copy(), one)


def lm_decode_step(cfg: ModelConfig, params, token, cache, pos):
    """One decode step. token: (B,) int32 (or (B,K) audio). Returns
    (logits (B, V[, K]), new_cache)."""
    if cfg.modality == MODALITY_AUDIO:
        x = _embed_tokens(cfg, params, token[:, None, :])
    else:
        x = _embed_tokens(cfg, params, token[:, None])

    def body(carry, xs):
        layer_params, layer_cache = xs
        y, new_cache = blk.block_decode(cfg, layer_params, carry, layer_cache, pos)
        return y, new_cache

    if not cfg.scan_layers:
        new_caches = []
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[i], params["blocks"])
            lcache = jax.tree.map(lambda a: a[i], cache)
            x, nc = body(x, (layer, lcache))
            new_caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = _head(cfg, params, x)
        return logits[:, 0], new_cache
    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = _head(cfg, params, x)
    return logits[:, 0], new_cache
