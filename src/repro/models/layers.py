"""Shared neural-net primitives (pure JAX, functional, no flax).

Parameters are plain pytrees (nested dicts of jnp arrays). Initializers take
an explicit PRNG key. All ``*_apply`` functions are pure.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.pjit_utils import constrain, gather_weight


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    """Glorot/Xavier-uniform init (the paper uses Glorot, ref. [41])."""
    lim = scale * math.sqrt(6.0 / (d_in + d_out))
    return jax.random.uniform(key, (d_in, d_out), dtype, -lim, lim)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, d). positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def swiglu_apply(params, x):
    # JIT weight-gather (FSDP): unshard the contraction dim of each weight
    # right before use — gathering the (small) weight instead of letting the
    # partitioner all-gather the (huge) batch activations.
    w_gate = gather_weight(params["w_gate"], (None, "tp"))
    w_up = gather_weight(params["w_up"], (None, "tp"))
    w_down = gather_weight(params["w_down"], ("tp", None))
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    h = constrain(h, ("batch", None, "ffn"))
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp_init(key, dims, dtype=jnp.float32, bias=True):
    """Plain MLP: dims = (d0, d1, ..., dn)."""
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
        w = dense_init(k, a, b, dtype)
        layers.append({"w": w, "b": jnp.zeros((b,), dtype)} if bias else {"w": w})
    return {"layers": layers}


def mlp_apply(params, x, activation=jax.nn.relu, final_activation=None):
    layers = params["layers"]
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"]
        if "b" in lyr:
            x = x + lyr["b"]
        if i < len(layers) - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


# ---------------------------------------------------------------------------
# causal conv1d (Mamba) helpers
# ---------------------------------------------------------------------------

def causal_conv1d(x, w):
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # frame into windows: out[:, t] = sum_k xp[:, t+k] * w[k]
    def body(k, acc):
        return acc + xp[:, k:k + x.shape[1], :] * w[k]
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4); unrolled python loop keeps HLO simple
        out = out + xp[:, k:k + x.shape[1], :] * w[k]
    return out


def causal_conv1d_update(conv_state, x_t, w):
    """One decode step. conv_state: (B, K-1, C), x_t: (B, C) -> (y_t, new_state)."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    return y, window[:, 1:, :]
