"""Attention variants: GQA (opt. QKV bias), sliding-window, and MLA.

Supports three execution modes:
  * ``forward``  — full-sequence causal attention (training / prefill)
  * ``decode``   — single new token against a KV cache
MLA (DeepSeek-V2) caches the compressed latent + shared rope key and uses
the absorbed formulation for decode (scores against the latent directly),
which is what makes its KV cache ~9x smaller.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ATTN_SLIDING
from repro.models.layers import dense_init, apply_rope
from repro.pjit_utils import constrain, gather_weight

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key, dtype=jnp.float32, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 6)
        p = {
            "w_dkv": dense_init(ks[0], d, m.kv_lora_rank, dtype),
            "w_krope": dense_init(ks[1], d, m.rope_head_dim, dtype),
            "w_uk": dense_init(ks[2], m.kv_lora_rank, H * hd, dtype),
            "w_uv": dense_init(ks[3], m.kv_lora_rank, H * hd, dtype),
            "w_o": dense_init(ks[5], H * hd, d, dtype),
        }
        if m.q_lora_rank:
            kq = jax.random.split(ks[4])
            p["w_dq"] = dense_init(kq[0], d, m.q_lora_rank, dtype)
            p["w_uq"] = dense_init(kq[1], m.q_lora_rank, H * (hd + m.rope_head_dim), dtype)
        else:
            p["w_q"] = dense_init(ks[4], d, H * (hd + m.rope_head_dim), dtype)
        return p
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], d, H * hd, dtype),
        "w_k": dense_init(ks[1], d, Hkv * hd, dtype),
        "w_v": dense_init(ks[2], d, Hkv * hd, dtype),
        "w_o": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((H * hd,), dtype)
        p["b_k"] = jnp.zeros((Hkv * hd,), dtype)
        p["b_v"] = jnp.zeros((Hkv * hd,), dtype)
    return p


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------

def causal_mask(q_pos, k_pos, window: int = 0):
    """(..., Sq, Sk) boolean mask. window>0 -> sliding window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m = m & (k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m


def _sdpa(q, k, v, mask, scale):
    """q: (B,S,Hkv,G,d) k,v: (B,T,Hkv,d). mask: (B,S,T) or (S,T)."""
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out


# ---------------------------------------------------------------------------
# GQA forward / decode
# ---------------------------------------------------------------------------

def _project_qkv(cfg, params, x):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    # JIT weight-gather (FSDP): unshard d_in before the matmul
    w_q = gather_weight(params["w_q"], (None, "tp"))
    w_k = gather_weight(params["w_k"], (None, "tp"))
    w_v = gather_weight(params["w_v"], (None, "tp"))
    q = jnp.einsum("bsd,de->bse", x, w_q)
    k = jnp.einsum("bsd,de->bse", x, w_k)
    v = jnp.einsum("bsd,de->bse", x, w_v)
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, Hkv, hd), v.reshape(B, S, Hkv, hd))


def _banded_sdpa(q, k, v, window: int, scale):
    """Block-banded sliding-window attention (TPU-native SWA blocking).

    q: (B,S,Hkv,G,d), k/v: (B,S,Hkv,d), S % window == 0. Each query block of
    ``window`` tokens attends only to its own and the previous key block
    (which together cover every in-window key), so scores are
    (B, H, nb, w, 2w) instead of (B, H, S, S): compute and intermediate
    memory drop by a factor S / (2 * window).
    """
    B, S, Hkv, G, d = q.shape
    w = window
    nb = S // w
    qb = q.reshape(B, nb, w, Hkv, G, d)
    kb = k.reshape(B, nb, w, Hkv, d)
    vb = v.reshape(B, nb, w, Hkv, d)
    zeros = jnp.zeros_like(kb[:, :1])
    k_prev = jnp.concatenate([zeros, kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)          # (B,nb,2w,Hkv,d)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    scores = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb, k2).astype(
        jnp.float32) * scale                            # (B,nb,Hkv,G,w,2w)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (w, 2 * w), 0)        # in-block
    kpos = jax.lax.broadcasted_iota(jnp.int32, (w, 2 * w), 1) - w
    first = (jnp.arange(nb) == 0)[:, None, None]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    mask = mask[None] & ~(first & (kpos[None] < 0))     # block 0 has no prev
    # mask: (nb, w, 2w) -> broadcast over (B, nb, Hkv, G, w, 2w)
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", probs, v2)
    return out.reshape(B, S, Hkv, G, d)


def gqa_forward(cfg: ModelConfig, params, x, positions):
    """Full-sequence causal attention. x: (B,S,D), positions: (B,S) or (S,)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    G = H // Hkv
    q, k, v = _project_qkv(cfg, params, x)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, S))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    window = cfg.sliding_window if cfg.attn_type == ATTN_SLIDING else 0
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # §Perf: block-banded path for long sliding-window prefill — avoids the
    # full (S, S) score materialization when the window covers < half of S.
    # Requires the default contiguous positions (0..S-1).
    if (window > 0 and S % window == 0 and S >= 2 * window
            and positions.shape[-1] == S):
        out = _banded_sdpa(q.reshape(B, S, Hkv, G, hd), k, v, window, scale)
    else:
        mask = causal_mask(positions, positions, window)
        out = _sdpa(q.reshape(B, S, Hkv, G, hd), k, v, mask, scale)
    out = out.reshape(B, S, H * hd)
    w_o = gather_weight(params["w_o"], ("tp", None))
    return jnp.einsum("bse,ed->bsd", out, w_o)


def gqa_decode(cfg: ModelConfig, params, x, cache, pos):
    """One-token decode. x: (B,1,D); cache: {"k","v"}: (B, Smax, Hkv, hd),
    plus {"pos": (Smax,) int32} ring-buffer position tags for sliding window;
    pos: scalar int32 — number of tokens already in the cache.

    Sliding-window archs use a ring buffer of size ``window`` (rope applied
    at write time with absolute positions), so a 524k-token decode carries a
    bounded cache.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    G = H // Hkv
    q, k_new, v_new = _project_qkv(cfg, params, x)
    posb = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)
    Smax = cache["k"].shape[1]
    sliding = cfg.attn_type == ATTN_SLIDING
    slot = jnp.asarray(pos) % Smax if sliding else jnp.asarray(pos)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    if sliding:
        tags = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.asarray(pos, jnp.int32)[None], (slot,))
        valid = (tags >= 0) & (tags <= pos) & (tags > pos - cfg.sliding_window)
        valid = valid[None, :]
        new_cache = {"k": k, "v": v, "pos": tags}
    else:
        k_pos = jnp.arange(Smax, dtype=jnp.int32)
        valid = k_pos[None, :] <= pos
        new_cache = {"k": k, "v": v}
    mask = jnp.broadcast_to(valid[:, None, :], (B, 1, Smax))
    out = _sdpa(q.reshape(B, 1, Hkv, G, hd), k, v, mask, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    out = out.reshape(B, 1, H * hd)
    w_o = gather_weight(params["w_o"], ("tp", None))
    y = jnp.einsum("bse,ed->bsd", out, w_o)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA forward / decode
# ---------------------------------------------------------------------------

def _mla_queries(cfg, params, x, positions):
    B, S, _ = x.shape
    m = cfg.mla
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    if m.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, gather_weight(params["w_dq"], (None, "tp")))
        q = jnp.einsum("bsr,re->bse", q, gather_weight(params["w_uq"], (None, "tp")))
    else:
        q = jnp.einsum("bsd,de->bse", x, gather_weight(params["w_q"], (None, "tp")))
    q = q.reshape(B, S, H, hd + m.rope_head_dim)
    q_c, q_r = q[..., :hd], q[..., hd:]
    q_r = apply_rope(q_r, positions, cfg.rope_theta)
    return q_c, q_r


def mla_forward(cfg: ModelConfig, params, x, positions):
    B, S, _ = x.shape
    m = cfg.mla
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, S))
    q_c, q_r = _mla_queries(cfg, params, x, positions)
    c_kv = jnp.einsum("bsd,dr->bsr", x, gather_weight(params["w_dkv"], (None, "tp")))
    k_r = jnp.einsum("bsd,dr->bsr", x, gather_weight(params["w_krope"], (None, None)))[:, :, None, :]
    k_r = apply_rope(k_r, positions, cfg.rope_theta)              # (B,S,1,rh)
    k_c = jnp.einsum("bsr,re->bse", c_kv, gather_weight(params["w_uk"], (None, "tp"))).reshape(B, S, H, hd)
    v = jnp.einsum("bsr,re->bse", c_kv, gather_weight(params["w_uv"], (None, "tp"))).reshape(B, S, H, hd)
    scale = 1.0 / jnp.sqrt(hd + m.rope_head_dim).astype(jnp.float32)
    scores = (jnp.einsum("bshd,bthd->bhst", q_c, k_c)
              + jnp.einsum("bshd,btgd->bhst", q_r, jnp.broadcast_to(k_r, (B, S, 1, m.rope_head_dim))))
    scores = scores.astype(jnp.float32) * scale
    mask = causal_mask(positions, positions)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, H * hd)
    return jnp.einsum("bse,ed->bsd", out, gather_weight(params["w_o"], ("tp", None)))


def mla_decode(cfg: ModelConfig, params, x, cache, pos):
    """Absorbed MLA decode. cache: {"ckv": (B,Smax,r), "krope": (B,Smax,rh)}."""
    B = x.shape[0]
    m = cfg.mla
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    posb = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_c, q_r = _mla_queries(cfg, params, x, posb)                 # (B,1,H,·)
    c_new = jnp.einsum("bsd,dr->bsr", x, gather_weight(params["w_dkv"], (None, "tp")))
    kr_new = jnp.einsum("bsd,dr->bsr", x, gather_weight(params["w_krope"], (None, None)))[:, :, None, :]
    kr_new = apply_rope(kr_new, posb, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_new.astype(cache["ckv"].dtype), (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], kr_new.astype(cache["krope"].dtype), (0, pos, 0))
    # absorb W_uk into the query: q_abs (B,H,r)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, hd)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_c, w_uk)[:, 0]          # (B,H,r)
    scale = 1.0 / jnp.sqrt(hd + m.rope_head_dim).astype(jnp.float32)
    scores = (jnp.einsum("bhr,btr->bht", q_abs, ckv)
              + jnp.einsum("bshr,btr->bht", q_r, krope))           # q_r: (B,1,H,rh)
    scores = scores.astype(jnp.float32) * scale
    Smax = ckv.shape[1]
    valid = jnp.arange(Smax, dtype=jnp.int32)[None, :] <= pos
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    ctx = jnp.einsum("bht,btr->bhr", probs, ckv)                   # latent context
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, hd)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(B, 1, H * hd)
    y = jnp.einsum("bse,ed->bsd", out, gather_weight(params["w_o"], ("tp", None)))
    return y, {"ckv": ckv, "krope": krope}


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def attn_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_seq, m.rope_head_dim), dtype),
        }
    if cfg.attn_type == ATTN_SLIDING:
        max_seq = min(max_seq, cfg.sliding_window)
        return {
            "k": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
            "pos": jnp.full((max_seq,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_seq, cfg.num_kv_heads, hd), dtype),
    }
