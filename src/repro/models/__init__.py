from repro.models.model import init_lm_params, lm_apply, lm_loss, init_lm_cache  # noqa: F401
from repro.models.cnn import init_cnn_params, cnn_apply  # noqa: F401
