"""Mamba2 block via SSD (state-space duality), Dao & Gu 2024 [arXiv:2405.21060].

Forward uses the chunked SSD algorithm: within each chunk a quadratic
attention-like term (MXU friendly), across chunks a linear recurrence on the
(H, P, N) state carried by ``lax.scan``. Decode is the classic selective
state-space recurrence with O(1) state — this is what makes ``long_500k``
tractable for SSM/hybrid architectures.

Shapes: x (B,S,D); d_inner = expand*D; H = d_inner/head_dim heads of dim P;
B/C projections have G groups of state size N broadcast over heads.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import (
    dense_init, rmsnorm_init, rmsnorm_apply, causal_conv1d, causal_conv1d_update,
)
from repro.pjit_utils import constrain, gather_weight


def mamba_dims(cfg: ModelConfig, d_model=None):
    s = cfg.ssm
    d = d_model or cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    return d, d_inner, H, s.head_dim, s.ngroups, s.d_state


def mamba_init(cfg: ModelConfig, key, dtype=jnp.float32, d_model=None):
    s = cfg.ssm
    d, d_inner, H, P, G, N = mamba_dims(cfg, d_model)
    conv_ch = d_inner + 2 * G * N
    ks = jax.random.split(key, 6)
    return {
        "w_xz": dense_init(ks[0], d, 2 * d_inner, dtype),
        "w_bc": dense_init(ks[1], d, 2 * G * N, dtype),
        "w_dt": dense_init(ks[2], d, H, dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "conv_w": jax.random.normal(ks[3], (s.d_conv, conv_ch), dtype) * 0.1,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "w_out": dense_init(ks[4], d_inner, d, dtype),
    }


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k] (lower-tri)."""
    S = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((S, S), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(xh, dt, A, B_, C_, chunk: int):
    """Chunked SSD.

    xh: (B,S,H,P) gated input; dt: (B,S,H) positive step sizes;
    A: (H,) negative decay rates; B_/C_: (B,S,G,N).
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    Bb, S, H, P = xh.shape
    G = B_.shape[2]
    N = B_.shape[3]
    if S % chunk != 0:
        raise ValueError(
            f"sequence length must be a chunk multiple: S={S}, "
            f"chunk={chunk}")
    nc = S // chunk
    rep = H // G

    # reshape into chunks
    xc = xh.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = B_.reshape(Bb, nc, chunk, G, N)
    Cc = C_.reshape(Bb, nc, chunk, G, N)

    dA = dtc * A                                                   # (B,nc,cs,H) <= 0
    dA_cum = jnp.cumsum(dA, axis=2)                                # within-chunk cumsum

    # intra-chunk (quadratic, attention-like):
    L = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))                   # (B,nc,H,cs,cs)
    CB = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)                  # (B,nc,G,cs,cs)
    CB = jnp.repeat(CB, rep, axis=2)                               # (B,nc,H,cs,cs)
    M = CB * L
    y_diag = jnp.einsum("bchij,bcjhp,bcjh->bcihp", M, xc, dtc)

    # chunk states: contribution of each chunk to the recurrent state
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)          # (B,nc,cs,H)
    Brep = jnp.repeat(Bc, rep, axis=3)                             # (B,nc,cs,H,N)
    states = jnp.einsum("bcihn,bcih,bcih,bcihp->bchpn",
                        Brep, decay_states, dtc, xc)

    # inter-chunk recurrence: h_{c} = exp(sum dA_c) h_{c-1} + states_{c-1}
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                     # (B,nc,H)

    def scan_fn(h, inp):
        dec, st = inp                                              # dec (B,H), st (B,H,P,N)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    init = jnp.zeros((Bb, states.shape[2], P, N), states.dtype)
    final, h_prev = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                            # (B,nc,H,P,N)

    # inter-chunk output: decay from chunk start
    state_decay = jnp.exp(dA_cum)                                  # (B,nc,cs,H)
    Crep = jnp.repeat(Cc, rep, axis=3)                             # (B,nc,cs,H,N)
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", Crep, h_prev, state_decay)

    y = (y_diag + y_off).reshape(Bb, S, H, P)
    return y, final


def mamba_forward(cfg: ModelConfig, params, x, return_state: bool = False):
    """Full-sequence forward. x: (B,S,D) -> (B,S,D)."""
    s = cfg.ssm
    d, d_inner, H, P, G, N = mamba_dims(cfg, x.shape[-1])
    Bb, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, gather_weight(params["w_xz"], (None, "tp")))
    xs, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("bsd,de->bse", x, gather_weight(params["w_bc"], (None, "tp")))
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, gather_weight(params["w_dt"], (None, "tp")))
                         + params["dt_bias"])

    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out = jax.nn.silu(causal_conv1d(conv_in, params["conv_w"]))
    xs = conv_out[..., :d_inner].reshape(Bb, S, H, P)
    bc = conv_out[..., d_inner:]
    B_ = bc[..., :G * N].reshape(Bb, S, G, N)
    C_ = bc[..., G * N:].reshape(Bb, S, G, N)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    pad = (-S) % s.chunk_size
    if pad:   # pad to a chunk multiple; dt=0 on pads -> state untouched
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # (B, S, H)
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, final_state = _ssd_chunked(
        xs.astype(jnp.float32), jnp.where(
            (jnp.arange(xs.shape[1]) < S)[None, :, None],
            dt.astype(jnp.float32), 0.0), A,
        B_.astype(jnp.float32), C_.astype(jnp.float32), s.chunk_size)
    if pad:
        y = y[:, :S]
        xs = xs[:, :S]
    y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bb, S, d_inner).astype(x.dtype)
    y = rmsnorm_apply(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, gather_weight(params["w_out"], ("tp", None)))
    if return_state:
        return out, final_state
    return out


# ---------------------------------------------------------------------------
# decode (recurrent step)
# ---------------------------------------------------------------------------

def mamba_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32, d_model=None):
    s = cfg.ssm
    d, d_inner, H, P, G, N = mamba_dims(cfg, d_model)
    conv_ch = d_inner + 2 * G * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
    }


def mamba_decode(cfg: ModelConfig, params, x, cache):
    """One-token step. x: (B,1,D) -> (y, new_cache). O(1) in sequence length."""
    s = cfg.ssm
    d, d_inner, H, P, G, N = mamba_dims(cfg, x.shape[-1])
    Bb = x.shape[0]
    xt = x[:, 0, :]
    xz = xt @ params["w_xz"]
    xs, z = jnp.split(xz, 2, axis=-1)
    bc = xt @ params["w_bc"]
    dt = jax.nn.softplus(xt @ params["w_dt"] + params["dt_bias"])   # (B,H)

    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, new_conv = causal_conv1d_update(cache["conv"], conv_in, params["conv_w"])
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_inner].reshape(Bb, H, P)
    bcv = conv_out[..., d_inner:]
    B_ = bcv[..., :G * N].reshape(Bb, G, N)
    C_ = bcv[..., G * N:].reshape(Bb, G, N)
    rep = H // G
    B_ = jnp.repeat(B_, rep, axis=1)                                # (B,H,N)
    C_ = jnp.repeat(C_, rep, axis=1)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))               # (H,)
    dA = jnp.exp(dt.astype(jnp.float32) * A)                        # (B,H)
    h = cache["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt.astype(jnp.float32), B_.astype(jnp.float32),
        xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", C_.astype(jnp.float32), h)
    y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bb, d_inner).astype(x.dtype)
    y = rmsnorm_apply(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ params["w_out"])[:, None, :]
    return out, {"ssm": h, "conv": new_conv}
