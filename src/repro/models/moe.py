"""Mixture-of-Experts FFN with top-k routing and capacity-bucketed dispatch.

GShard-style *grouped* dispatch: the token stream is reshaped to
``(G, g, D)`` groups; each group routes its ``g`` tokens into per-expert
capacity buckets (``C = cf * g * k / E``) via one-hot einsums; expert FFNs
run batched over the expert axis (shardable for expert parallelism); outputs
are combined with router weights.

Why groups: the dispatch tensor is ``(G, g, E, C)`` and the expert input is
``(G, E, C, D)`` whose total size is ``T * cf * k * D`` — independent of E
and g — so the formulation scales to 160-expert / 1M-token configurations.
Sharding G over the data axis and E over the model axis reproduces the
all-to-all communication pattern of expert parallelism under GSPMD.

Supports DeepSeek-V2 style shared experts (always-on) alongside routed ones,
plus a Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, swiglu_init, swiglu_apply
from repro.pjit_utils import constrain, gather_weight

MOE_GROUP_TOKENS = 1024  # tokens per dispatch group (capped by seq len)
# Gather-based dispatch (O(T*k*D) instead of the one-hot einsums' O(T*E*C*D))
# is kept as an option but DISABLED by default: the §Perf dry-run iterations
# showed that under GSPMD the combine gather costs an (G,E,C,D)-sized
# all-gather/all-reduce (~6x the einsum path's (G,g,D) partial-sum
# all-reduce), so the einsum path wins on the collective term at equal
# compute within measurement noise. See EXPERIMENTS.md §Perf, deepseek-v2
# iterations 2-3 (hypothesis refuted).
GATHER_DISPATCH_MIN_E = 1_000_000


def moe_init(cfg: ModelConfig, key, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    eff = m.expert_d_ff or cfg.d_ff
    k_r, k_e, k_s = jax.random.split(key, 3)
    ke = jax.random.split(k_e, 3)
    p = {
        "router": dense_init(k_r, d, m.num_experts, dtype),
        # expert weights stacked on a leading E axis
        "w_gate": jax.vmap(lambda k: dense_init(k, d, eff, dtype))(
            jax.random.split(ke[0], m.num_experts)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, eff, dtype))(
            jax.random.split(ke[1], m.num_experts)),
        "w_down": jax.vmap(lambda k: dense_init(k, eff, d, dtype))(
            jax.random.split(ke[2], m.num_experts)),
    }
    if m.num_shared_experts:
        p["shared"] = swiglu_init(k_s, d, m.num_shared_experts * eff, dtype)
    return p


def group_capacity(m, group_tokens: int) -> int:
    cap = int(m.capacity_factor * group_tokens * m.num_experts_per_tok / m.num_experts)
    return max(cap, 4)


def moe_apply(cfg: ModelConfig, params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    K, E = m.num_experts_per_tok, m.num_experts
    g = min(S, MOE_GROUP_TOKENS)
    if (B * S) % g != 0:
        raise ValueError(
            f"token count B*S={B * S} must divide into MoE routing "
            f"groups of {g} tokens")
    G = (B * S) // g
    xt = x.reshape(G, g, D)
    xt = constrain(xt, ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                          # (G,g,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                    # (G,g,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)          # (G,g,K,E)

    # Switch-style load-balance auxiliary loss, computed over all tokens
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))              # (E,)
    aux = E * jnp.sum(me * ce) * m.router_aux_loss_coef

    # position of each (token, k) routing inside its expert's bucket (per group)
    C = group_capacity(m, g)
    flat = onehot.reshape(G, g * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                            # exclusive
    pos_in_e = jnp.sum(pos * flat, axis=-1).reshape(G, g, K)         # (G,g,K)
    keep = pos_in_e < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    cdt = x.dtype
    c_idx = jnp.where(keep, pos_in_e.astype(jnp.int32), C)          # C = drop
    if E >= GATHER_DISPATCH_MIN_E:
        # §Perf (gather dispatch): the dense one-hot dispatch/combine
        # einsums cost O(T * E * C * D) flops — for 160-expert configs that
        # rivals the model's entire useful compute. Scatter token ids into
        # per-expert capacity slots and GATHER the tokens instead: O(T*k*D).
        tok = jax.lax.broadcasted_iota(jnp.int32, (G, g, K), 1)
        gidx = jax.lax.broadcasted_iota(jnp.int32, (G, g, K), 0)
        idx = jnp.full((G, E, C + 1), g, jnp.int32)                  # g = pad
        idx = idx.at[gidx, gate_idx, c_idx].set(tok, mode="drop")
        idx = idx[..., :C]                                           # (G,E,C)
        xt_pad = jnp.concatenate(
            [xt, jnp.zeros((G, 1, D), xt.dtype)], axis=1)
        ein = jax.vmap(lambda xg, ig: xg[ig])(
            xt_pad, idx.reshape(G, E * C)).reshape(G, E, C, D)
        # keep the gather shard-LOCAL (xt and idx are batch-sharded), then
        # reshard to the expert-parallel layout as one explicit all-to-all —
        # otherwise GSPMD lowers a cross-shard gather as masked all-reduces
        ein = constrain(ein, ("batch", None, None, None))
    else:
        slot_oh = jax.nn.one_hot(c_idx, C + 1, dtype=cdt)[..., :C]
        disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(cdt), slot_oh)
        ein = jnp.einsum("gtec,gtd->gecd", disp, xt)                 # (G,E,C,D)
    ein = constrain(ein, ("batch", "expert", None, None))
    # §Perf (expert parallelism): when E divides the tensor-parallel axis,
    # experts stay sharded in ID space ("expert" -> model axis) and tokens
    # move to them (GSPMD inserts the all-to-all on the dispatch einsums)
    # instead of all-gathering the whole expert tables every layer. For
    # small-E archs (mixtral, E=8 < 16) the divisibility guard drops the
    # expert axis and the (d, f)-sharded + JIT-weight-gather layout is used.
    w_gate = gather_weight(params["w_gate"], ("expert", None, "tp"))
    w_up = gather_weight(params["w_up"], ("expert", None, "tp"))
    w_down = gather_weight(params["w_down"], ("expert", "tp", None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, w_gate))
    h = h * jnp.einsum("gecd,edf->gecf", ein, w_up)
    eout = jnp.einsum("gecf,efd->gecd", h, w_down)                   # (G,E,C,D)
    eout = constrain(eout, ("batch", "expert", None, None))

    if E >= GATHER_DISPATCH_MIN_E:
        # gather each (token, k)'s expert output back and weight by gates;
        # all-to-all back to the batch-sharded layout first so the gather
        # stays shard-local
        eout = constrain(eout, ("batch", None, None, None))
        eflat = jnp.concatenate(
            [eout.reshape(G, E * C, D),
             jnp.zeros((G, 1, D), eout.dtype)], axis=1)
        slot = jnp.where(keep, gate_idx * C + c_idx, E * C)          # (G,g,K)
        vals = jax.vmap(lambda eg, sg: eg[sg])(
            eflat, slot.reshape(G, g * K)).reshape(G, g, K, D)
        out = jnp.einsum("gtkd,gtk->gtd", vals, gate_vals.astype(cdt))
    else:
        comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(cdt), slot_oh,
                          gate_vals.astype(cdt))
        out = jnp.einsum("gtec,gecd->gtd", comb, eout)
    if m.num_shared_experts:
        out = out + swiglu_apply(params["shared"], xt)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
