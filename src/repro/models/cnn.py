"""The paper's own model families, in pure JAX.

* MNIST CNN (Appendix A.1, Table 1): Conv32-Conv64-MaxPool-Dense128-Dense10.
* Deep-driving CNN (Appendix A.4, Table 5; Bojarski et al. PilotNet):
  5 conv layers + 4 dense layers -> steering angle.
* MLP for the random-graphical-model concept-drift task (Appendix A.3).

A ``cnn_spec`` is a tuple of layer descriptors:
  ("conv", out_ch, k, stride)   valid-padded conv + ReLU
  ("pool", k)                   max pool k x k
  ("flatten",)
  ("dense", n)                  dense + ReLU (last dense is linear)
  ("dropout", rate)             inverted dropout (active only given an rng)
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init


def _conv_init(key, k: int, c_in: int, c_out: int, dtype):
    # Glorot-uniform with the CONV fans: fan_in = k*k*c_in receptive-field
    # inputs, fan_out = k*k*c_out (each weight feeds k*k output taps). The
    # earlier k*k*c_in + c_out denominator under-counted fan_out and
    # over-scaled every conv layer.
    lim = math.sqrt(6.0 / (k * k * (c_in + c_out)))
    w = jax.random.uniform(key, (k, k, c_in, c_out), dtype, -lim, lim)
    return {"w": w, "b": jnp.zeros((c_out,), dtype)}


def _shape_after(spec, input_shape):
    h, w, c = input_shape
    flat = None
    for layer in spec:
        if layer[0] == "conv":
            _, c_out, k, s = layer
            h = (h - k) // s + 1
            w = (w - k) // s + 1
            c = c_out
        elif layer[0] == "pool":
            k = layer[1]
            h, w = h // k, w // k
        elif layer[0] == "flatten":
            if c:
                flat = h * w * c
        elif layer[0] == "dense":
            flat = layer[1]
    return flat


def init_cnn_params(cfg: ModelConfig, key, dtype=jnp.float32):
    spec = cfg.cnn_spec
    if len(cfg.input_shape) == 1:           # dense-only model (drift MLP)
        h = w = c = 0
        flat = cfg.input_shape[0]
    else:
        h, w, c = cfg.input_shape
        flat = None
    params = []
    for layer in spec:
        if layer[0] == "conv":
            _, c_out, k, s = layer
            key, sub = jax.random.split(key)
            params.append(_conv_init(sub, k, c, c_out, dtype))
            h = (h - k) // s + 1
            w = (w - k) // s + 1
            c = c_out
        elif layer[0] == "pool":
            params.append({})
            h, w = h // layer[1], w // layer[1]
        elif layer[0] == "flatten":
            params.append({})
            if c:                       # image input; 1-D inputs keep flat
                flat = h * w * c
        elif layer[0] == "dense":
            key, sub = jax.random.split(key)
            params.append({"w": dense_init(sub, flat, layer[1], dtype),
                           "b": jnp.zeros((layer[1],), dtype)})
            flat = layer[1]
        elif layer[0] == "dropout":
            params.append({})
        else:
            raise ValueError(layer)
    return {"layers": params}


def cnn_apply(cfg: ModelConfig, params, x, *, rng: Optional[jax.Array] = None):
    """x: (B, H, W, C) [or (B, d_in) for pure-dense specs] -> (B, num_outputs)."""
    spec = cfg.cnn_spec
    n_dense = sum(1 for l in spec if l[0] == "dense")
    seen_dense = 0
    for layer, p in zip(spec, params["layers"]):
        if layer[0] == "conv":
            _, c_out, k, s = layer
            x = jax.lax.conv_general_dilated(
                x, p["w"], window_strides=(s, s), padding="VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + p["b"])
        elif layer[0] == "pool":
            k = layer[1]
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")
        elif layer[0] == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif layer[0] == "dense":
            seen_dense += 1
            x = x @ p["w"] + p["b"]
            if seen_dense < n_dense:
                x = jax.nn.relu(x)
        elif layer[0] == "dropout":
            if rng is not None:
                rate = layer[1]
                rng, sub = jax.random.split(rng)
                keepmask = jax.random.bernoulli(sub, 1.0 - rate, x.shape)
                x = jnp.where(keepmask, x / (1.0 - rate), 0.0)
    return x


def cnn_loss(cfg: ModelConfig, params, batch, *, rng=None):
    """Cross-entropy for classifiers, MSE for regression (num_outputs==1)."""
    out = cnn_apply(cfg, params, batch["x"], rng=rng)
    if cfg.num_outputs == 1:
        return jnp.mean(jnp.square(out[:, 0] - batch["y"]))
    lp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None], axis=-1))


def cnn_accuracy(cfg: ModelConfig, params, batch):
    out = cnn_apply(cfg, params, batch["x"])
    return jnp.mean((jnp.argmax(out, axis=-1) == batch["y"]).astype(jnp.float32))
