"""Decoder block: mixer (attention / SSM / hybrid-parallel) + FFN (dense / MoE).

One block's params are a dict; the full model stacks L copies on a leading
axis and runs ``lax.scan`` over them (small HLO, fast compiles even at 126
layers).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, BLOCK_ATTN, BLOCK_SSM, BLOCK_HYBRID
from repro.models import attention as attn
from repro.models import mamba
from repro.models import moe as moe_mod
from repro.models.layers import (
    rmsnorm_init, rmsnorm_apply, swiglu_init, swiglu_apply,
)
from repro.pjit_utils import constrain


def block_init(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    p = {"norm_mix": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.block_type in (BLOCK_ATTN, BLOCK_HYBRID):
        p["attn"] = attn.attn_init(cfg, ks[0], dtype)
    if cfg.block_type in (BLOCK_SSM, BLOCK_HYBRID):
        p["ssm"] = mamba.mamba_init(cfg, ks[1], dtype)
    if cfg.block_type == BLOCK_HYBRID:
        # Hymba-style parallel heads: per-branch output norms before fusion
        p["norm_attn_out"] = rmsnorm_init(cfg.d_model, dtype)
        p["norm_ssm_out"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.d_ff or cfg.is_moe:
        p["norm_ffn"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.is_moe:
            p["moe"] = moe_mod.moe_init(cfg, ks[2], dtype)
        else:
            p["ffn"] = swiglu_init(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def _mixer_forward(cfg: ModelConfig, p, h, positions):
    if cfg.block_type == BLOCK_ATTN:
        if cfg.mla is not None:
            return attn.mla_forward(cfg, p["attn"], h, positions)
        return attn.gqa_forward(cfg, p["attn"], h, positions)
    if cfg.block_type == BLOCK_SSM:
        return mamba.mamba_forward(cfg, p["ssm"], h)
    # hybrid: parallel attention + SSM heads, normalized and averaged (Hymba)
    a = attn.gqa_forward(cfg, p["attn"], h, positions)
    s = mamba.mamba_forward(cfg, p["ssm"], h)
    a = rmsnorm_apply(p["norm_attn_out"], a, cfg.norm_eps)
    s = rmsnorm_apply(p["norm_ssm_out"], s, cfg.norm_eps)
    return 0.5 * (a + s)


def block_forward(cfg: ModelConfig, p, x, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (x', aux_loss)."""
    h = rmsnorm_apply(p["norm_mix"], x, cfg.norm_eps)
    x = x + _mixer_forward(cfg, p, h, positions)
    aux = jnp.zeros((), jnp.float32)
    if "norm_ffn" in p:
        h = rmsnorm_apply(p["norm_ffn"], x, cfg.norm_eps)
        h = constrain(h, ("batch", None, None))
        if cfg.is_moe:
            f, aux = moe_mod.moe_apply(cfg, p["moe"], h)
        else:
            f = swiglu_apply(p["ffn"], h)
        x = x + f
    x = constrain(x, ("batch", None, None))
    return x, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def block_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32):
    c = {}
    if cfg.block_type in (BLOCK_ATTN, BLOCK_HYBRID):
        c["attn"] = attn.attn_cache_init(cfg, batch, max_seq, dtype)
    if cfg.block_type in (BLOCK_SSM, BLOCK_HYBRID):
        c["ssm"] = mamba.mamba_cache_init(cfg, batch, dtype)
    return c


def _mixer_decode(cfg: ModelConfig, p, h, cache, pos):
    new_cache = {}
    if cfg.block_type == BLOCK_ATTN:
        if cfg.mla is not None:
            y, new_cache["attn"] = attn.mla_decode(cfg, p["attn"], h, cache["attn"], pos)
        else:
            y, new_cache["attn"] = attn.gqa_decode(cfg, p["attn"], h, cache["attn"], pos)
        return y, new_cache
    if cfg.block_type == BLOCK_SSM:
        y, new_cache["ssm"] = mamba.mamba_decode(cfg, p["ssm"], h, cache["ssm"])
        return y, new_cache
    a, new_cache["attn"] = attn.gqa_decode(cfg, p["attn"], h, cache["attn"], pos)
    s, new_cache["ssm"] = mamba.mamba_decode(cfg, p["ssm"], h, cache["ssm"])
    a = rmsnorm_apply(p["norm_attn_out"], a, cfg.norm_eps)
    s = rmsnorm_apply(p["norm_ssm_out"], s, cfg.norm_eps)
    return 0.5 * (a + s), new_cache


def block_decode(cfg: ModelConfig, p, x, cache, pos):
    """x: (B,1,D) -> (x', new_cache)."""
    h = rmsnorm_apply(p["norm_mix"], x, cfg.norm_eps)
    y, new_cache = _mixer_decode(cfg, p, h, cache, pos)
    x = x + y
    if "norm_ffn" in p:
        h = rmsnorm_apply(p["norm_ffn"], x, cfg.norm_eps)
        if cfg.is_moe:
            f, _ = moe_mod.moe_apply(cfg, p["moe"], h)
        else:
            f = swiglu_apply(p["ffn"], h)
        x = x + f
    return x, new_cache
