"""Static and post-hoc analysis of the protocol machinery.

Each tool answers one question about the program WITHOUT running it on
real data:

* ``contracts`` — *do the registered stages keep their declared
  shape/dtype promises, on every preset, layout and hierarchy?*
  Abstract evaluation via ``jax.eval_shape`` (zero FLOPs); also the
  layout-conformance harness any future fleet backend plugs into.
* ``audit`` — *does the traced round contain a forbidden pattern?*
  Recursive jaxpr walk: host callbacks inside ``lax.scan``, float64 /
  weak-type leaks, dynamic shapes, narrow-int accumulators that can
  wrap. ``audit_hlo`` applies the dtype/callback rules to compiled HLO
  text.
* ``lint`` — *does the source obey the repo's shape rules?* AST pass:
  no bare asserts, ``jax.__version__`` only in compat.py, every
  ``register_*`` call declares a contract, ``network/`` modules stay
  pure in (seed, t).
* ``hlo`` — *what collectives does a compiled module run, and how many
  bytes do they move?* Regex parser over HLO text (import
  ``repro.analysis.hlo`` directly).
* ``roofline`` — *is a measured run compute-, memory- or
  network-bound?* Three-term model on top of ``hlo`` (import
  ``repro.analysis.roofline`` directly).

``python -m repro.analysis --check-all`` runs the first three as the
tier-1 CI gate (exit 1 on any finding).
"""
from repro.analysis.report import Finding, render_findings
from repro.analysis.contracts import (
    abstract_state, check_all, check_hierarchy, check_layout_equivalence,
    check_preset_matrix, check_registry, check_round, check_spec,
    mixed_template,
)
from repro.analysis.audit import (
    audit_fn, audit_hlo, audit_jaxpr, audit_presets, audit_spec,
)
from repro.analysis.lint import lint_file, lint_paths, lint_source

__all__ = [
    "Finding", "render_findings",
    # contracts
    "abstract_state", "check_all", "check_hierarchy",
    "check_layout_equivalence", "check_preset_matrix", "check_registry",
    "check_round", "check_spec", "mixed_template",
    # audit
    "audit_fn", "audit_hlo", "audit_jaxpr", "audit_presets", "audit_spec",
    # lint
    "lint_file", "lint_paths", "lint_source",
]
