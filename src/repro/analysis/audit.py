"""Jaxpr auditor: walk the traced computation for forbidden patterns.

``repro.analysis.contracts`` proves the SIGNATURES of the protocol
machinery; this module inspects the PROGRAM. It traces the exact
scanned-round shape the engine runs (``jax.lax.scan`` over the compiled
spec round) and recursively walks the jaxpr — into scan bodies, cond
branches, while loops and closed calls — flagging:

* **callback-in-scan** — host callbacks (``pure_callback``,
  ``io_callback``, ``jax.debug.*``) inside a scanned body: a host
  round-trip per round, the single worst thing that can happen to the
  protocol hot loop.
* **float64-leak** / **complex-leak** — any equation producing a 64-bit
  float (or complex) value. The simulator is a 32-bit program end to
  end; a float64 appearing in the trace means a Python float promoted
  something past f32 (or someone enabled x64 halfway).
* **weak-type-carry** — a scan carry leaf whose output aval is weakly
  typed: the second iteration retraces with the strong type, so the
  carry never stabilizes.
* **dynamic-shape** — an equation output whose shape is not fully
  static (polymorphic dims); the fleet plane is a statically-shaped
  (m, P) program by construction.
* **int32-accumulator** — a narrow-int scan carry that grows by a
  data-dependent amount each iteration with no reset, i.e. one that can
  wrap silently. The engine's legitimate int32 counters pass: literal
  ``+1`` increments (the step clock) and counters that feed a
  ``select_n`` reset (the violation counter, the staleness ages) are
  exempt; 64-bit ledgers (the host-side bytes ledger) are exempt by
  width.

``audit_spec`` is the per-spec entry point used by the CI gate;
``audit_fn`` audits an arbitrary callable on abstract inputs. The
HLO-text backend (``audit_hlo``) applies the same dtype/callback rules
to a compiled module via the regex helpers in ``repro.analysis.hlo`` —
useful when only the lowered text of a run survives (the artifact the
roofline tooling already consumes).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import hlo as hlo_mod
from repro.analysis.report import Finding

__all__ = ["audit_jaxpr", "audit_fn", "audit_spec", "audit_hlo",
           "audit_presets"]

# host-callback primitives; any of these inside a scan body is a finding
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "callback", "host_callback_call", "outside_call", "infeed", "outfeed",
})

# value-preserving unary ops the accumulator analysis sees through
_TRANSPARENT = frozenset({
    "convert_element_type", "broadcast_in_dim", "reshape", "squeeze",
    "copy", "stop_gradient",
})

_BAD_DTYPES = {"float64": "float64-leak", "complex128": "complex-leak"}


def _is_literal(v) -> bool:
    return hasattr(v, "val")          # core.Literal carries .val; Var doesn't


def _sub_jaxprs(params):
    """Every sub-jaxpr referenced by one equation's params."""
    subs = []
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "jaxpr"):          # ClosedJaxpr
                subs.append(v.jaxpr)
            elif hasattr(v, "eqns"):         # raw Jaxpr
                subs.append(v)
    return subs


def _aval(v):
    return getattr(v, "aval", None)


def _dtype_name(aval) -> str:
    try:
        return jnp.dtype(aval.dtype).name
    except Exception:  # noqa: BLE001 — abstract tokens etc. have no dtype
        return ""


# ---------------------------------------------------------------------------
# the int32-accumulator rule
# ---------------------------------------------------------------------------

def _producer(jaxpr, var):
    for eqn in jaxpr.eqns:
        if any(o is var for o in eqn.outvars):
            return eqn
    return None


def _reaches(jaxpr, var, targets, depth: int = 8) -> bool:
    """Does ``var`` trace back to any of ``targets`` through producers?"""
    if _is_literal(var):
        return False
    if any(var is t for t in targets):
        return True
    if depth == 0:
        return False
    eqn = _producer(jaxpr, var)
    if eqn is None:
        return False
    return any(_reaches(jaxpr, o, targets, depth - 1)
               for o in eqn.invars if not _is_literal(o))


def _feeds_select(jaxpr, var) -> bool:
    """Is ``var`` an input of a ``select_n`` in the same jaxpr? That is
    the reset idiom (``jnp.where(done, 0, acc)``) — a bounded counter."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "select_n" and \
                any(o is var for o in eqn.invars if not _is_literal(o)):
            return True
    return False


def _is_invariant(jaxpr, var, invariants, carry_ins, depth: int = 6) -> bool:
    """Is ``var`` loop-invariant — a literal, a scan const/constvar, or a
    pure function of those? Carry leaves and per-iteration xs inputs (any
    var of unknown origin) are NOT invariant."""
    if _is_literal(var):
        return True
    if any(var is i for i in invariants):
        return True
    if any(var is c for c in carry_ins):
        return False
    if depth == 0:
        return False
    eqn = _producer(jaxpr, var)
    if eqn is None:
        return False                  # xs input / outer var: varies per step
    return all(_is_invariant(jaxpr, o, invariants, carry_ins, depth - 1)
               for o in eqn.invars)


def _unbounded_growth(jaxpr, var, carry_ins, invariants,
                      depth: int = 6) -> Optional[str]:
    """Classify how carry-out ``var`` was produced: returns a description
    of an unbounded data-dependent increment, or None when the update is
    safe (pass-through, loop-invariant step, reset via select, bounded
    op). An add is an accumulator when an operand chains back to a carry
    leaf; its increment is data-dependent when MORE than one operand is
    non-invariant (e.g. ``acc + f(y)`` where ``y`` is carried data or a
    per-iteration input)."""
    if any(var is c for c in carry_ins):
        return None                              # pass-through
    if _is_literal(var):
        return None
    eqn = _producer(jaxpr, var)
    if eqn is None:
        return None                              # invar/constvar: no growth
    name = eqn.primitive.name
    if name in _TRANSPARENT:
        ops = [o for o in eqn.invars if not _is_literal(o)]
        return _unbounded_growth(jaxpr, ops[0], carry_ins, invariants,
                                 depth) if ops and depth else None
    if name in ("add", "add_any", "sub"):
        if not any(not _is_literal(o) and _reaches(jaxpr, o, carry_ins)
                   for o in eqn.invars):
            return None                          # not an accumulator at all
        variable = [o for o in eqn.invars
                    if not _is_invariant(jaxpr, o, invariants, carry_ins)]
        if len(variable) <= 1:
            # the single non-invariant operand is the accumulator itself;
            # the step is constant (the t+1 clock) — bounded by the scan
            # length the caller chose
            return None
        if _feeds_select(jaxpr, var):
            return None                          # reset idiom downstream
        return (f"grows by a data-dependent amount each iteration "
                f"({name} with a non-constant operand) and is never reset")
    if name == "select_n":
        # a select over candidates: unbounded only if EVERY candidate is
        ops = [o for o in eqn.invars[1:] if not _is_literal(o)]
        if not depth:
            return None
        results = [_unbounded_growth(jaxpr, o, carry_ins, invariants,
                                     depth - 1) for o in ops]
        if results and all(r is not None for r in results):
            return results[0]
        return None
    if name == "cond":
        branches = eqn.params.get("branches", ())
        idx = next(i for i, o in enumerate(eqn.outvars) if o is var)
        ops = eqn.invars[1:]                     # invars[0] is the index
        if not depth:
            return None
        for br in branches:
            bj = br.jaxpr if hasattr(br, "jaxpr") else br
            tr_carries, tr_inv = [], list(bj.constvars)
            for i, o in enumerate(ops):
                if i >= len(bj.invars):
                    break
                if not _is_literal(o) and _reaches(jaxpr, o, carry_ins):
                    tr_carries.append(bj.invars[i])
                elif _is_invariant(jaxpr, o, invariants, carry_ins):
                    tr_inv.append(bj.invars[i])
            r = _unbounded_growth(bj, bj.outvars[idx], tr_carries, tr_inv,
                                  depth - 1)
            if r is not None:
                return r
        return None
    # max/min/clamp/and/or/mul-by-mask/...: treated as bounded
    return None


# ---------------------------------------------------------------------------
# the walker
# ---------------------------------------------------------------------------

def _audit_scan_carries(eqn, where: str, findings: List[Finding]) -> None:
    closed = eqn.params["jaxpr"]
    body = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    nc = eqn.params.get("num_consts", 0)
    ncar = eqn.params.get("num_carry", 0)
    carry_ins = list(body.invars[nc:nc + ncar])
    carry_outs = list(body.outvars[:ncar])
    invariants = list(body.invars[:nc]) + list(body.constvars)
    for i, (cin, cout) in enumerate(zip(carry_ins, carry_outs)):
        aval = _aval(cout) or _aval(cin)
        if aval is None:
            continue
        if getattr(aval, "weak_type", False):
            findings.append(Finding(
                "audit", "weak-type-carry", f"{where}/carry[{i}]",
                f"scan carry leaf is weakly typed ({_dtype_name(aval)}): "
                f"the strong-typed second iteration forces a retrace"))
        dt = _dtype_name(aval)
        if dt.startswith("int") and jnp.dtype(aval.dtype).itemsize < 8:
            why = _unbounded_growth(body, cout, carry_ins, invariants)
            if why is not None:
                findings.append(Finding(
                    "audit", "int32-accumulator", f"{where}/carry[{i}]",
                    f"{dt} scan carry {why} — it can wrap silently; "
                    f"accumulate in int64 on the host (the bytes-ledger "
                    f"pattern) or reset it inside the loop"))


def audit_jaxpr(jaxpr, where: str = "jaxpr",
                _in_scan: bool = False) -> List[Finding]:
    """Recursively audit one (closed or raw) jaxpr. ``where`` prefixes
    the finding locations."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    findings: List[Finding] = []
    for v in list(jaxpr.invars) + list(jaxpr.outvars):
        aval = _aval(v)
        if aval is None:
            continue
        rule = _BAD_DTYPES.get(_dtype_name(aval))
        if rule:
            findings.append(Finding(
                "audit", rule, f"{where}/signature",
                f"jaxpr boundary carries a {_dtype_name(aval)} value "
                f"{tuple(aval.shape)}"))
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS and _in_scan:
            findings.append(Finding(
                "audit", "callback-in-scan", f"{where}/{name}",
                f"host callback {name!r} inside a scanned body: one "
                f"host round-trip per iteration"))
        for o in eqn.outvars:
            aval = _aval(o)
            if aval is None:
                continue
            rule = _BAD_DTYPES.get(_dtype_name(aval))
            if rule:
                findings.append(Finding(
                    "audit", rule, f"{where}/{name}",
                    f"{name} produces {_dtype_name(aval)} "
                    f"{tuple(aval.shape)} — the simulator is a 32-bit "
                    f"program (device side)"))
            if not all(isinstance(d, int) for d in aval.shape):
                findings.append(Finding(
                    "audit", "dynamic-shape", f"{where}/{name}",
                    f"{name} output shape {aval.shape} is not static"))
        if name == "scan":
            _audit_scan_carries(eqn, f"{where}/scan", findings)
            findings += audit_jaxpr(eqn.params["jaxpr"], f"{where}/scan",
                                    _in_scan=True)
        else:
            for sub in _sub_jaxprs(eqn.params):
                findings += audit_jaxpr(sub, f"{where}/{name}",
                                        _in_scan=_in_scan)
    return findings


def audit_fn(fn, *abstract_args, where: str = "fn") -> List[Finding]:
    """Trace ``fn`` on ``ShapeDtypeStruct`` (or array) arguments and audit
    the resulting jaxpr."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return audit_jaxpr(closed, where)


# ---------------------------------------------------------------------------
# the spec entry points (what the CI gate runs)
# ---------------------------------------------------------------------------

def audit_spec(spec, template=None, *, rounds: int = 3) -> List[Finding]:
    """Audit the exact program the engine runs for ``spec``: ``rounds``
    compiled round calls under one ``lax.scan`` (availability-masked, so
    every stage path is in the trace)."""
    from repro.analysis.contracts import (
        abstract_state, mixed_template, _num_learners, _variant_label,
    )
    template = mixed_template() if template is None else template
    m = _num_learners(template)
    state = abstract_state(spec, template)
    acts = jax.ShapeDtypeStruct((rounds, m), jnp.bool_)
    adj = jax.ShapeDtypeStruct((m, m), jnp.bool_)
    round_fn = spec.compile()

    def chunk(stacked, st, act_seq, adjacency):
        def body(carry, act):
            cfg, s = carry
            res = round_fn(cfg, s, None, active=act, adjacency=adjacency)
            return (res.params, res.state), (res.rec, res.xfers,
                                             res.link_msgs)
        return jax.lax.scan(body, (stacked, st), act_seq)

    label = _variant_label(spec, weighted=False, with_active=True)
    try:
        closed = jax.make_jaxpr(chunk)(template, state, acts, adj)
    except Exception as e:  # noqa: BLE001
        msg = f"{type(e).__name__}: {e}"
        return [Finding("audit", "trace-error", label,
                        msg if len(msg) <= 300 else msg[:297] + "...")]
    return audit_jaxpr(closed, label)


def audit_presets(template=None,
                  presets: Optional[Sequence[str]] = None) -> List[Finding]:
    """Audit every registered preset's scanned round, on both layouts."""
    from repro.core.sync.registry import PROTOCOLS, get_protocol
    from repro.core.sync.spec import LAYOUTS
    findings: List[Finding] = []
    names = sorted(PROTOCOLS) if presets is None else list(presets)
    for name in names:
        preset = get_protocol(name)
        for layout in LAYOUTS:
            findings += audit_spec(preset.with_params(layout=layout),
                                   template)
    return findings


# ---------------------------------------------------------------------------
# HLO-text backend (repro.analysis.hlo is the parser)
# ---------------------------------------------------------------------------

_HLO_CALLBACK_MARKERS = ("custom-call", "CustomCall")
_HLO_CALLBACK_TARGETS = ("callback", "xla_python_cpu_callback",
                         "xla_ffi_python", "EmitPythonCallback")


def audit_hlo(hlo_text: str, where: str = "hlo") -> List[Finding]:
    """Apply the dtype and callback rules to compiled HLO text — the same
    artifact ``repro.analysis.hlo.parse_collectives`` (and the roofline
    report) already consumes."""
    findings: List[Finding] = []
    for i, line in enumerate(hlo_text.splitlines(), 1):
        mdef = hlo_mod._DEF_RE.match(line)
        if mdef is not None:
            # _DEF_RE: (name, shape, op); _SHAPE_RE: (dtype, dims)
            for mshape in hlo_mod._SHAPE_RE.finditer(mdef.group(2)):
                dt = mshape.group(1)
                rule = _BAD_DTYPES.get({"f64": "float64",
                                        "c128": "complex128"}.get(dt, dt))
                if rule:
                    findings.append(Finding(
                        "audit", rule, f"{where}:{i}",
                        f"compiled module materializes a {dt} tensor: "
                        f"{line.strip()[:120]}"))
        if any(mk in line for mk in _HLO_CALLBACK_MARKERS) and \
                any(tg in line for tg in _HLO_CALLBACK_TARGETS):
            findings.append(Finding(
                "audit", "host-callback", f"{where}:{i}",
                f"compiled module calls back into Python: "
                f"{line.strip()[:120]}"))
    return findings
