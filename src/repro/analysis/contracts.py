"""Abstract contract checking for the protocol-spec API — zero FLOPs.

Every registered stage (``repro.core.sync.registry``) declares a
``StageContract``; this module VERIFIES those declarations instead of
trusting them, by abstract evaluation (``jax.eval_shape``) of each stage
and of each compiled round over a mixed-dtype model template. Nothing is
ever executed on a device: the whole preset × layout × weighted ×
availability matrix (plus the two-tier hierarchy for every coordinator
preset) traces in seconds and proves, for each combination:

* **trigger** — the gate is a scalar bool; a conditional trigger's hot
  mask is (m,) bool and its count int32; condition auxiliaries match the
  declared ``cond_aux`` keys; trigger-owned extra state keeps its
  declared names/dtypes through ``init_extra``/``commit_extra``/
  ``skip_extra``.
* **cohort** — the mask is (m,) bool, the RNG key dtype is carried
  unchanged, the violation counter is owned exactly by stages declaring
  ``manages_v`` (scalar int32 + scalar bool full flag), ``aux`` keys
  match the declaration.
* **aggregate** — the output matches its declared kind: ``"model"`` is a
  single-model pytree (tree layout) / a (P,) plane row (flat layout),
  ``"fleet"`` an (m, ...) stacked pytree / the (m, P) plane.
* **commit + round** — the committed configuration and reference keep
  the input shapes AND dtypes bitwise (no promotion drift past the
  boundary), ``v``/``step``/``CommRecord``/``xfers``/``link_msgs`` are
  int32, and no weak type leaks into the scan carry.
* **layout equivalence** — the tree and flat rounds produce abstractly
  IDENTICAL ``StageResult`` trees (shape, dtype, weak type). This is the
  conformance harness for any future layout (e.g. a device-sharded
  plane): add the layout string to ``spec.LAYOUTS`` and every registered
  preset is checked against the tree reference for free.

``check_all()`` is the CI entry point (``python -m repro.analysis
--contracts``): registry coverage + the full preset matrix.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.report import Finding
from repro.core import flatten
from repro.core.sync import registry, stages
from repro.core.sync.registry import (
    AGGREGATES, COHORTS, COMMITS, PROTOCOLS, TRIGGERS, StageCtx, SyncState,
)
from repro.core.sync.spec import (
    GLOBAL_PARAMS, LAYOUTS, PLANE_LAYOUTS, ProtocolSpec,
)

__all__ = [
    "DEFAULT_M", "mixed_template", "abstract_state", "check_registry",
    "check_spec", "check_round", "check_layout_equivalence",
    "check_hierarchy", "check_preset_matrix", "check_all",
]

DEFAULT_M = 4            # fleet size of the abstract template
DEFAULT_CLUSTERS = 2     # hierarchy width (must divide DEFAULT_M)


def mixed_template(m: int = DEFAULT_M):
    """A deliberately mixed-dtype (f32 + bf16) stacked model template:
    promotion bugs that a homogeneous-f32 fleet can never exhibit (a
    weight vector downcast to bfloat16, a mean accumulated in the leaf
    dtype) change the abstract output here and fail the check."""
    return {
        "w": jax.ShapeDtypeStruct((m, 3, 2), jnp.float32),
        "b": jax.ShapeDtypeStruct((m, 2), jnp.bfloat16),
    }


def _num_learners(template) -> int:
    return jax.tree.leaves(template)[0].shape[0]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_state(spec: ProtocolSpec, template) -> SyncState:
    """The abstract ``SyncState`` matching ``init_state(ref, seed, spec=,
    m=)`` for a template fleet — extra state included, no arrays built."""
    m = _num_learners(template)
    ref = jax.tree.map(lambda s: _sds(s.shape[1:], s.dtype), template)
    extra = jax.eval_shape(lambda: spec.init_extra(m))
    i32 = _sds((), jnp.int32)
    return SyncState(ref=ref, v=i32, rng=_sds((2,), jnp.uint32), step=i32,
                     extra=extra)


# ---------------------------------------------------------------------------
# abstract-signature helpers
# ---------------------------------------------------------------------------

def _sig(x):
    """(shape, dtype) signature of one abstract leaf (None passes through)."""
    if x is None:
        return None
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return (tuple(x.shape), jnp.dtype(x.dtype).name)
    return ("py", type(x).__name__)


def _wsig(x):
    """Signature including the weak-type bit — the round-boundary check:
    a weak scalar leaking into the scan carry retraces every round."""
    if x is None:
        return None
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return (tuple(x.shape), jnp.dtype(x.dtype).name,
                bool(getattr(x, "weak_type", False)))
    return ("py", type(x).__name__)


def _sig_tree(t, sig=_sig):
    return jax.tree.map(sig, t)


def _is_scalar(x, dtype) -> bool:
    return (x is not None and hasattr(x, "shape") and tuple(x.shape) == ()
            and jnp.dtype(x.dtype) == jnp.dtype(dtype))


def _is_vec(x, n, dtype) -> bool:
    return (x is not None and hasattr(x, "shape") and tuple(x.shape) == (n,)
            and jnp.dtype(x.dtype) == jnp.dtype(dtype))


def _fmt(e: Exception) -> str:
    msg = f"{type(e).__name__}: {e}"
    return msg if len(msg) <= 300 else msg[:297] + "..."


# ---------------------------------------------------------------------------
# the stage harness: one abstract trace through every slot
# ---------------------------------------------------------------------------

def _trace_slots(spec: ProtocolSpec, template, *, weighted: bool,
                 with_active: bool) -> Dict[str, Any]:
    """Abstract-evaluate every slot of ``spec`` on ``template``, mirroring
    ``_compiled_round``'s exact ctx wiring, and return the per-slot
    ``ShapeDtypeStruct`` trees (plus the plane views under the flat
    layout)."""
    trig, coh, agg, com = spec.stage_records()
    p = spec.resolved_params()
    flat_layout = p["layout"] in PLANE_LAYOUTS
    m = _num_learners(template)
    state = abstract_state(spec, template)
    w = _sds((m,), jnp.float32) if weighted else None
    act = _sds((m,), jnp.bool_) if with_active else None
    adj = _sds((m, m), jnp.bool_)

    def run(stacked, st, weights, active, adjacency):
        out = {}
        t = st.step + 1
        reach = stages.cohort_all(m, active)
        adapter = flatten.fleet_adapter(stacked) if flat_layout else None
        ctx = StageCtx(params=p, stacked=stacked, state=st, weights=weights,
                       active=active, adjacency=adjacency, m=m, t=t,
                       reach=reach, adapter=adapter)
        g = trig.gate(ctx)
        out["gate"] = jnp.asarray(g) if isinstance(g, bool) else g
        if adapter is not None:
            ctx = ctx._replace(flat=adapter.ravel(stacked),
                               ref_flat=adapter.ravel_model(st.ref))
            out["plane"] = ctx.flat
            out["ref_plane"] = ctx.ref_flat
        hot, nhot = reach, None
        if trig.condition is not None:
            cond = trig.condition(ctx)
            hot, nhot = cond[0], cond[1]
            out["hot"], out["nhot"] = hot, nhot
            if len(cond) > 2:
                out["cond_aux"] = cond[2]
                ctx = ctx._replace(cond_aux=cond[2])
        cout = coh.fn(ctx, hot, nhot, st.rng)
        out["cohort"] = cout
        out["aggregate"] = agg.fn(ctx, cout)
        out["commit"] = com.fn(ctx, cout, out["aggregate"], hot, nhot)
        out["commit_extra"] = trig.commit_extra(ctx, cout.mask)
        out["skip_extra"] = trig.skip_extra(ctx)
        return out

    traced = jax.eval_shape(run, template, state, w, act, adj)
    traced["init_extra"] = state.extra
    traced["rng"] = state.rng
    return traced


def _variant_label(spec: ProtocolSpec, *, weighted: bool,
                   with_active: bool) -> str:
    name = spec.name or (f"{spec.trigger}/{spec.cohort}/"
                         f"{spec.aggregate}/{spec.commit}")
    tags = [spec.param("layout")]
    if weighted:
        tags.append("weighted")
    if not with_active:
        tags.append("ideal")
    return f"{name}[{','.join(tags)}]"


def check_spec(spec: ProtocolSpec, template=None, *, weighted: bool = False,
               with_active: bool = True) -> List[Finding]:
    """Verify every slot of one spec against its stages' declared
    contracts by abstract evaluation. Empty list = clean."""
    template = mixed_template() if template is None else template
    m = _num_learners(template)
    trig, coh, agg, com = spec.stage_records()
    label = _variant_label(spec, weighted=weighted, with_active=with_active)
    out: List[Finding] = []

    def bad(rule, slot, msg):
        out.append(Finding("contracts", rule, f"{label}/{slot}", msg))

    try:
        tr = _trace_slots(spec, template, weighted=weighted,
                          with_active=with_active)
    except Exception as e:  # noqa: BLE001 — any trace failure is a finding
        return [Finding("contracts", "trace-error", label, _fmt(e))]

    flat_layout = spec.param("layout") in PLANE_LAYOUTS
    plane_sig = _sig(tr.get("plane"))
    ref_plane_sig = _sig(tr.get("ref_plane"))
    ref_sig = _sig_tree(jax.tree.map(lambda s: _sds(s.shape[1:], s.dtype),
                                     template))
    tmpl_sig = _sig_tree(template)
    key_sig = _sig(tr["rng"])

    # ---- trigger ------------------------------------------------------
    gate = tr["gate"]
    if not (hasattr(gate, "shape") and tuple(gate.shape) == ()
            and jnp.dtype(gate.dtype) == jnp.dtype(jnp.bool_)):
        bad("gate-shape", f"trigger:{trig.name}",
            f"gate must be a scalar bool, got {_sig(gate)}")
    if trig.condition is not None:
        if not _is_vec(tr["hot"], m, jnp.bool_):
            bad("hot-mask", f"trigger:{trig.name}",
                f"condition hot mask must be ({m},) bool, "
                f"got {_sig(tr['hot'])}")
        if not _is_scalar(tr["nhot"], jnp.int32):
            bad("hot-count", f"trigger:{trig.name}",
                f"condition count must be scalar int32, "
                f"got {_sig(tr['nhot'])}")
        declared_aux = tuple(sorted(trig.contract.cond_aux)) \
            if trig.contract else ()
        got_aux = tr.get("cond_aux")
        got_keys = tuple(sorted(got_aux)) if isinstance(got_aux, dict) \
            else ()
        if got_keys != declared_aux:
            bad("cond-aux", f"trigger:{trig.name}",
                f"condition aux keys {list(got_keys)} != declared "
                f"{list(declared_aux)}")
        for k in got_keys:
            vsig = _sig(got_aux[k])
            if vsig is None or vsig[0] == "py" or vsig[0][:1] != (m,):
                bad("cond-aux", f"trigger:{trig.name}",
                    f"condition aux {k!r} must be an (m, ...) array, "
                    f"got {vsig}")

    # trigger-owned extra state: declared names/dtypes, identical
    # signatures through the init/commit/skip paths
    declared = dict(trig.contract.extra_state) if trig.contract else {}
    init_sig = _sig_tree(tr["init_extra"])
    if sorted(init_sig) != sorted(declared):
        bad("extra-state", f"trigger:{trig.name}",
            f"init_extra keys {sorted(init_sig)} != declared "
            f"{sorted(declared)}")
    else:
        for k, dt in declared.items():
            shape, got_dt = init_sig[k]
            if got_dt != jnp.dtype(dt).name:
                bad("extra-state", f"trigger:{trig.name}",
                    f"extra {k!r} is {got_dt}, declared {dt}")
    for path in ("commit_extra", "skip_extra"):
        if _sig_tree(tr[path]) != init_sig:
            bad("extra-state", f"trigger:{trig.name}",
                f"{path} signature {_sig_tree(tr[path])} != init_extra "
                f"{init_sig} — the carried dict must be shape/dtype "
                f"stable across sync and skip rounds")

    # ---- cohort -------------------------------------------------------
    cout = tr["cohort"]
    if not _is_vec(cout.mask, m, jnp.bool_):
        bad("cohort-mask", f"cohort:{coh.name}",
            f"mask must be ({m},) bool, got {_sig(cout.mask)}")
    if _sig(cout.rng) != key_sig:
        bad("rng-dtype", f"cohort:{coh.name}",
            f"carried RNG key {_sig(cout.rng)} != input key {key_sig}")
    manages = bool(coh.contract and coh.contract.manages_v)
    if manages:
        if not _is_scalar(cout.v, jnp.int32):
            bad("counter-dtype", f"cohort:{coh.name}",
                f"declares manages_v: v must be scalar int32, "
                f"got {_sig(cout.v)}")
        if not _is_scalar(cout.full, jnp.bool_):
            bad("counter-dtype", f"cohort:{coh.name}",
                f"declares manages_v: full must be scalar bool, "
                f"got {_sig(cout.full)}")
    else:
        if cout.v is not None or cout.full is not None:
            bad("counter-owner", f"cohort:{coh.name}",
                "returns v/full without declaring manages_v")
    declared_aux = tuple(sorted(coh.contract.aux)) if coh.contract else ()
    got_keys = tuple(sorted(cout.aux)) if isinstance(cout.aux, dict) else ()
    if got_keys != declared_aux:
        bad("cohort-aux", f"cohort:{coh.name}",
            f"aux keys {list(got_keys)} != declared {list(declared_aux)}")

    # ---- aggregate ----------------------------------------------------
    kind = agg.contract.out if agg.contract else "model"
    agg_sig = _sig_tree(tr["aggregate"])
    if kind == "model":
        want = ref_plane_sig if flat_layout else ref_sig
    else:  # "fleet"
        want = plane_sig if flat_layout else tmpl_sig
    if agg_sig != want:
        bad("aggregate-out", f"aggregate:{agg.name}",
            f"declared out={kind!r}: abstract output {agg_sig} != "
            f"expected {want}")

    # ---- commit -------------------------------------------------------
    sout = tr["commit"]
    want_params = plane_sig if flat_layout else tmpl_sig
    want_ref = ref_plane_sig if flat_layout else ref_sig
    if _sig_tree(sout.params) != want_params:
        bad("commit-params", f"commit:{com.name}",
            f"committed configuration {_sig_tree(sout.params)} != input "
            f"{want_params} — shapes and dtypes must be preserved bitwise")
    if _sig_tree(sout.ref) != want_ref:
        bad("commit-ref", f"commit:{com.name}",
            f"committed reference {_sig_tree(sout.ref)} != input "
            f"{want_ref}")
    if not _is_scalar(sout.v, jnp.int32):
        bad("counter-dtype", f"commit:{com.name}",
            f"carried v must be scalar int32, got {_sig(sout.v)}")
    if _sig(sout.rng) != key_sig:
        bad("rng-dtype", f"commit:{com.name}",
            f"carried RNG key {_sig(sout.rng)} != input key {key_sig}")
    for fname, fval in sout.rec._asdict().items():
        if not _is_scalar(fval, jnp.int32):
            bad("ledger-dtype", f"commit:{com.name}",
                f"CommRecord.{fname} must be scalar int32, "
                f"got {_sig(fval)}")
    if not _is_vec(sout.xfers, m, jnp.int32):
        bad("ledger-dtype", f"commit:{com.name}",
            f"xfers must be ({m},) int32, got {_sig(sout.xfers)}")
    if not _is_vec(sout.link_msgs, m, jnp.int32):
        bad("ledger-dtype", f"commit:{com.name}",
            f"link_msgs must be ({m},) int32, got {_sig(sout.link_msgs)}")
    return out


# ---------------------------------------------------------------------------
# full-round checks (through spec.compile, the thing the engine scans)
# ---------------------------------------------------------------------------

def _round_sds(spec: ProtocolSpec, template, *, weighted: bool,
               with_active: bool):
    m = _num_learners(template)
    state = abstract_state(spec, template)
    w = _sds((m,), jnp.float32) if weighted else None
    act = _sds((m,), jnp.bool_) if with_active else None
    adj = _sds((m, m), jnp.bool_)
    fn = spec.compile()
    return jax.eval_shape(
        lambda s, st, w_, a_, ad: fn(s, st, w_, active=a_, adjacency=ad),
        template, state, w, act, adj)


def check_round(spec: ProtocolSpec, template=None, *,
                weighted: bool = False,
                with_active: bool = True) -> List[Finding]:
    """The round-boundary invariants of one compiled spec: the
    ``StageResult`` that enters the scan carry keeps the input signatures
    exactly — dtypes, shapes AND weak-type bits."""
    template = mixed_template() if template is None else template
    m = _num_learners(template)
    label = _variant_label(spec, weighted=weighted, with_active=with_active)
    out: List[Finding] = []

    def bad(rule, msg):
        out.append(Finding("contracts", rule, f"{label}/round", msg))

    try:
        res = _round_sds(spec, template, weighted=weighted,
                         with_active=with_active)
    except Exception as e:  # noqa: BLE001
        return [Finding("contracts", "trace-error", f"{label}/round",
                        _fmt(e))]

    tmpl_wsig = _sig_tree(template, _wsig)
    ref_wsig = _sig_tree(jax.tree.map(lambda s: _sds(s.shape[1:], s.dtype),
                                      template), _wsig)
    if _sig_tree(res.params, _wsig) != tmpl_wsig:
        bad("round-params",
            f"committed configuration {_sig_tree(res.params, _wsig)} != "
            f"input {tmpl_wsig} — promotion or weak-type drift across the "
            f"round boundary would retrace every scan iteration")
    if _sig_tree(res.state.ref, _wsig) != ref_wsig:
        bad("round-ref",
            f"carried reference {_sig_tree(res.state.ref, _wsig)} != "
            f"input {ref_wsig}")
    state0 = abstract_state(spec, template)
    for fname in ("v", "step"):
        got = getattr(res.state, fname)
        if _wsig(got) != ((), "int32", False):
            bad("round-counters",
                f"state.{fname} must be a strong scalar int32, "
                f"got {_wsig(got)}")
    if _wsig(res.state.rng) != _wsig(state0.rng):
        bad("rng-dtype",
            f"carried RNG key {_wsig(res.state.rng)} != input "
            f"{_wsig(state0.rng)}")
    if _sig_tree(res.state.extra, _wsig) != _sig_tree(state0.extra, _wsig):
        bad("round-extra",
            f"carried extra state {_sig_tree(res.state.extra, _wsig)} != "
            f"initial {_sig_tree(state0.extra, _wsig)}")
    for fname, fval in res.rec._asdict().items():
        if _wsig(fval) != ((), "int32", False):
            bad("ledger-dtype",
                f"CommRecord.{fname} must be a strong scalar int32, "
                f"got {_wsig(fval)}")
    for fname in ("xfers", "link_msgs"):
        if _wsig(getattr(res, fname)) != ((m,), "int32", False):
            bad("ledger-dtype",
                f"{fname} must be a strong ({m},) int32, "
                f"got {_wsig(getattr(res, fname))}")
    return out


def check_layout_equivalence(spec: ProtocolSpec, template=None, *,
                             layouts: Sequence[str] = LAYOUTS,
                             weighted: bool = False,
                             with_active: bool = True) -> List[Finding]:
    """Prove the layouts are abstractly INTERCHANGEABLE: every layout's
    compiled round maps the same inputs to an identical ``StageResult``
    signature tree (shape, dtype, weak type — structure included).

    This is the conformance harness for new fleet backends: a future
    ``layout="sharded"`` plane joins the check by appearing in
    ``spec.LAYOUTS``, and every registered preset is then held to the
    tree reference without writing a single new test."""
    template = mixed_template() if template is None else template
    out: List[Finding] = []
    sigs = {}
    for layout in layouts:
        s = spec.with_params(layout=layout)
        label = _variant_label(s, weighted=weighted, with_active=with_active)
        try:
            res = _round_sds(s, template, weighted=weighted,
                             with_active=with_active)
        except Exception as e:  # noqa: BLE001
            out.append(Finding("contracts", "trace-error",
                               f"{label}/round", _fmt(e)))
            continue
        sigs[layout] = (jax.tree.structure(res, is_leaf=lambda x: x is None),
                        _sig_tree(res, _wsig))
    if len(sigs) < 2:
        return out
    base_layout = next(iter(sigs))
    base = sigs[base_layout]
    name = spec.name or spec.trigger
    for layout, sig in sigs.items():
        if layout != base_layout and sig != base:
            out.append(Finding(
                "contracts", "layout-equivalence",
                f"{name}[{base_layout} vs {layout}]",
                f"abstract StageResult trees differ between layouts: "
                f"{base[1]} vs {sig[1]}"))
    return out


def check_hierarchy(spec: ProtocolSpec, template=None, *,
                    num_clusters: int = DEFAULT_CLUSTERS) -> List[Finding]:
    """Abstract conformance of the two-tier hierarchy for one intra-tier
    spec: the committed configuration keeps the input signatures, and the
    member/aggregator ledger vectors are int32 of the right lengths."""
    from repro.config import HierarchyConfig, ProtocolConfig
    from repro.core.sync.hierarchy import apply_hierarchical, init_hier_state
    from repro.core.sync.spec import resolve_spec

    template = mixed_template() if template is None else template
    m = _num_learners(template)
    g = num_clusters
    name = spec.name or spec.trigger
    label = f"{name}[{spec.param('layout')},hier:{g}]"
    out: List[Finding] = []

    def bad(rule, msg):
        out.append(Finding("contracts", rule, label, msg))

    tiers = HierarchyConfig(num_clusters=g,
                            inter=ProtocolConfig(kind="periodic"))
    ref = jax.tree.map(lambda s: _sds(s.shape[1:], s.dtype), template)
    act = _sds((m,), jnp.bool_)
    try:
        hstate = jax.eval_shape(
            lambda r: init_hier_state(r, tiers, 0, m=m, intra_spec=spec,
                                      inter_spec=resolve_spec(tiers.inter)),
            ref)
        res = jax.eval_shape(
            lambda s, hs, a: apply_hierarchical(spec, tiers, s, hs, None,
                                                active=a),
            template, hstate, act)
    except Exception as e:  # noqa: BLE001
        return [Finding("contracts", "trace-error", label, _fmt(e))]

    if _sig_tree(res.params, _wsig) != _sig_tree(template, _wsig):
        bad("round-params",
            f"hierarchical round output {_sig_tree(res.params, _wsig)} != "
            f"input {_sig_tree(template, _wsig)}")
    for fname, n in (("member_xfers", m), ("member_msgs", m),
                     ("agg_xfers", g), ("agg_msgs", g)):
        if _wsig(getattr(res, fname)) != ((n,), "int32", False):
            bad("ledger-dtype",
                f"{fname} must be a strong ({n},) int32, "
                f"got {_wsig(getattr(res, fname))}")
    for fname, fval in res.rec._asdict().items():
        if _wsig(fval) != ((), "int32", False):
            bad("ledger-dtype",
                f"CommRecord.{fname} must be a strong scalar int32, "
                f"got {_wsig(fval)}")
    return out


# ---------------------------------------------------------------------------
# registry coverage + the full matrix
# ---------------------------------------------------------------------------

def check_registry() -> List[Finding]:
    """Every registered stage must DECLARE a contract; triggers' declared
    extra state must match their abstract ``init_extra`` output."""
    out: List[Finding] = []
    for slot, reg in (("trigger", TRIGGERS), ("cohort", COHORTS),
                      ("aggregate", AGGREGATES), ("commit", COMMITS)):
        for name, rec in sorted(reg.items()):
            if rec.contract is None:
                out.append(Finding(
                    "contracts", "missing-contract", f"{slot}:{name}",
                    "registered without a StageContract — declare the "
                    "stage's shape/dtype promises at registration"))
    m = DEFAULT_M
    for name, rec in sorted(TRIGGERS.items()):
        if rec.contract is None:
            continue
        params = dict(GLOBAL_PARAMS)
        params.update(rec.params)
        try:
            extra = jax.eval_shape(lambda: rec.init_extra(params, m))
        except Exception as e:  # noqa: BLE001
            out.append(Finding("contracts", "trace-error",
                               f"trigger:{name}/init_extra", _fmt(e)))
            continue
        got = _sig_tree(extra)
        declared = dict(rec.contract.extra_state)
        if sorted(got) != sorted(declared):
            out.append(Finding(
                "contracts", "extra-state", f"trigger:{name}",
                f"init_extra keys {sorted(got)} != declared "
                f"{sorted(declared)}"))
            continue
        for k, dt in declared.items():
            shape, got_dt = got[k]
            if got_dt != jnp.dtype(dt).name:
                out.append(Finding(
                    "contracts", "extra-state", f"trigger:{name}",
                    f"extra {k!r} is {got_dt}, declared {dt}"))
    return out


def check_preset_matrix(template=None,
                        presets: Optional[Sequence[str]] = None
                        ) -> List[Finding]:
    """Every registered preset × layout × {weighted, unweighted} ×
    {masked, ideal} combination, plus layout equivalence per preset and
    the two-tier hierarchy for every coordinator preset."""
    template = mixed_template() if template is None else template
    out: List[Finding] = []
    names = sorted(PROTOCOLS) if presets is None else list(presets)
    for name in names:
        preset = registry.get_protocol(name)
        for layout in LAYOUTS:
            s = preset.with_params(layout=layout)
            for weighted in (False, True):
                for with_active in (True, False):
                    out += check_spec(s, template, weighted=weighted,
                                      with_active=with_active)
                    out += check_round(s, template, weighted=weighted,
                                       with_active=with_active)
        for weighted in (False, True):
            out += check_layout_equivalence(preset, template,
                                            weighted=weighted)
        if preset.uses_coordinator:
            for layout in LAYOUTS:
                out += check_hierarchy(preset.with_params(layout=layout),
                                       template)
    return out


def check_all(template=None) -> List[Finding]:
    """The CI gate: registry coverage + the full preset matrix."""
    return check_registry() + check_preset_matrix(template)
