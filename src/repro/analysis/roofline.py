"""Three-term roofline model from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

``cost_analysis()`` on the compiled executable reports the per-device
(post-SPMD-partition) module, so its flops/bytes are already per chip;
collective wire bytes come from ``repro.analysis.hlo``. MODEL_FLOPS uses
the 6·N·D rule (N = params, D = tokens; N_active for MoE) to measure how
much of the compiled compute is useful.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.analysis import hlo as hlo_mod
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


@dataclass
class RooflineReport:
    name: str
    num_devices: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None          # 6*N*D (total, all chips)
    useful_fraction: Optional[float] = None      # model / (hlo * chips)
    collectives: dict = field(default_factory=dict)
    memory_stats: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in (
            "name", "num_devices", "flops_per_chip", "bytes_per_chip",
            "wire_bytes_per_chip", "compute_s", "memory_s", "collective_s",
            "bottleneck", "model_flops", "useful_fraction", "collectives",
            "memory_stats")}


def model_flops_for(cfg, shape, mode: str) -> float:
    """6·N·D rule. Train counts fwd+bwd (6ND); prefill 2ND; decode 2N·B."""
    n = cfg.active_param_count()
    if mode in ("train", "train_dynamic", "train_periodic"):
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(name: str, compiled, num_devices: int,
            model_flops: Optional[float] = None,
            peak_flops: float = PEAK_FLOPS_BF16,
            hbm_bw: float = HBM_BW,
            link_bw: float = ICI_BW_PER_LINK) -> RooflineReport:
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    stats = hlo_mod.parse_collectives(compiled.as_text(), num_devices)
    wire = stats.total_wire_bytes

    compute_s = flops / peak_flops
    memory_s = bytes_acc / hbm_bw
    collective_s = wire / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = {}
    try:
        ms = compiled.memory_analysis()
        if ms is not None:
            mem = {
                "argument_bytes": int(ms.argument_size_in_bytes),
                "output_bytes": int(ms.output_size_in_bytes),
                "temp_bytes": int(ms.temp_size_in_bytes),
                "alias_bytes": int(ms.alias_size_in_bytes),
            }
    except Exception:
        pass

    useful = None
    if model_flops and flops:
        useful = model_flops / (flops * num_devices)
    return RooflineReport(
        name=name, num_devices=num_devices,
        flops_per_chip=flops, bytes_per_chip=bytes_acc,
        wire_bytes_per_chip=wire,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_fraction=useful, collectives=stats.summary(),
        memory_stats=mem)


# ---------------------------------------------------------------------------
# jaxpr-level FLOP estimation (static, pre-compilation)
# ---------------------------------------------------------------------------
#
# ``analyze`` above costs a COMPILED executable; the telemetry plane's
# cost attribution (repro.telemetry.costs) needs per-BRANCH costs of an
# uncompiled round — how much compute the sync branch of the protocol's
# ``lax.cond`` would burn vs. its skip branch — which only the jaxpr still
# exposes (XLA folds the branches into one module). This is a first-order
# traversal: matmuls/convs counted exactly, reductions and elementwise ops
# at one FLOP per element, control flow by its trip count/worst branch.

def _size(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return math.prod(shape) if shape else 1


def _out_size(eqn) -> int:
    return sum(_size(getattr(v, "aval", None)) for v in eqn.outvars)


def _sub_jaxprs(params):
    """Every sub-jaxpr referenced by one equation's params."""
    subs = []
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "jaxpr"):          # ClosedJaxpr
                subs.append(v.jaxpr)
            elif hasattr(v, "eqns"):         # raw Jaxpr
                subs.append(v)
    return subs


def _dot_general_flops(eqn) -> float:
    (lc, rc), (lb, _rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = math.prod(lhs[i] for i in lb) if lb else 1
    contract = math.prod(lhs[i] for i in lc) if lc else 1
    lfree = math.prod(d for i, d in enumerate(lhs)
                      if i not in lb and i not in lc)
    rfree = math.prod(d for i, d in enumerate(rhs)
                      if i not in _rb and i not in rc)
    return 2.0 * batch * contract * lfree * rfree


def _conv_flops(eqn) -> float:
    out = _size(eqn.outvars[0].aval)
    rhs = eqn.invars[1].aval.shape
    dn = eqn.params.get("dimension_numbers")
    out_ch = rhs[dn.rhs_spec[0]] if dn is not None else rhs[-1]
    # each output element = one dot over the kernel's in-features window
    return 2.0 * out * (math.prod(rhs) / max(out_ch, 1))


_REDUCE_PRIMS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
    "cummin", "cumprod",
})

# structural primitives that only forward values — no arithmetic
_FREE_PRIMS = frozenset({
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "convert_element_type",
    "copy", "gather", "scatter", "rev", "pad", "iota", "stop_gradient",
    "split",
})


def _eqn_flops(eqn) -> float:
    name = eqn.primitive.name
    params = eqn.params
    if name == "scan":
        body = params.get("jaxpr")
        return params.get("length", 1) * jaxpr_flops(body)
    if name == "while":
        # trip count is dynamic: count one body + one cond evaluation
        return (jaxpr_flops(params.get("body_jaxpr"))
                + jaxpr_flops(params.get("cond_jaxpr")))
    if name == "cond":
        return max((jaxpr_flops(b) for b in params.get("branches", ())),
                   default=0.0)
    if name == "dot_general":
        return _dot_general_flops(eqn)
    if name == "conv_general_dilated":
        return _conv_flops(eqn)
    if name in _REDUCE_PRIMS:
        return float(_size(eqn.invars[0].aval))
    if name in _FREE_PRIMS:
        return 0.0
    subs = _sub_jaxprs(params)
    if subs:          # pjit / remat / custom_* / closed_call wrappers
        return sum(jaxpr_flops(s) for s in subs)
    # elementwise default: one FLOP per output element
    return float(_out_size(eqn))


def jaxpr_flops(jaxpr) -> float:
    """First-order FLOP estimate of a jaxpr (``ClosedJaxpr`` or raw
    ``Jaxpr``): matmul/conv exactly, reductions/elementwise at one FLOP
    per element, ``scan`` by trip count, ``cond`` by its worst branch."""
    if jaxpr is None:
        return 0.0
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    return float(sum(_eqn_flops(e) for e in jx.eqns))


def format_table(reports) -> str:
    hdr = (f"{'program':44s} {'chips':>5s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'bneck':>10s} "
           f"{'useful':>7s} {'arg_GB':>8s} {'temp_GB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        arg = r.memory_stats.get("argument_bytes", 0) / 1e9
        tmp = r.memory_stats.get("temp_bytes", 0) / 1e9
        uf = f"{r.useful_fraction:.3f}" if r.useful_fraction else "-"
        lines.append(
            f"{r.name:44s} {r.num_devices:5d} {r.compute_s:10.3e} "
            f"{r.memory_s:10.3e} {r.collective_s:10.3e} {r.bottleneck:>10s} "
            f"{uf:>7s} {arg:8.2f} {tmp:8.2f}")
    return "\n".join(lines)
