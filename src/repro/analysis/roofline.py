"""Three-term roofline model from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

``cost_analysis()`` on the compiled executable reports the per-device
(post-SPMD-partition) module, so its flops/bytes are already per chip;
collective wire bytes come from ``repro.analysis.hlo``. MODEL_FLOPS uses
the 6·N·D rule (N = params, D = tokens; N_active for MoE) to measure how
much of the compiled compute is useful.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.analysis import hlo as hlo_mod
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


@dataclass
class RooflineReport:
    name: str
    num_devices: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None          # 6*N*D (total, all chips)
    useful_fraction: Optional[float] = None      # model / (hlo * chips)
    collectives: dict = field(default_factory=dict)
    memory_stats: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in (
            "name", "num_devices", "flops_per_chip", "bytes_per_chip",
            "wire_bytes_per_chip", "compute_s", "memory_s", "collective_s",
            "bottleneck", "model_flops", "useful_fraction", "collectives",
            "memory_stats")}


def model_flops_for(cfg, shape, mode: str) -> float:
    """6·N·D rule. Train counts fwd+bwd (6ND); prefill 2ND; decode 2N·B."""
    n = cfg.active_param_count()
    if mode in ("train", "train_dynamic", "train_periodic"):
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(name: str, compiled, num_devices: int,
            model_flops: Optional[float] = None,
            peak_flops: float = PEAK_FLOPS_BF16,
            hbm_bw: float = HBM_BW,
            link_bw: float = ICI_BW_PER_LINK) -> RooflineReport:
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    stats = hlo_mod.parse_collectives(compiled.as_text(), num_devices)
    wire = stats.total_wire_bytes

    compute_s = flops / peak_flops
    memory_s = bytes_acc / hbm_bw
    collective_s = wire / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = {}
    try:
        ms = compiled.memory_analysis()
        if ms is not None:
            mem = {
                "argument_bytes": int(ms.argument_size_in_bytes),
                "output_bytes": int(ms.output_size_in_bytes),
                "temp_bytes": int(ms.temp_size_in_bytes),
                "alias_bytes": int(ms.alias_size_in_bytes),
            }
    except Exception:
        pass

    useful = None
    if model_flops and flops:
        useful = model_flops / (flops * num_devices)
    return RooflineReport(
        name=name, num_devices=num_devices,
        flops_per_chip=flops, bytes_per_chip=bytes_acc,
        wire_bytes_per_chip=wire,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_fraction=useful, collectives=stats.summary(),
        memory_stats=mem)


def format_table(reports) -> str:
    hdr = (f"{'program':44s} {'chips':>5s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'bneck':>10s} "
           f"{'useful':>7s} {'arg_GB':>8s} {'temp_GB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        arg = r.memory_stats.get("argument_bytes", 0) / 1e9
        tmp = r.memory_stats.get("temp_bytes", 0) / 1e9
        uf = f"{r.useful_fraction:.3f}" if r.useful_fraction else "-"
        lines.append(
            f"{r.name:44s} {r.num_devices:5d} {r.compute_s:10.3e} "
            f"{r.memory_s:10.3e} {r.collective_s:10.3e} {r.bottleneck:>10s} "
            f"{uf:>7s} {arg:8.2f} {tmp:8.2f}")
    return "\n".join(lines)
