"""``python -m repro.analysis`` — the static-analysis CI gate.

    python -m repro.analysis --check-all          # contracts + audit + lint
    python -m repro.analysis --contracts          # abstract spec checking
    python -m repro.analysis --audit              # jaxpr audit of all presets
    python -m repro.analysis --lint [paths...]    # AST repo lint

Exit status 0 = clean, 1 = findings (printed one per line). The whole
gate is ``jax.eval_shape`` + ``jax.make_jaxpr`` + ``ast`` — no FLOPs, no
devices, seconds of wall-clock — so it runs tier-1 in CI.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

from repro.analysis.report import Finding, render_findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis gate: contract checking (eval_shape), "
                    "jaxpr audit, repo lint.")
    ap.add_argument("--check-all", action="store_true",
                    help="run every analyzer (the CI gate)")
    ap.add_argument("--contracts", action="store_true",
                    help="abstract contract checking of every registered "
                         "preset x layout x hierarchy")
    ap.add_argument("--audit", action="store_true",
                    help="jaxpr audit of every preset's scanned round")
    ap.add_argument("--lint", action="store_true",
                    help="AST lint (bare asserts, version probes, missing "
                         "contracts, network purity)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories for --lint (default: the "
                         "installed repro package)")
    args = ap.parse_args(argv)

    run_contracts = args.check_all or args.contracts
    run_audit = args.check_all or args.audit
    run_lint = args.check_all or args.lint
    if not (run_contracts or run_audit or run_lint):
        ap.print_help()
        return 2

    findings: List[Finding] = []
    t0 = time.perf_counter()
    if run_contracts or run_audit:
        import repro.core.sync  # noqa: F401 — populate the registries
    if run_contracts:
        from repro.analysis.contracts import check_all
        findings += check_all()
    if run_audit:
        from repro.analysis.audit import audit_presets
        findings += audit_presets()
    if run_lint:
        from repro.analysis.lint import lint_paths
        findings += lint_paths(args.paths or None)

    dt = time.perf_counter() - t0
    if findings:
        print(render_findings(findings))
        print(f"{len(findings)} finding(s) in {dt:.1f}s", file=sys.stderr)
        return 1
    ran = [n for n, r in (("contracts", run_contracts), ("audit", run_audit),
                          ("lint", run_lint)) if r]
    print(f"OK: {' + '.join(ran)} clean in {dt:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
