"""AST lint: the repo-shape rules no runtime test can enforce.

Five rules over ``src/repro`` (pure ``ast`` — no imports of the linted
code, so a file with a syntax error is itself a finding, not a crash):

* **bare-assert** — no ``assert`` statements in library code: they
  vanish under ``python -O`` and turn contract violations into silent
  corruption. Raise ``ValueError``/``KeyError`` with a message instead.
* **jax-version** — ``jax.__version__`` may be consulted ONLY in
  ``compat.py``: every version probe outside the compat shim is a
  lurking fork in behavior that the pinned-toolchain CI cannot see.
* **contract-required** — every ``register_trigger``/``register_cohort``/
  ``register_aggregate``/``register_commit`` call must pass a
  non-None ``contract=`` (the declaration ``repro.analysis.contracts``
  verifies abstractly).
* **print-outside-cli** — no bare ``print(`` in library code: output
  goes through ``repro.telemetry.get_logger().event(...)`` so CLIs
  choose the formatter and library callers stay silent. Exempt:
  ``__main__.py`` files (they ARE the CLI) and the top-level ``main()``
  of ``launch/`` entry-point modules.
* **network-impure** — modules under ``repro/network/`` must be pure
  functions of ``(seed, t)``: no wall-clock (``time``/``datetime``), no
  stateful RNG (``random``, ``secrets``, ``numpy.random``), no carried
  JAX keys (``jax.random.split`` — derive per-round keys with
  ``fold_in`` on the seed instead), no ``global`` statements. This is
  what makes availability traces replayable from a scalar seed.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional

from repro.analysis.report import Finding

__all__ = ["lint_source", "lint_file", "lint_paths", "default_root",
           "REGISTER_FUNCS"]

REGISTER_FUNCS = frozenset({
    "register_trigger", "register_cohort", "register_aggregate",
    "register_commit",
})

_IMPURE_MODULES = frozenset({"time", "random", "datetime", "secrets"})


def default_root() -> str:
    """The installed ``repro`` package directory — what ``--check-all``
    lints when no paths are given."""
    import repro
    # repro is a namespace package (no __init__.py): __file__ is None,
    # the package directory lives in __path__
    return os.path.abspath(next(iter(repro.__path__)))


def _is_compat(path: str) -> bool:
    return os.path.basename(path) == "compat.py"


def _is_network(path: str) -> bool:
    parts = path.replace(os.sep, "/").split("/")
    return "network" in parts


def _is_main_file(path: str) -> bool:
    return os.path.basename(path) == "__main__.py"


def _is_launch(path: str) -> bool:
    parts = path.replace(os.sep, "/").split("/")
    return "launch" in parts


def _main_ranges(tree: ast.Module):
    """Line spans of top-level ``def main`` — the CLI entry points where
    ``print`` is legitimate in a ``launch/`` module."""
    return [(f.lineno, f.end_lineno or f.lineno)
            for f in tree.body
            if isinstance(f, ast.FunctionDef) and f.name == "main"]


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def _dotted(node) -> str:
    """'jax.random.split' for a nested Attribute/Name chain, else ''."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def lint_source(src: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text. ``path`` scopes the path-dependent
    rules (compat exemption, network purity) and labels the findings."""
    findings: List[Finding] = []

    def bad(rule, node, msg):
        findings.append(Finding("lint", rule, f"{path}:{node.lineno}", msg))

    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("lint", "syntax-error", f"{path}:{e.lineno or 0}",
                        str(e.msg))]

    compat = _is_compat(path)
    network = _is_network(path)
    cli_file = _is_main_file(path)
    main_spans = (_main_ranges(tree)
                  if _is_launch(path) and not cli_file else [])
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            bad("bare-assert", node,
                "bare assert in library code — it vanishes under "
                "python -O; raise ValueError/KeyError with a message")
        elif isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            if dotted == "jax.__version__" and not compat:
                bad("jax-version", node,
                    "jax.__version__ consulted outside compat.py — "
                    "version probes live in the compat shim only")
            if network and dotted in ("jax.random.split",
                                      "np.random", "numpy.random"):
                bad("network-impure", node,
                    f"{dotted} in a network module — availability and "
                    f"topology must be pure in (seed, t); derive keys "
                    f"with jax.random.fold_in on the scalar seed")
        elif isinstance(node, ast.Call):
            if (isinstance(node.func, ast.Name) and node.func.id == "print"
                    and not cli_file
                    and not any(lo <= node.lineno <= hi
                                for lo, hi in main_spans)):
                bad("print-outside-cli", node,
                    "bare print() in library code — emit a structured "
                    "event via repro.telemetry.get_logger().event(...) "
                    "and let the CLI attach console_handler()")
            if _call_name(node) in REGISTER_FUNCS:
                kw = {k.arg: k.value for k in node.keywords}
                contract = kw.get("contract")
                if contract is None or (isinstance(contract, ast.Constant)
                                        and contract.value is None):
                    bad("contract-required", node,
                        f"{_call_name(node)} without a StageContract — "
                        f"declare the stage's shape/dtype promises "
                        f"(repro.analysis.contracts verifies them)")
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            if not network:
                continue
            if isinstance(node, ast.Import):
                mods = [a.name.split(".")[0] for a in node.names]
            else:
                mods = [(node.module or "").split(".")[0]]
            for mod in mods:
                if mod in _IMPURE_MODULES:
                    bad("network-impure", node,
                        f"import of {mod!r} in a network module — "
                        f"availability and topology must be pure in "
                        f"(seed, t)")
        elif isinstance(node, ast.Global) and network:
            bad("network-impure", node,
                "global statement in a network module — availability "
                "and topology must be pure in (seed, t)")
    return findings


def lint_file(path: str) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_paths(paths: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint every ``.py`` file under the given files/directories
    (default: the installed ``repro`` package)."""
    if paths is None:
        paths = [default_root()]
    findings: List[Finding] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__")
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        findings += lint_file(os.path.join(dirpath, fn))
        else:
            findings += lint_file(p)
    return findings
