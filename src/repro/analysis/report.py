"""Shared finding type + rendering for the static-analysis toolkit.

Every analyzer (``contracts``, ``audit``, ``lint``) returns a flat list of
``Finding``s; an empty list is a clean pass. The CLI
(``python -m repro.analysis``) renders them one per line and exits
non-zero when any survive.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence


class Finding(NamedTuple):
    """One verified violation: which tool, which rule, where, and what."""
    tool: str       # "contracts" | "audit" | "lint"
    rule: str       # short rule slug, e.g. "int32-accumulator"
    where: str      # spec/stage/file:line the finding anchors to
    message: str    # one-sentence statement of the violation

    def render(self) -> str:
        return f"{self.tool}:{self.rule} {self.where}: {self.message}"


def render_findings(findings: Sequence[Finding]) -> str:
    return "\n".join(f.render() for f in findings)
