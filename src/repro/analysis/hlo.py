"""Collective-byte accounting from post-partition HLO text.

``cost_analysis()`` has no collective-byte entry, so we parse the compiled
module text. The compiled module is the per-device (SPMD-partitioned)
program, so every shape we read is a *per-device* shape; the returned byte
counts are bytes-on-the-wire per device, using standard ring-algorithm
factors:

    all-reduce        2 * B * (n-1)/n
    all-gather        B_out * (n-1)/n
    reduce-scatter    B_in * (n-1)/n
    all-to-all        B * (n-1)/n
    collective-permute B

(n = collective group size parsed from ``replica_groups``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# instruction definition: %name = <shape> opcode(...)  /  %name = (tuple) op(...)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of one shape token list (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    line: str

    @property
    def wire_bytes(self) -> float:
        n = max(self.group_size, 1)
        f = (n - 1) / n if n > 1 else 0.0
        if self.kind == "all-reduce":
            return 2.0 * self.operand_bytes * f
        if self.kind == "all-gather":
            return self.result_bytes * f
        if self.kind == "reduce-scatter":
            return self.operand_bytes * f
        if self.kind == "all-to-all":
            return self.operand_bytes * f
        return float(self.operand_bytes)  # collective-permute


@dataclass
class CollectiveStats:
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    def by_kind(self) -> Dict[str, Tuple[int, float]]:
        out: Dict[str, Tuple[int, float]] = {}
        agg: Dict[str, List[CollectiveOp]] = defaultdict(list)
        for o in self.ops:
            agg[o.kind].append(o)
        for k, v in agg.items():
            out[k] = (len(v), sum(o.wire_bytes for o in v))
        return out

    def summary(self) -> dict:
        return {
            "total_wire_bytes": self.total_wire_bytes,
            "num_ops": len(self.ops),
            "by_kind": {k: {"count": c, "wire_bytes": b}
                        for k, (c, b) in self.by_kind().items()},
        }


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOA_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]<=[...]
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, num_devices: int = 1) -> CollectiveStats:
    """Parse the per-device HLO module for collective ops.

    Async pairs (``all-gather-start``/``-done``) are counted once on the
    start op. ``num_devices`` is the fallback group size when
    ``replica_groups`` is empty (= all devices).
    """
    shapes: Dict[str, str] = {}
    defs: List[Tuple[str, str, str, str]] = []   # (name, shape, op, line)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_str
        defs.append((name, shape_str, op, line))

    stats = CollectiveStats()
    for name, shape_str, op, line in defs:
        base = op[:-6] if op.endswith("-start") else op
        if base not in COLLECTIVE_OPS or op.endswith("-done"):
            continue
        # operands: %names inside the call parens (skip metadata/regions)
        try:
            arg_str = line.split(op + "(", 1)[1]
        except IndexError:
            continue
        arg_str = arg_str.split(")", 1)[0]
        operand_bytes = 0
        for om in _OPERAND_RE.finditer(arg_str):
            operand_bytes += _shape_bytes(shapes.get(om.group(1), ""))
        result_bytes = _shape_bytes(shape_str)
        if op.endswith("-start") and base == "all-gather":
            # start result is a tuple (operand, result); take the larger half
            result_bytes = max(result_bytes - operand_bytes, operand_bytes)
        stats.ops.append(CollectiveOp(
            kind=base, result_bytes=result_bytes,
            operand_bytes=operand_bytes,
            group_size=_group_size(line, num_devices), line=line.strip()))
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    """Count instruction definitions of a given opcode (e.g. 'fusion')."""
    n = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m and m.group(3) == opname:
            n += 1
    return n
