"""Sharding-constraint hook usable from model code without a mesh.

Model code calls ``constrain(x, ("data", None, "model"))`` with *logical*
axis names. When no mesh context is active this is a no-op, so the same
model runs unmodified on a single CPU device (tests, simulator) and under
GSPMD (dry-run, production launch).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _active():
    return getattr(_state, "ctx", None)


@contextmanager
def mesh_context(mesh, rules: dict | None = None):
    """Activate ``mesh`` for ``constrain`` calls.

    ``rules`` maps logical axis names to (tuples of) mesh axis names, e.g.
    ``{"batch": ("pod", "data"), "embed": "data", "heads": "model"}``.
    Logical names missing from the rules are unsharded.
    """
    prev = _active()
    _state.ctx = (mesh, rules or {})
    try:
        yield
    finally:
        _state.ctx = prev


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def logical_to_spec(names, rules, mesh=None, dims=None) -> P:
    """Map logical names to mesh axes; with ``dims`` given, drop any axis
    that does not evenly divide its dim (e.g. 25 heads over a 16-way axis).
    Duplicate mesh axes are dropped (first dim that can use an axis keeps
    it) — lets callers express fallbacks like ("expert", None, "tp")."""
    parts = []
    used: set = set()
    for i, n in enumerate(names):
        axis = rules.get(n) if n is not None else None
        if axis is not None and mesh is not None and dims is not None:
            if dims[i] % _axis_size(mesh, axis) != 0:
                axis = None
        if axis is not None:
            members = set(axis) if isinstance(axis, tuple) else {axis}
            if members & used:
                axis = None
            else:
                used |= members
        parts.append(axis)
    return P(*parts)


def constrain(x, names):
    """Apply a sharding constraint using logical axis ``names`` (or no-op)."""
    ctx = _active()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(names, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_weight(x, names):
    """JIT weight-gather constraint — applied only when the active rules set
    ``_gather_weights`` (default True). Training/prefill programs gather the
    (small) weights to keep the (huge) batch activations in place; decode
    programs (a handful of tokens) leave weights fully sharded and let the
    tiny activations move instead (EXPERIMENTS.md §Perf, decode iteration).
    """
    ctx = _active()
    if ctx is None:
        return x
    _, rules = ctx
    if not rules.get("_gather_weights", True):
        return x
    return constrain(x, names)
