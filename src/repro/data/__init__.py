from repro.data.synthetic import (  # noqa: F401
    SyntheticMNIST, GraphicalModelStream, TokenStream, DeepDriveStream,
)
from repro.data.pipeline import LearnerStreams  # noqa: F401
