"""Per-learner streaming batches (paper Section 2 setting).

``LearnerStreams`` wraps a data source and yields, each round t, a pytree of
batches with leading (m, B, ...) leaves — learner i's sample E_t^i. Supports
unbalanced sampling rates B^i (Appendix C / Algorithm 2) by padding to
max(B^i) with repeated samples and exposing per-learner weights.

``next_chunk(n)`` produces the (n, m, B, ...) layout the scanned round
driver consumes. When the source implements the pure ``concept()`` /
``sample_from()`` protocol (see ``repro.data.synthetic``), the whole chunk
is drawn by ONE jitted ``lax.scan`` whose per-round key derivation is
identical to ``next()``'s — so chunked and per-round sampling yield
bitwise-equal batches while eliminating the m*n host dispatches that
dominated simulator wall-clock.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


class LearnerStreams:
    def __init__(self, source, m: int, batch: int = 10, seed: int = 0,
                 batch_sizes: Optional[Sequence[int]] = None, **sample_kw):
        self.source = source
        self.m = m
        self.batch = batch
        self.batch_sizes = batch_sizes
        self.sample_kw = sample_kw
        self._key = jax.random.PRNGKey(seed ^ 0x5EED)
        self._round = 0
        self._chunk_samplers: dict = {}

    @property
    def weights(self) -> Optional[jnp.ndarray]:
        if self.batch_sizes is None:
            return None
        return jnp.asarray(self.batch_sizes, jnp.float32)

    def next(self):
        """Batches for one round: leaves (m, B, ...)."""
        self._key, sub = jax.random.split(self._key)
        keys = jax.random.split(sub, self.m)
        if self.batch_sizes is None:
            batches = [self.source.sample(k, self.batch, **self.sample_kw)
                       for k in keys]
        else:
            bmax = max(self.batch_sizes)
            batches = []
            for k, bi in zip(keys, self.batch_sizes):
                b = self.source.sample(k, bi, **self.sample_kw)
                if bi < bmax:
                    reps = -(-bmax // bi)
                    b = jax.tree.map(
                        lambda x: jnp.tile(
                            x, (reps,) + (1,) * (x.ndim - 1))[:bmax], b)
                batches.append(b)
        self._round += 1
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    # ------------------------------------------------------------------
    # chunked sampling (scanned-driver input layout)
    # ------------------------------------------------------------------

    @property
    def fused_chunks(self) -> bool:
        """True when whole chunks can be drawn in one compiled program."""
        return self.batch_sizes is None and hasattr(self.source, "sample_from")

    def _chunk_sampler(self, n: int):
        fn = self._chunk_samplers.get(n)
        if fn is None:
            m, batch, kw, source = self.m, self.batch, self.sample_kw, self.source

            def sample_chunk(key, concept):
                def per_round(key, _):
                    key, sub = jax.random.split(key)      # == next()'s splits
                    keys = jax.random.split(sub, m)
                    b = jax.vmap(
                        lambda k: source.sample_from(concept, k, batch, **kw)
                    )(keys)
                    return key, b

                return jax.lax.scan(per_round, key, None, length=n)

            fn = self._chunk_samplers[n] = jax.jit(sample_chunk)
        return fn

    def next_chunk(self, n: int, on_round=None):
        """Batches for n consecutive rounds: leaves (n, m, B, ...), the
        input layout of ``DecentralizedLearner.run_chunk``. ``on_round(i)``
        (i = 0..n-1) runs before round i's samples are drawn — the hook for
        host-side per-round events such as concept drift; passing it forces
        the per-round host path (the concept may change mid-chunk)."""
        if n < 1:
            raise ValueError(f"chunk length must be >= 1, got {n}")
        if on_round is None and self.fused_chunks:
            self._key, batches = self._chunk_sampler(n)(
                self._key, self.source.concept())
            self._round += n
            return batches
        rounds = []
        for i in range(n):
            if on_round is not None:
                on_round(i)
            rounds.append(self.next())
        return jax.tree.map(lambda *xs: jnp.stack(xs), *rounds)
