"""Per-learner streaming batches (paper Section 2 setting).

``LearnerStreams`` wraps a data source and yields, each round t, a pytree of
batches with leading (m, B, ...) leaves — learner i's sample E_t^i. Supports
unbalanced sampling rates B^i (Appendix C / Algorithm 2) by padding to
max(B^i) with repeated samples and exposing per-learner weights.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


class LearnerStreams:
    def __init__(self, source, m: int, batch: int = 10, seed: int = 0,
                 batch_sizes: Optional[Sequence[int]] = None, **sample_kw):
        self.source = source
        self.m = m
        self.batch = batch
        self.batch_sizes = batch_sizes
        self.sample_kw = sample_kw
        self._key = jax.random.PRNGKey(seed ^ 0x5EED)
        self._round = 0

    @property
    def weights(self) -> Optional[jnp.ndarray]:
        if self.batch_sizes is None:
            return None
        return jnp.asarray(self.batch_sizes, jnp.float32)

    def next(self):
        """Batches for one round: leaves (m, B, ...)."""
        self._key, sub = jax.random.split(self._key)
        keys = jax.random.split(sub, self.m)
        if self.batch_sizes is None:
            batches = [self.source.sample(k, self.batch, **self.sample_kw)
                       for k in keys]
        else:
            bmax = max(self.batch_sizes)
            batches = []
            for k, bi in zip(keys, self.batch_sizes):
                b = self.source.sample(k, bi, **self.sample_kw)
                if bi < bmax:
                    reps = -(-bmax // bi)
                    b = jax.tree.map(
                        lambda x: jnp.tile(
                            x, (reps,) + (1,) * (x.ndim - 1))[:bmax], b)
                batches.append(b)
        self._round += 1
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
