"""Synthetic data sources (the container is offline — no dataset downloads).

* ``SyntheticMNIST`` — an MNIST-like 10-class image task: class templates
  (blurred random blobs) + per-sample noise and random shifts. Learnable to
  high accuracy by the paper's CNN, which is what the protocol experiments
  need (the paper's claims concern communication dynamics, not MNIST
  itself).
* ``GraphicalModelStream`` — the paper's concept-drift source (App. A.3):
  binary labels from a random linear-Gaussian graphical model over R^50
  [Bshouty & Long 2012]; a *drift* resamples the generating model. Drifts
  trigger at random with probability p per round (paper: p = 0.001).
* ``TokenStream`` — LM token stream from a sampled bigram Markov chain, for
  decentralized LLM training examples; drift resamples the chain.
* ``DeepDriveStream`` — front-camera-like frames (procedural road curves) +
  steering-angle targets for the deep-driving case study.

All sources are deterministic given a seed, support per-learner streams
(learner i gets an independent slice of the distribution) and a shared
underlying concept so data is iid across learners (the paper's assumption).

Each source also exposes the pure-function sampling protocol used by the
scanned round driver (``LearnerStreams.next_chunk``):

    concept()                     -> pytree of arrays defining the current
                                     generating distribution (changes on
                                     drift, stable shape/dtype)
    sample_from(concept, key, B)  -> batch; pure jax function of its inputs

``sample(key, B)`` == ``sample_from(concept(), key, B)``. Because drift
only changes the *values* of the concept pytree, a jitted sampler keyed on
shapes never retraces across drifts.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticMNIST:
    """10-class 28x28 images from class templates + noise + translation."""

    def __init__(self, seed: int = 0, num_classes: int = 10,
                 image_size: int = 28, noise: float = 0.35):
        self.num_classes = num_classes
        self.image_size = image_size
        self.noise = noise
        rng = np.random.RandomState(seed)
        # smooth class templates: random low-frequency patterns
        freqs = rng.randn(num_classes, 4, 4)
        t = np.linspace(0, 2 * np.pi, image_size)
        basis = np.stack([np.sin((i + 1) * t / 2) for i in range(4)])  # (4,S)
        self.templates = np.einsum("cij,ih,jw->chw", freqs, basis, basis)
        self.templates /= np.abs(self.templates).max(axis=(1, 2), keepdims=True)
        self._templates_dev = jnp.asarray(self.templates, jnp.float32)

    def concept(self):
        return self._templates_dev

    def sample_from(self, concept, key, batch: int):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        labels = jax.random.randint(k1, (batch,), 0, self.num_classes)
        temps = concept[labels]                                        # (B,H,W)
        shift = jax.random.randint(k2, (batch, 2), -2, 3)
        temps = jax.vmap(lambda img, s: jnp.roll(img, s, axis=(0, 1)))(temps, shift)
        imgs = temps + self.noise * jax.random.normal(k3, temps.shape)
        return {"x": imgs[..., None], "y": labels}

    def sample(self, key, batch: int):
        return self.sample_from(self.concept(), key, batch)


class GraphicalModelStream:
    """Random linear-Gaussian graphical model over R^d, binary labels.

    A concept is (W, w): latent h ~ N(0, I_k), x = W h + noise,
    y = sign(w . h). ``maybe_drift`` resamples the concept with prob. p.
    """

    def __init__(self, seed: int = 0, d: int = 50, k: int = 10,
                 drift_prob: float = 0.001):
        self.d, self.k = d, k
        self.drift_prob = drift_prob
        self._rng = np.random.RandomState(seed)
        self._resample()
        self.drift_count = 0

    def _resample(self):
        self.W = jnp.asarray(self._rng.randn(self.d, self.k) / np.sqrt(self.k),
                             jnp.float32)
        self.w = jnp.asarray(self._rng.randn(self.k), jnp.float32)

    def maybe_drift(self) -> bool:
        if self._rng.rand() < self.drift_prob:
            self._resample()
            self.drift_count += 1
            return True
        return False

    def force_drift(self):
        self._resample()
        self.drift_count += 1

    def concept(self):
        return (self.W, self.w)

    def sample_from(self, concept, key, batch: int):
        W, w = concept
        k1, k2 = jax.random.split(key)
        h = jax.random.normal(k1, (batch, self.k))
        x = h @ W.T + 0.1 * jax.random.normal(k2, (batch, self.d))
        y = (h @ w > 0).astype(jnp.int32)
        return {"x": x, "y": y}

    def sample(self, key, batch: int):
        return self.sample_from(self.concept(), key, batch)


class TokenStream:
    """Bigram-Markov token stream for LM training; drift resamples the chain."""

    def __init__(self, seed: int = 0, vocab: int = 512, temp: float = 1.0):
        self.vocab = vocab
        self._rng = np.random.RandomState(seed)
        self.temp = temp
        self._resample()

    def _resample(self):
        logits = self._rng.randn(self.vocab, self.vocab) * self.temp
        self.logits = jnp.asarray(logits, jnp.float32)

    def force_drift(self):
        self._resample()

    def concept(self):
        return self.logits

    def sample_from(self, concept, key, batch: int, seq_len: int):
        def chain(k):
            k0, k = jax.random.split(k)
            first = jax.random.randint(k0, (), 0, self.vocab)

            def step(tok, kk):
                nxt = jax.random.categorical(kk, concept[tok])
                return nxt, nxt

            _, toks = jax.lax.scan(step, first, jax.random.split(k, seq_len))
            return jnp.concatenate([first[None], toks[:-1]]), toks

        keys = jax.random.split(key, batch)
        tokens, labels = jax.vmap(chain)(keys)
        return {"tokens": tokens, "labels": labels}

    def sample(self, key, batch: int, seq_len: int):
        return self.sample_from(self.concept(), key, batch, seq_len)


class DeepDriveStream:
    """Procedural road frames -> steering angle (deep-driving case study).

    A 'road' is a quadratic curve; the frame renders the road as bright
    pixels on a dark background from a forward-looking viewpoint; the target
    steering angle is proportional to the curvature ahead. Concept drift =
    changing road texture/curvature statistics (e.g. a new country).
    """

    def __init__(self, seed: int = 0, height: int = 68, width: int = 320,
                 curvature_scale: float = 1.0):
        self.h, self.w = height, width
        self._rng = np.random.RandomState(seed)
        self.curvature_scale = curvature_scale

    def force_drift(self):
        self.curvature_scale = float(self._rng.uniform(0.5, 2.0))

    def concept(self):
        return jnp.float32(self.curvature_scale)

    def sample_from(self, concept, key, batch: int):
        k1, k2, k3 = jax.random.split(key, 3)
        curv = concept * jax.random.normal(k1, (batch,)) * 0.3
        offset = jax.random.normal(k2, (batch,)) * 0.2
        ys = jnp.linspace(1.0, 0.0, self.h)                   # depth rows
        xs = jnp.linspace(-1.0, 1.0, self.w)

        def frame(c, o):
            center = o + c * (1.0 - ys) ** 2                  # (h,)
            halfw = 0.08 + 0.5 * ys                           # road widens nearby
            img = jnp.exp(-((xs[None, :] - center[:, None]) / halfw[:, None]) ** 2)
            return img

        imgs = jax.vmap(frame)(curv, offset)
        imgs = imgs + 0.05 * jax.random.normal(k3, imgs.shape)
        rgb = jnp.stack([imgs, imgs * 0.8, imgs * 0.6], axis=-1)
        steering = -2.0 * curv - 0.5 * offset                 # steer against curve
        return {"x": rgb, "y": steering}

    def sample(self, key, batch: int):
        return self.sample_from(self.concept(), key, batch)
