"""Serving: prefill + batched decode against KV / SSM-state caches.

``serve_step`` (one new token for a batch of requests, each with a
``seq_len``-deep cache) is what the decode input shapes lower in the
dry-run. The ``ServeEngine`` provides a minimal batched-request loop
(greedy or temperature sampling) for the examples."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.model import (
    init_lm_cache, lm_apply, lm_decode_step,
)


def make_prefill(cfg: ModelConfig):
    """Prefill = full forward (logits for every position)."""

    def prefill(params, tokens):
        logits, _ = lm_apply(cfg, params, tokens)
        return logits

    return prefill


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        return lm_decode_step(cfg, params, token, cache, pos)

    return serve_step


class ServeEngine:
    """Minimal batched serving loop (greedy / temperature sampling)."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 batch: int, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.cache = init_lm_cache(cfg, batch, max_seq, dtype)
        self.pos = 0
        self._step = jax.jit(make_decode_step(cfg))

    def feed(self, tokens):
        """Sequentially feed prompt tokens (B, S_prompt) through decode."""
        logits = None
        for t in range(tokens.shape[1]):
            logits, self.cache = self._step(
                self.params, self.cache, tokens[:, t], self.pos)
            self.pos += 1
        return logits

    def generate(self, num_tokens: int, key=None, temperature: float = 0.0,
                 first_logits=None):
        out = []
        logits = first_logits
        for _ in range(num_tokens):
            if logits is None:
                raise ValueError("call feed() first")
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            out.append(nxt)
            logits, self.cache = self._step(
                self.params, self.cache, nxt, self.pos)
            self.pos += 1
        return jnp.stack(out, axis=1)
