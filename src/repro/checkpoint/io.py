"""Checkpointing: pytrees (params, optimizer state, protocol state) <-> npz.

Flat-key encoding: each leaf is stored under its tree path; structure is
rebuilt on load from the stored key strings, so no pickling is involved and
files are portable."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


SEP = "|"


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"a:{p}"


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten_with_paths(tree))


def _set_nested(root, keys, value):
    node = root
    for i, k in enumerate(keys[:-1]):
        nxt_is_idx = keys[i + 1][0] == "i"
        k_val = k[1]
        if isinstance(node, dict):
            node = node.setdefault(k_val, [] if nxt_is_idx else {})
        else:  # list
            while len(node) <= k_val:
                node.append([] if nxt_is_idx else {})
            node = node[k_val]
    last = keys[-1][1]
    if isinstance(node, dict):
        node[last] = value
    else:
        while len(node) <= last:
            node.append(None)
        node[last] = value


def load_pytree(path: str):
    data = np.load(path)
    root: Any = None
    items = []
    for key in data.files:
        parts = []
        for seg in key.split(SEP):
            tag, val = seg[0], seg[2:]
            parts.append(("i", int(val)) if tag == "i" else ("k", val))
        items.append((parts, jnp.asarray(data[key])))
    if not items:
        return {}
    if items[0][0][0][0] == "i":
        root = []
    else:
        root = {}
    for parts, val in items:
        _set_nested(root, parts, val)
    return root


def _sync_dict(sync_state) -> dict:
    return {"ref": sync_state.ref, "v": sync_state.v,
            "rng": sync_state.rng, "step": sync_state.step}


def save_protocol_state(path: str, params, opt_state, sync_state) -> None:
    from repro.core.sync.hierarchy import HierSyncState
    save_pytree(path + ".params.npz", params)
    save_pytree(path + ".opt.npz", opt_state)
    if isinstance(sync_state, HierSyncState):
        # two-tier state: per-cluster intra states + the inter-tier state
        save_pytree(path + ".sync.npz", {
            "intra": _sync_dict(sync_state.intra),
            "inter": _sync_dict(sync_state.inter),
        })
    else:
        save_pytree(path + ".sync.npz", _sync_dict(sync_state))


def _sync_state(d):
    from repro.core.operators import SyncState
    return SyncState(ref=d["ref"], v=d["v"], rng=d["rng"], step=d["step"])


def load_protocol_state(path: str):
    from repro.core.sync.hierarchy import HierSyncState
    params = load_pytree(path + ".params.npz")
    opt = load_pytree(path + ".opt.npz")
    sync = load_pytree(path + ".sync.npz")
    if "intra" in sync:
        state = HierSyncState(intra=_sync_state(sync["intra"]),
                              inter=_sync_state(sync["inter"]))
    else:
        state = _sync_state(sync)
    return params, opt, state
