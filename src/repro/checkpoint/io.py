"""Checkpointing: pytrees (params, optimizer state, protocol state) <-> npz.

Flat-key encoding: each leaf is stored under its tree path; structure is
rebuilt on load from the stored key strings, so no pickling is involved and
files are portable.

Every write here is ATOMIC: the file is produced under a temporary name
in the destination directory and moved into place with ``os.replace``
(an atomic rename on POSIX). A process crashing mid-save leaves either
the previous complete checkpoint or the new one — never a truncated npz
or a half-written JSON sidecar that a later restore would choke on."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


SEP = "|"


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"a:{p}"


def _atomic_write(path: str, write_fn) -> None:
    """Run ``write_fn(tmp_path)`` against a sibling temp file, then
    ``os.replace`` it over ``path``. The temp file lives in the SAME
    directory (``os.replace`` must not cross filesystems) and is cleaned
    up if the write itself fails."""
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        write_fn(tmp)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def _atomic_text(path: str, text: str) -> None:
    def write(tmp):
        with open(tmp, "w") as f:
            f.write(text)
    _atomic_write(path, write)


def save_pytree(path: str, tree) -> None:
    flat = _flatten_with_paths(tree)

    def write(tmp):
        # np.savez appends ".npz" unless told not to — hand it an open
        # file object so the temp name is used verbatim
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
    _atomic_write(path, write)


def _set_nested(root, keys, value):
    node = root
    for i, k in enumerate(keys[:-1]):
        nxt_is_idx = keys[i + 1][0] == "i"
        k_val = k[1]
        if isinstance(node, dict):
            node = node.setdefault(k_val, [] if nxt_is_idx else {})
        else:  # list
            while len(node) <= k_val:
                node.append([] if nxt_is_idx else {})
            node = node[k_val]
    last = keys[-1][1]
    if isinstance(node, dict):
        node[last] = value
    else:
        while len(node) <= last:
            node.append(None)
        node[last] = value


def load_pytree(path: str):
    data = np.load(path)
    root: Any = None
    items = []
    for key in data.files:
        parts = []
        for seg in key.split(SEP):
            tag, val = seg[0], seg[2:]
            parts.append(("i", int(val)) if tag == "i" else ("k", val))
        items.append((parts, jnp.asarray(data[key])))
    if not items:
        return {}
    if items[0][0][0][0] == "i":
        root = []
    else:
        root = {}
    for parts, val in items:
        _set_nested(root, parts, val)
    return root


def _sync_dict(sync_state) -> dict:
    d = {"ref": sync_state.ref, "v": sync_state.v,
         "rng": sync_state.rng, "step": sync_state.step}
    # trigger-declared extra carried state (e.g. staleness counters);
    # an empty dict contributes no leaves and round-trips as absence
    if sync_state.extra:
        d["extra"] = dict(sync_state.extra)
    return d


def save_protocol_state(path: str, params, opt_state, sync_state,
                        protocol=None, counters=None) -> None:
    """Persist a run. ``protocol`` (a ``ProtocolConfig`` or
    ``ProtocolSpec``) additionally writes ``<path>.spec.json`` — the
    serialized ``ProtocolSpec`` — so a restore reconstructs the exact
    protocol, not just its state. A hierarchical config
    (``ProtocolConfig.tiers``) writes an extended sidecar
    ``{"spec": <intra>, "tiers": {"num_clusters", "link_class",
    "inter": <spec>}}`` so the tier structure survives too.

    ``counters`` (``DecentralizedLearner.counters_state()``) writes
    ``<path>.counters.json`` — the cumulative host counters — so a
    resumed run continues its telemetry stream as ONE continuous record
    (``load_counters`` + ``DecentralizedLearner.restore_counters``)."""
    from repro.core.sync.hierarchy import HierSyncState
    save_pytree(path + ".params.npz", params)
    save_pytree(path + ".opt.npz", opt_state)
    if isinstance(sync_state, HierSyncState):
        # two-tier state: per-cluster intra states + the inter-tier state
        save_pytree(path + ".sync.npz", {
            "intra": _sync_dict(sync_state.intra),
            "inter": _sync_dict(sync_state.inter),
        })
    else:
        save_pytree(path + ".sync.npz", _sync_dict(sync_state))
    if protocol is not None:
        import json

        from repro.core.sync.spec import resolve_spec
        tiers = getattr(protocol, "tiers", None)
        if tiers is None:
            blob = resolve_spec(protocol).to_json()
        else:
            blob = json.dumps({
                "spec": resolve_spec(protocol).to_dict(),
                "tiers": {
                    "num_clusters": tiers.num_clusters,
                    "link_class": tiers.link_class,
                    "inter": resolve_spec(tiers.inter).to_dict(),
                },
            }, indent=1, sort_keys=True)
        _atomic_text(path + ".spec.json", blob)
    if counters is not None:
        import json
        _atomic_text(path + ".counters.json",
                     json.dumps(counters, indent=1, sort_keys=True))


def _sync_state(d):
    from repro.core.operators import SyncState
    return SyncState(ref=d["ref"], v=d["v"], rng=d["rng"], step=d["step"],
                     extra=d.get("extra", {}))


def load_protocol_state(path: str):
    from repro.core.sync.hierarchy import HierSyncState
    params = load_pytree(path + ".params.npz")
    opt = load_pytree(path + ".opt.npz")
    sync = load_pytree(path + ".sync.npz")
    if "intra" in sync:
        state = HierSyncState(intra=_sync_state(sync["intra"]),
                              inter=_sync_state(sync["inter"]))
    else:
        state = _sync_state(sync)
    return params, opt, state


def load_protocol_spec(path: str):
    """The flat (or intra-tier) ``ProtocolSpec`` saved next to a
    checkpoint, or None for checkpoints written before the spec API
    (callers then fall back to their own config). For a hierarchical
    checkpoint this is the INTRA spec; the tier structure lives in the
    sidecar's ``tiers`` block (``load_protocol_tiers``)."""
    from repro.core.sync.spec import ProtocolSpec
    d = _read_sidecar(path)
    if d is None:
        return None
    return ProtocolSpec.from_dict(d.get("spec", d))


def load_protocol_tiers(path: str):
    """The hierarchy block of a checkpoint's spec sidecar —
    ``{"num_clusters", "link_class", "inter": <inter ProtocolSpec>}`` —
    or None for flat checkpoints."""
    from repro.core.sync.spec import ProtocolSpec
    d = _read_sidecar(path)
    if d is None or "tiers" not in d:
        return None
    tiers = dict(d["tiers"])
    tiers["inter"] = ProtocolSpec.from_dict(tiers["inter"])
    return tiers


def load_counters(path: str):
    """The cumulative-counter snapshot saved next to a checkpoint
    (``counters=`` in :func:`save_protocol_state`), or None for
    checkpoints written without one. Feed it to
    ``DecentralizedLearner.restore_counters`` so a resumed run's
    counters — and its telemetry stream — continue where the
    checkpointed run stopped."""
    import json
    counters_path = path + ".counters.json"
    if not os.path.exists(counters_path):
        return None
    with open(counters_path) as f:
        return json.load(f)


def _read_sidecar(path: str):
    import json
    spec_path = path + ".spec.json"
    if not os.path.exists(spec_path):
        return None
    with open(spec_path) as f:
        return json.load(f)
