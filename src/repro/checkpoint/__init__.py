from repro.checkpoint.io import (  # noqa: F401
    load_protocol_spec, load_protocol_state, load_protocol_tiers,
    load_pytree, save_protocol_state, save_pytree,
)
