from repro.checkpoint.io import save_pytree, load_pytree, save_protocol_state, load_protocol_state  # noqa: F401
