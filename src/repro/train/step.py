"""Baseline single-model training step (data-parallel / FSDP / TP).

This is the sigma_1 (continuous averaging) reference point: by the paper's
Proposition 3, per-step gradient averaging over m learners with batch B is
*exactly* serial mini-batch SGD with batch mB and learning rate eta/m — so
the standard data-parallel step doubles as the paper's consistency anchor
and as the baseline for the roofline table.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.optim import make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def make_train_step(loss_fn: Callable[[Any, Any], jnp.ndarray],
                    train: TrainConfig):
    """Returns (init_state_fn, step_fn).

    ``train.micro_batch > 1`` enables gradient accumulation: the global
    batch is split into micro_batch slices scanned sequentially, shrinking
    live activation memory ~micro_batch x at unchanged math (the mean of
    per-microbatch mean-gradients equals the full-batch mean gradient for
    equal slices) — the fit lever for configs whose dry-run
    ``temp GB/chip`` exceeds HBM (EXPERIMENTS.md §Dry-run).
    """
    opt = make_optimizer(train)

    def init_state(params) -> TrainState:
        return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))

    def grads_of(params, batch):
        if not train.micro_batch or train.micro_batch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        n = train.micro_batch

        def slice_batch(b):
            return jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), b)

        def body(carry, micro):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, micro)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, grad_acc, grads)), None

        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros_like(p), params))
        (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, slice_batch(batch))
        inv = 1.0 / n
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        params, opt_state = opt.update(state.params, grads, state.opt_state)
        return TrainState(params, opt_state, state.step + 1), {"loss": loss}

    return init_state, step
