from repro.train.step import make_train_step, TrainState  # noqa: F401
from repro.train.loop import run_protocol_training  # noqa: F401
