"""High-level protocol training loop used by examples and benchmarks.

Runs a ``DecentralizedLearner`` against a data source for T rounds, with
optional concept drift, recording per-round cumulative loss/communication
trajectories (the quantities the paper plots)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig, TrainConfig
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams


@dataclass
class Trajectory:
    rounds: List[int] = field(default_factory=list)
    cumulative_loss: List[float] = field(default_factory=list)
    cumulative_bytes: List[int] = field(default_factory=list)
    syncs: List[int] = field(default_factory=list)
    drift_rounds: List[int] = field(default_factory=list)

    def as_dict(self):
        return {
            "rounds": self.rounds,
            "cumulative_loss": self.cumulative_loss,
            "cumulative_bytes": self.cumulative_bytes,
            "syncs": self.syncs,
            "drift_rounds": self.drift_rounds,
        }


def run_protocol_training(
    loss_fn: Callable,
    init_fn: Callable,
    source,
    m: int,
    rounds: int,
    protocol: ProtocolConfig,
    train: TrainConfig = TrainConfig(),
    batch: int = 10,
    seed: int = 0,
    record_every: int = 10,
    drift: bool = False,
    batch_sizes=None,
    init_heterogeneity: float = 0.0,
    sample_kw: Optional[dict] = None,
) -> tuple:
    """Returns (learner, trajectory)."""
    streams = LearnerStreams(source, m, batch=batch, seed=seed,
                             batch_sizes=batch_sizes, **(sample_kw or {}))
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, protocol, train, seed=seed,
        init_heterogeneity=init_heterogeneity,
        sample_weights=streams.weights)
    traj = Trajectory()
    for t in range(rounds):
        if drift and hasattr(source, "maybe_drift") and source.maybe_drift():
            traj.drift_rounds.append(t)
        dl.step(streams.next())
        if (t + 1) % record_every == 0 or t == rounds - 1:
            traj.rounds.append(t + 1)
            traj.cumulative_loss.append(dl.cumulative_loss)
            traj.cumulative_bytes.append(dl.comm_bytes())
            traj.syncs.append(dl.comm_totals["syncs"])
    return dl, traj
