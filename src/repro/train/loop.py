"""High-level protocol training loop used by examples and benchmarks.

Runs a ``DecentralizedLearner`` against a data source for T rounds, with
optional concept drift, recording per-round cumulative loss/communication
trajectories (the quantities the paper plots).

The driver is CHUNKED: rounds are executed ``chunk_size`` at a time through
``DecentralizedLearner.run_chunk`` — one ``jax.lax.scan`` program per chunk
instead of one jitted dispatch per round. Trajectory records at arbitrary
``record_every`` points are reconstructed exactly from the chunk's stacked
per-round metrics (integer comm counters cumsum bitwise-identically; losses
differ from the per-round driver only in float32 summation order)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.config import (
    AsyncConfig, FaultConfig, NetworkConfig, TelemetryConfig, TrainConfig,
)
from repro.core import operators as ops
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams

# Default scan length: long enough that per-chunk dispatch is noise, short
# enough that the stacked (n, m, B, ...) batch chunk stays small on CPU.
DEFAULT_CHUNK = 64


@dataclass
class Trajectory:
    rounds: List[int] = field(default_factory=list)
    cumulative_loss: List[float] = field(default_factory=list)
    cumulative_bytes: List[int] = field(default_factory=list)
    syncs: List[int] = field(default_factory=list)
    drift_rounds: List[int] = field(default_factory=list)
    network_time: List[float] = field(default_factory=list)  # simulated s

    def as_dict(self):
        return {
            "rounds": self.rounds,
            "cumulative_loss": self.cumulative_loss,
            "cumulative_bytes": self.cumulative_bytes,
            "syncs": self.syncs,
            "drift_rounds": self.drift_rounds,
            "network_time": self.network_time,
        }


def run_drift_segments(dl, streams, source, rounds: int, drift_rounds=()):
    """Run ``rounds`` rounds as scanned chunks segmented at KNOWN drift
    rounds, calling ``source.force_drift()`` at each boundary. Returns the
    per-round cumulative ``(sync_curve, loss_curve)`` arrays the drift
    figures plot, reconstructed from each chunk's stacked metrics.

    ``drift_rounds`` must lie strictly inside (0, rounds) — a drift at
    round 0 is just a different initial concept and a drift at/after the
    last round is unobservable.
    """
    bounds = sorted(set(int(d) for d in drift_rounds))
    if bounds and (bounds[0] <= 0 or bounds[-1] >= rounds):
        raise ValueError(
            f"drift_rounds must lie strictly inside (0, {rounds}): {bounds}")
    sync_curve, loss_curve = [], []
    for start, end in zip([0] + bounds, bounds + [rounds]):
        if start in bounds:
            source.force_drift()
        metrics = dl.run_chunk(streams.next_chunk(end - start))
        s0 = sync_curve[-1] if sync_curve else 0
        l0 = loss_curve[-1] if loss_curve else 0.0
        sync_curve.extend(
            (s0 + np.cumsum(np.asarray(metrics.comm.syncs, np.int64)))
            .tolist())
        loss_curve.extend(
            (l0 + np.cumsum(np.sum(
                np.asarray(metrics.loss_per_learner, np.float64), axis=1)))
            .tolist())
    return np.asarray(sync_curve), np.asarray(loss_curve)


def run_protocol_training(
    loss_fn: Callable,
    init_fn: Callable,
    source,
    m: int,
    rounds: int,
    protocol,   # ProtocolConfig sugar or a ProtocolSpec composition
    train: TrainConfig = TrainConfig(),
    batch: int = 10,
    seed: int = 0,
    record_every: int = 10,
    drift: bool = False,
    batch_sizes=None,
    init_heterogeneity: float = 0.0,
    sample_kw: Optional[dict] = None,
    chunk_size: int = DEFAULT_CHUNK,
    network: Optional[NetworkConfig] = None,
    telemetry: Optional[TelemetryConfig] = None,
    async_net: Optional[AsyncConfig] = None,
    faults: Optional[FaultConfig] = None,
) -> tuple:
    """Returns (learner, trajectory). A ``telemetry`` config attaches
    the fleet telemetry plane (``repro.telemetry``): one schema'd record
    per round streamed to JSONL, with no change to the training
    numerics and no extra device transfers."""
    streams = LearnerStreams(source, m, batch=batch, seed=seed,
                             batch_sizes=batch_sizes, **(sample_kw or {}))
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, protocol, train, seed=seed,
        init_heterogeneity=init_heterogeneity,
        sample_weights=streams.weights, network=network,
        telemetry=telemetry, async_net=async_net, faults=faults)
    traj = Trajectory()
    chunk = max(1, min(chunk_size, rounds))
    t = 0
    drifting = drift and hasattr(source, "maybe_drift")
    while t < rounds:
        n = min(chunk, rounds - t)

        def on_round(i, t=t):
            if source.maybe_drift():
                traj.drift_rounds.append(t + i)

        base_loss = dl.cumulative_loss
        base_totals = dict(dl.comm_totals)
        base_net_time = dl.network_time
        base_ledger = int(dl.link_bytes_totals.sum())
        metrics = dl.run_chunk(streams.next_chunk(
            n, on_round=on_round if drifting else None))

        # reconstruct the per-round cumulative trajectory from the chunk
        loss_cum = base_loss + np.cumsum(
            np.asarray(jnp.sum(metrics.loss_per_learner, axis=1), np.float64))
        comm_cum = {k: base_totals[k] + np.cumsum(
            np.asarray(getattr(metrics.comm, k), np.int64))
            for k in ops.CommRecord._fields}
        # under a hierarchy the tiers move different payload sizes, so the
        # byte curve comes from the per-round ledger (link counts priced
        # host-side at each link's payload size), not the scalar counts
        ledger_cum = base_ledger + np.cumsum(
            dl.price_link_counts(
                np.asarray(metrics.link_counts, np.int64)).sum(axis=1))
        net_cum = base_net_time + np.cumsum(
            np.asarray(metrics.net_time, np.float64))
        for i in range(n):
            g = t + i
            if (g + 1) % record_every == 0 or g == rounds - 1:
                traj.rounds.append(g + 1)
                traj.cumulative_loss.append(float(loss_cum[i]))
                traj.cumulative_bytes.append(
                    int(ledger_cum[i]) if dl.tiers is not None
                    else dl.comm_bytes_of(
                        {k: int(v[i]) for k, v in comm_cum.items()}))
                traj.syncs.append(int(comm_cum["syncs"][i]))
                traj.network_time.append(float(net_cum[i]))
        t += n
    return dl, traj
