"""Fused RMS-norm kernel: one HBM pass per (row-block, D) tile.

Normalization statistics, the rsqrt and the scale multiply are fused in
VMEM (f32 accumulation); the unfused jnp version reads x twice (once for
the variance, once for the normalize) and materializes the f32 upcast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 128,
            interpret: bool = True):
    """x: (..., D), scale: (D,). Rows processed in (block_rows, D) tiles."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    nb = x2.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
