"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python for correctness validation; on a TPU
backend the same ``pallas_call`` compiles to Mosaic. ``_interp()`` picks
automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.sqdist import sqdist as _sqdist
from repro.kernels.sqdist import sqdist_rows as _sqdist_rows
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def sqdist(x, r, *, block: int = 65536):
    return _sqdist(x, r, block=block, interpret=_interp())


def sqdist_rows(x, r, *, block_m: int = 8, block: int = 65536):
    """Batched local condition over the flat fleet-plane:
    ``(m, P) x (P,) -> (m,)`` row-wise squared distances in one grid."""
    return _sqdist_rows(x, r, block_m=block_m, block=block,
                        interpret=_interp())


def tree_sqdist(tree_a, tree_b, *, block: int = 65536):
    """||a - b||^2 summed over a whole pytree (the local condition on a
    full model)."""
    return sum(
        sqdist(x, y, block=block)
        for x, y in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)))


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 128):
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=_interp())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 128, block_k: int = 128):
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  block_q=block_q, block_k=block_k, interpret=_interp())


def flash_attention_gqa(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None, block_q: int = 128, block_k: int = 128):
    """GQA front-end: q (B, S, H, d), k/v (B, S, Hkv, d).

    Folds (B, Hkv, group) into the kernel's batch grid axis so each kv head
    is staged once per group."""
    B, Sq, H, d = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Sk = k.shape[1]
    qg = q.reshape(B, Sq, Hkv, G, d).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(B * Hkv * G, Sq, d)
    kg = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Hkv * G, Sk, d)
    vg = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Hkv * G, Sk, d)
    out = flash_attention(qg, kg, vg, causal=causal, window=window,
                          scale=scale, block_q=block_q, block_k=block_k)
    out = out.reshape(B, Hkv, G, Sq, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, d)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 64):
    """Chunked SSD over (BH, S, *) layouts; pads S to a chunk multiple."""
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y, h = _ssd(x, dt, a, b, c, chunk=chunk, interpret=_interp())
    if pad:
        y = y[:, :S]
    return y, h
