"""Banded sliding-window attention kernel — the Pallas twin of the XLA-graph
blocking in ``models.attention._banded_sdpa`` (EXPERIMENTS.md §Perf pair 3).

Where ``flash_attention`` sweeps EVERY k block and masks, this kernel's grid
is (batch, q_block, 2): for query block i only k blocks i-1 and i are ever
staged into VMEM (they cover the whole window when block == window), so HBM
traffic and MXU work drop by the same S/(2w) factor the graph-level path
achieves — but with no (B, nb, 2w, d) gathered-key intermediate at all.
Online-softmax state is carried in VMEM scratch across the 2-step k sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, window: int):
    i = pl.program_id(1)
    t = pl.program_id(2)              # 0: previous k block, 1: own k block

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (w, d)
    k = k_ref[0].astype(jnp.float32)          # (w, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # absolute positions: q row r -> i*w + r; k col c -> (i - 1 + t)*w + c
    qpos = i * window + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kblock = i - 1 + t
    kpos = kblock * window + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos <= qpos) & (kpos > qpos - window) & (kblock >= 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(t == 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def swa_attention(q, k, v, *, window: int, scale: float | None = None,
                  interpret: bool = True):
    """q, k, v: (B, S, d) with S % window == 0 and S >= window.

    Causal sliding-window attention (window == block size): each query
    attends to the ``window`` most recent positions including itself."""
    B, S, d = q.shape
    if S % window != 0 or S < window:
        raise ValueError(
            f"sequence length must be a multiple of the window and at "
            f"least one window long: S={S}, window={window}")
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    nb = S // window

    def k_index(b, i, t):
        # clamp block -1 to 0; its contribution is masked out in-kernel
        return (b, jnp.maximum(i - 1 + t, 0), 0)

    return pl.pallas_call(
        functools.partial(_swa_kernel, scale=scale, window=window),
        grid=(B, nb, 2),
        in_specs=[
            pl.BlockSpec((1, window, d), lambda b, i, t: (b, i, 0)),
            pl.BlockSpec((1, window, d), k_index),
            pl.BlockSpec((1, window, d), k_index),
        ],
        out_specs=pl.BlockSpec((1, window, d), lambda b, i, t: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((window,), jnp.float32),
            pltpu.VMEM((window,), jnp.float32),
            pltpu.VMEM((window, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
