"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sqdist_ref(x, r):
    """||x - r||^2 in f32. x, r: any same-shape arrays."""
    d = x.astype(jnp.float32) - r.astype(jnp.float32)
    return jnp.sum(d * d)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    """Row-wise RMS normalization. x: (..., D), scale: (D,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """Masked softmax attention. q: (B, Sq, d), k/v: (B, Sk, d).

    ``window`` > 0 adds sliding-window masking (positions are 0..S-1 with
    q-position offset so Sq == Sk aligns the diagonals).
    """
    B, Sq, d = q.shape
    Sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b, c, *, chunk: int = 0):
    """Sequential (non-chunked) SSD reference.

    x: (BH, S, P) inputs; dt: (BH, S) step sizes (>0); a: (BH,) negative
    decay rates; b, c: (BH, S, N). Returns (y (BH, S, P), h (BH, P, N)):
        h_t = exp(dt_t * a) h_{t-1} + dt_t * x_t b_t^T,   y_t = h_t^T... c_t
    (y_t[p] = sum_n h_t[p, n] c_t[n]).
    """
    del chunk
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def one(xh, dth, ah, bh, ch):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(dtt * ah) * h + dtt * (xt[:, None] * bt[None, :])
            y = h @ ct                       # (P,)
            return h, y

        h0 = jnp.zeros((xh.shape[-1], bh.shape[-1]), jnp.float32)
        h, ys = jax.lax.scan(step, h0, (xh, dth, bh, ch))
        return ys, h

    y, h = jax.vmap(one)(xf, dtf, af, bf, cf)
    return y.astype(x.dtype), h
