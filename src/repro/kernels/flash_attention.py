"""Blockwise online-softmax attention (TPU flash attention).

TPU adaptation of the flash-attention idea: the grid walks (batch*head,
q-block, k-block) with the k dimension innermost — TPU grid iteration is
sequential per core, so the running max / denominator / accumulator live in
VMEM scratch across the k sweep instead of in GPU shared memory per CTA.
BlockSpecs stage (block_q, d) and (block_k, d) tiles HBM->VMEM; block sizes
default to 128 to align the MXU matmul dims.

Causal and sliding-window masking are applied via broadcasted iotas; GQA is
handled by the ops.py wrapper (folding the group into the batch-head grid
axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, num_k_blocks: int,
                  q_offset: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)                 # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    qi = pl.program_id(1)
    qpos = (qi * block_q + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)                  # (bq,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)                      # kill fully-masked rows
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=(
        "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, Sq, d), k/v: (B, Sk, d) -> (B, Sq, d).

    Sq may be shorter than Sk (the causal diagonal is right-aligned, as in
    decode/chunked prefill)."""
    B, Sq, d = q.shape
    Sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded k positions fall outside the causal mask of real q rows
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, num_k_blocks=nk,
            q_offset=Sk - Sq),
        grid=(B, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :Sq]
    return out
