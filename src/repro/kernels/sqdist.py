"""Fused squared-distance reduction kernels — the protocol's monitoring
hot-spot: every learner evaluates ``||theta - r||^2`` every b steps
(Algorithm 1's local condition).

``sqdist`` is the single-model reduction: one HBM pass, each grid step
stages a (1, block) tile of both vectors into VMEM and accumulates
``sum((x - r)^2)`` in f32 into a (1, 1) output tile that every grid step
maps to (TPU grid iteration is sequential, so the accumulation is
race-free). No materialized difference tensor.

``sqdist_rows`` is the FLEET-PLANE variant the flat sync path runs:
``(m, P) x (P,) -> (m,)`` over a 2-D row x column-block grid. Each grid
step stages a ``(block_m, block)`` tile of the plane plus the matching
``(1, block)`` slice of the reference row, and accumulates per-row
partial sums into a ``(block_m, 1)`` output tile revisited across the
column sweep — the whole fleet's local conditions in one pass over the
``(m, P)`` matrix, instead of m kernel launches (or 2xleaf-count HBM
walks on the pytree layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(x_ref, r_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = x_ref[...].astype(jnp.float32) - r_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sqdist(x, r, *, block: int = 65536, interpret: bool = True):
    """||x - r||^2 over flattened inputs. Pads to a block multiple with
    equal values (zero contribution)."""
    xf = x.reshape(-1)
    rf = r.reshape(-1)
    n = xf.shape[0]
    pad = (-n) % block
    if pad:
        xf = jnp.pad(xf, (0, pad))
        rf = jnp.pad(rf, (0, pad))
    nb = xf.shape[0] // block
    x2 = xf.reshape(nb, block)
    r2 = rf.reshape(nb, block)
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2, r2)
    return out[0, 0]


def _sqdist_rows_kernel(x_ref, r_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = x_ref[...].astype(jnp.float32) - r_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(d * d, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block", "interpret"))
def sqdist_rows(x, r, *, block_m: int = 8, block: int = 65536,
                interpret: bool = True):
    """Row-wise ``||x[i] - r||^2``: x ``(m, n)``, r ``(n,)`` -> ``(m,)``.

    No padding copies of the plane: the grid only visits the
    block-aligned column prefix of the FULL array (every accessed tile
    stays in bounds, so nothing is re-materialized — a ``jnp.pad`` to a
    block multiple would silently copy the whole (m, n) matrix first)
    and the ragged tail (< ``block`` columns, if any) is reduced with a
    plain jnp row pass and added. Rows that do not tile into ``block_m``
    fall back to ``block_m=1``, which always divides. The grid sweeps
    columns innermost, so each ``(block_m, 1)`` output tile accumulates
    its rows' partials sequentially (race-free on TPU's sequential
    grid)."""
    m, n = x.shape
    rf = r.reshape(-1)
    if m % block_m:
        block_m = 1
    n0 = n - n % block

    def tail_sums(xt, rt):
        d = xt.astype(jnp.float32) - rt.astype(jnp.float32)[None]
        return jnp.sum(d * d, axis=1)

    if n0 == 0:                    # everything is tail: no kernel launch
        return tail_sums(x, rf)
    out = pl.pallas_call(
        _sqdist_rows_kernel,
        grid=(m // block_m, n0 // block),
        in_specs=[
            pl.BlockSpec((block_m, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(x, rf.reshape(1, n))[:, 0]
    if n0 < n:
        out = out + tail_sums(x[:, n0:], rf[n0:])
    return out
