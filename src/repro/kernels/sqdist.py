"""Fused squared-distance reduction kernel — the protocol's monitoring
hot-spot: every learner evaluates ``||theta - r||^2`` every b steps
(Algorithm 1's local condition).

One HBM pass: each grid step stages a (1, block) tile of both vectors into
VMEM, accumulates ``sum((x - r)^2)`` in f32 into a (1, 1) output tile that
every grid step maps to (TPU grid iteration is sequential, so the
accumulation is race-free). No materialized difference tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(x_ref, r_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = x_ref[...].astype(jnp.float32) - r_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sqdist(x, r, *, block: int = 65536, interpret: bool = True):
    """||x - r||^2 over flattened inputs. Pads to a block multiple with
    equal values (zero contribution)."""
    xf = x.reshape(-1)
    rf = r.reshape(-1)
    n = xf.shape[0]
    pad = (-n) % block
    if pad:
        xf = jnp.pad(xf, (0, pad))
        rf = jnp.pad(rf, (0, pad))
    nb = xf.shape[0] // block
    x2 = xf.reshape(nb, block)
    r2 = rf.reshape(nb, block)
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2, r2)
    return out[0, 0]
