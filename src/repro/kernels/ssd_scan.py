"""Chunked SSD (state-space duality) scan kernel — Mamba2's core compute.

TPU adaptation of the Dao & Gu chunked algorithm: the grid walks
(batch*head, chunk) with the chunk dimension innermost and sequential, so
the inter-chunk recurrent state (P, N) lives in VMEM scratch across the
sweep (the GPU version parallelizes chunks across SMs and does a separate
state-passing pass; TPU's sequential grid makes the recurrence free).
Within a chunk the quadratic attention-like term runs on the MXU:

    y_diag = (C B^T * L) (dt * x)        L = exp(segsum(dt*a)), lower-tri
    y_off  = exp(cum) * (C h_prev^T)
    h     <- exp(cum[-1]) h + ((dt * decay * x)^T B)

Block shapes: x (chunk, P), b/c (chunk, N) staged HBM->VMEM per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, h_scr, *,
                num_chunks: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (cs, P)
    dt = dt_ref[0].astype(jnp.float32)        # (cs,)
    a = a_ref[0].astype(jnp.float32)          # ()
    b = b_ref[0].astype(jnp.float32)          # (cs, N)
    c = c_ref[0].astype(jnp.float32)          # (cs, N)

    da = dt * a                               # (cs,) <= 0
    cum = jnp.cumsum(da)                      # within-chunk cumulative decay
    cs = x.shape[0]

    # intra-chunk quadratic term
    seg = cum[:, None] - cum[None, :]         # (cs, cs)
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    L = jnp.where(jj <= ii, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (cs, cs)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (cs, P)

    # inter-chunk contribution from the carried state
    h = h_scr[...]                            # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update
    decay = jnp.exp(cum[-1] - cum)            # (cs,)
    xw = xdt * decay[:, None]                 # (cs, P)
    h_scr[...] = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(cj == num_chunks - 1)
    def _finish():
        h_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 64, interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S); a: (BH,); b, c: (BH, S, N).

    Returns (y (BH, S, P), h (BH, P, N)) — matching ``ref.ssd_scan_ref``.
    S must be a chunk multiple (callers pad)."""
    BH, S, P = x.shape
    N = b.shape[-1]
    if S % chunk != 0:
        raise ValueError(
            f"sequence length must be a chunk multiple (callers pad): "
            f"S={S}, chunk={chunk}")
    nc = S // chunk
    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, num_chunks=nc),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, P, N), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, h
