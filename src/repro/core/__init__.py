# The paper's primary contribution: the dynamic averaging protocol.
from repro.core.divergence import (  # noqa: F401
    divergence, sq_distance, local_condition_violated, flat_size,
    tree_mean, tree_weighted_mean, per_learner_sq_distance,
    per_learner_sq_distance_flat,
)
from repro.core.flatten import FleetAdapter, fleet_adapter  # noqa: F401
from repro.core.protocol import DecentralizedLearner, make_protocol  # noqa: F401
from repro.core import operators  # noqa: F401
from repro.core import sync  # noqa: F401  (the staged sync kernel)
