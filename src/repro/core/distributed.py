"""SPMD dynamic averaging: the paper's protocol on a TPU mesh.

Hardware adaptation (DESIGN.md §2): each *learner* is a model-parallel
group of chips (typically: a pod). Learner-distinct parameters carry a
leading ``m`` axis sharded over the learner mesh axis ("pod"); within a
learner, weights shard over ("data", "model") exactly like the baseline.

The jitted ``train_step`` then contains:
  * per-learner forward/backward + optimizer update — NO collective over
    the learner axis (vmap over the m axis; XLA keeps it pod-local),
  * every ``b`` steps, the local condition ||theta_i - r||^2 > Delta — one
    scalar reduce per learner + an m-wide any() (tiny collective),
  * a ``lax.cond``-gated full averaging (mean over the m axis -> all-reduce
    over the learner axis) that only *executes* on violation. Both branches
    lower, so the dry-run HLO exhibits the worst-case collective — exactly
    the paper's worst-case bound (sigma_Delta <= sigma_b communication).

Partial balancing (Algorithm 1's incremental augmentation) degenerates for
pod-scale m (2-32) and lives in the simulator; the SPMD path implements the
``B = [m]`` branch (augmentation="all"), which still satisfies Def. 2.

Communication accounting: ``syncs`` counts executed averaging rounds;
protocol bytes = syncs * 2 * (m) * model_bytes (paper semantics) while the
collective bytes of one sync on a ring are 2*(m-1)/m * model_bytes per
learner — both reported by the roofline tooling.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ProtocolConfig, TrainConfig
from repro.optim import make_optimizer


class DynamicTrainState(NamedTuple):
    params: Any          # leaves (m, ...) — sharded over the learner axis
    opt_state: Any       # leaves (m, ...)
    ref: Any             # reference model r — single copy (replicated over m)
    step: jnp.ndarray    # scalar int32
    syncs: jnp.ndarray   # scalar int32: number of executed averaging rounds
    checks: jnp.ndarray  # scalar int32: number of condition evaluations


def init_dynamic_state(init_fn: Callable, key, m: int,
                       train: TrainConfig) -> DynamicTrainState:
    base = init_fn(key)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), base)
    opt = make_optimizer(train)
    opt_state = jax.vmap(opt.init)(stacked)
    z = jnp.zeros((), jnp.int32)
    return DynamicTrainState(stacked, opt_state, base, z, z, z)


def _tree_sq_dist_per_learner(stacked, ref):
    def leaf(x, r):
        d = x.astype(jnp.float32) - r.astype(jnp.float32)[None]
        return jnp.sum(d.reshape(d.shape[0], -1) ** 2, axis=1)
    return sum(jax.tree.leaves(jax.tree.map(leaf, stacked, ref)))


def make_dynamic_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    proto: ProtocolConfig,
    train: TrainConfig,
    m: int,
    spmd_axis_name: Optional[str] = None,
):
    """Returns step(state, batch) -> (state, metrics).

    ``batch`` leaves must have leading (m, per_learner_batch, ...) — the
    launcher reshapes the global batch; the m axis shards over the learner
    mesh axis so each learner trains on its own shard.

    ``spmd_axis_name``: mesh axis carrying the learner dim (e.g. "pod").
    Passing it lets the per-learner sharding constraints inside the model
    propagate through the vmap (jax inserts the learner axis into every
    constrained spec), which is what keeps the within-learner layout
    identical to the single-learner baseline. Without it, XLA must infer
    all intermediate shardings from the inputs alone (§Perf records the
    difference).
    """
    opt = make_optimizer(train)

    def local_update(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    vmapped = jax.vmap(local_update, spmd_axis_name=spmd_axis_name)

    def step(state: DynamicTrainState, batch):
        params, opt_state, losses = vmapped(
            state.params, state.opt_state, batch)
        t = state.step + 1

        def check(operand):
            params, ref = operand
            dists = _tree_sq_dist_per_learner(params, ref)      # (m,)
            violated = jnp.any(dists > proto.delta)

            def sync(_):
                mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), params)
                newp = jax.tree.map(
                    lambda mn: jnp.broadcast_to(mn[None], (m,) + mn.shape),
                    mean)
                return newp, mean, jnp.int32(1)

            def keep(_):
                return params, ref, jnp.int32(0)

            newp, newref, did = jax.lax.cond(violated, sync, keep, None)
            return newp, newref, did, jnp.int32(1), jnp.max(dists)

        def skip(operand):
            params, ref = operand
            return params, ref, jnp.int32(0), jnp.int32(0), jnp.zeros(())

        do_check = (t % proto.b) == 0
        params, ref, did_sync, did_check, maxdist = jax.lax.cond(
            do_check, check, skip, (params, state.ref))

        new_state = DynamicTrainState(
            params, opt_state, ref, t,
            state.syncs + did_sync, state.checks + did_check)
        metrics = {
            "loss": jnp.mean(losses),
            "loss_per_learner": losses,
            "synced": did_sync,
            "max_sq_dist": maxdist,
        }
        return new_state, metrics

    return step


def make_periodic_train_step(loss_fn, proto: ProtocolConfig,
                             train: TrainConfig, m: int,
                             spmd_axis_name: Optional[str] = None):
    """sigma_b baseline in the same m-learner layout (for A/B comparison)."""
    opt = make_optimizer(train)

    def local_update(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    vmapped = jax.vmap(local_update, spmd_axis_name=spmd_axis_name)

    def step(state: DynamicTrainState, batch):
        params, opt_state, losses = vmapped(
            state.params, state.opt_state, batch)
        t = state.step + 1

        def sync(params):
            mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), params)
            return jax.tree.map(
                lambda mn: jnp.broadcast_to(mn[None], (m,) + mn.shape), mean), jnp.int32(1)

        def keep(params):
            return params, jnp.int32(0)

        params, did = jax.lax.cond((t % proto.b) == 0, sync, keep, params)
        new_state = DynamicTrainState(
            params, opt_state, state.ref, t, state.syncs + did, state.checks)
        return new_state, {"loss": jnp.mean(losses), "synced": did}

    return step
