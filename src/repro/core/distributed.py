"""SPMD dynamic averaging on a pod mesh — a thin shim over the staged
sync engine.

Hardware adaptation (DESIGN.md §2): each *learner* is a model-parallel
group of chips (typically: a pod). Learner-distinct parameters carry a
leading ``m`` axis sharded over the learner mesh axis ("pod"); within a
learner, weights shard over ("data", "model") exactly like the baseline.

This module used to be an independent protocol implementation (plain
dynamic averaging only). It is now sugar over the same ``ProtocolSpec``
compile that powers the simulator and the ``layout="sharded"`` fleet
plane (``repro.core.shard``): the step below vmaps the local update
(with ``spmd_axis_name`` so within-learner sharding constraints
propagate) and delegates the sync decision to the compiled staged round
— divergence trigger, full-fleet cohort (``augmentation="all"``, the
``B = [m]`` branch of Algorithm 1, the right degeneration for pod-scale
m), mean aggregate, balancing commit. The spec keeps ``layout="tree"``:
per-leaf expressions preserve the within-learner ("data", "model")
placement that the ``(m, P)`` plane concatenation would destroy; fleets
of single-device learners that want the plane use
``DecentralizedLearner`` with ``layout="flat"``/``"sharded"`` instead.

The jitted ``train_step`` still lowers to exactly the paper's shape:
  * per-learner update — no collective over the learner axis,
  * every ``b`` steps, one scalar reduce per learner + an m-wide any(),
  * a ``lax.cond``-gated full averaging (mean over m -> all-reduce over
    the learner axis) that only *executes* on violation. Both branches
    lower, so dry-run HLO exhibits the worst-case collective — the
    paper's sigma_Delta <= sigma_b communication bound.

Communication accounting: ``syncs`` counts executed averaging rounds;
protocol bytes = syncs * 2 * m * model_bytes (paper semantics) while the
collective bytes of one sync on a ring are 2*(m-1)/m * model_bytes per
learner — both reported by the roofline tooling. Metrics use the
engine-wide key ``"synced"`` (this-round 0/1) everywhere; the retired
manual-collective path's cumulative ``"syncs"`` key is gone.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig, TrainConfig
from repro.core.divergence import per_learner_sq_distance
from repro.core.sync.registry import SyncState
from repro.core.sync.spec import resolve_spec
from repro.optim import make_optimizer


class DynamicTrainState(NamedTuple):
    params: Any          # leaves (m, ...) — sharded over the learner axis
    opt_state: Any       # leaves (m, ...)
    ref: Any             # reference model r — single copy (replicated over m)
    step: jnp.ndarray    # scalar int32
    syncs: jnp.ndarray   # scalar int32: number of executed averaging rounds
    checks: jnp.ndarray  # scalar int32: number of condition evaluations


def init_dynamic_state(init_fn: Callable, key, m: int,
                       train: TrainConfig) -> DynamicTrainState:
    base = init_fn(key)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), base)
    opt = make_optimizer(train)
    opt_state = jax.vmap(opt.init)(stacked)
    z = jnp.zeros((), jnp.int32)
    return DynamicTrainState(stacked, opt_state, base, z, z, z)


def _spmd_spec(proto: ProtocolConfig):
    """The staged spec this shim delegates to: the config's preset with
    the full-fleet cohort forced (``B = [m]``, where the cohort consumes
    an augmentation strategy) on the tree layout."""
    spec = resolve_spec(proto).with_params(layout="tree")
    if "augmentation" in spec.known_params:
        spec = spec.with_params(augmentation="all")
    return spec


def _vmapped_update(loss_fn, train, spmd_axis_name):
    opt = make_optimizer(train)

    def local_update(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    return jax.vmap(local_update, spmd_axis_name=spmd_axis_name)


def _sync_input(spec, state: DynamicTrainState, m: int) -> SyncState:
    """The staged round's carry, synthesized per step from the pod-path
    state. With ``augmentation="all"`` every fired sync is FULL, so the
    balancing count v is 0 in and 0 out (full sync resets it) and the
    cohort draws no randomness — constants are self-consistent."""
    return SyncState(ref=state.ref, v=jnp.zeros((), jnp.int32),
                     rng=jax.random.PRNGKey(0), step=state.step,
                     extra=spec.init_extra(m))


def make_dynamic_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    proto: ProtocolConfig,
    train: TrainConfig,
    m: int,
    spmd_axis_name: Optional[str] = None,
):
    """Returns step(state, batch) -> (state, metrics).

    ``batch`` leaves must have leading (m, per_learner_batch, ...) — the
    launcher reshapes the global batch; the m axis shards over the learner
    mesh axis so each learner trains on its own shard.

    ``spmd_axis_name``: mesh axis carrying the learner dim (e.g. "pod").
    Passing it lets the per-learner sharding constraints inside the model
    propagate through the vmap (jax inserts the learner axis into every
    constrained spec), which is what keeps the within-learner layout
    identical to the single-learner baseline. Without it, XLA must infer
    all intermediate shardings from the inputs alone (§Perf records the
    difference).
    """
    spec = _spmd_spec(proto)
    round_fn = spec.compile()
    vmapped = _vmapped_update(loss_fn, train, spmd_axis_name)

    def step(state: DynamicTrainState, batch):
        params, opt_state, losses = vmapped(
            state.params, state.opt_state, batch)
        t = state.step + 1
        res = round_fn(params, _sync_input(spec, state, m))
        do_check = (t % proto.b) == 0
        # the trigger already priced the distances into its decision; the
        # diagnostic max recomputes them against the pre-sync reference
        # (reported on check rounds only, like the pre-shim step)
        maxdist = jax.lax.cond(
            do_check,
            lambda: jnp.max(per_learner_sq_distance(params, state.ref)),
            lambda: jnp.zeros(()))
        new_state = DynamicTrainState(
            res.params, opt_state, res.state.ref, t,
            state.syncs + res.rec.syncs,
            state.checks + do_check.astype(jnp.int32))
        metrics = {
            "loss": jnp.mean(losses),
            "loss_per_learner": losses,
            "synced": res.rec.syncs,
            "max_sq_dist": maxdist,
        }
        return new_state, metrics

    return step


def make_periodic_train_step(loss_fn, proto: ProtocolConfig,
                             train: TrainConfig, m: int,
                             spmd_axis_name: Optional[str] = None):
    """sigma_b baseline in the same m-learner layout (for A/B comparison).

    Delegates to the ``periodic`` preset of the same staged compile. The
    pod-path state keeps its frozen reference model (periodic makes no
    decision from it), matching the pre-shim step exactly."""
    spec = _spmd_spec(
        ProtocolConfig(kind="periodic", b=proto.b,
                       bytes_per_param=proto.bytes_per_param))
    round_fn = spec.compile()
    vmapped = _vmapped_update(loss_fn, train, spmd_axis_name)

    def step(state: DynamicTrainState, batch):
        params, opt_state, losses = vmapped(
            state.params, state.opt_state, batch)
        res = round_fn(params, _sync_input(spec, state, m))
        new_state = DynamicTrainState(
            res.params, opt_state, state.ref, state.step + 1,
            state.syncs + res.rec.syncs, state.checks)
        return new_state, {"loss": jnp.mean(losses),
                           "synced": res.rec.syncs}

    return step
