"""The decentralized learning simulator: m learners, one protocol.

Faithful to the paper's setting (Section 2): in each round t every learner i
observes a sample E_t^i of size B, updates its local model with the learning
algorithm phi (vmap'd over the learner axis), and every b rounds the
synchronization operator sigma runs (``repro.core.operators``).

The whole round — local updates + protocol — is one jitted function, so the
paper's experiments (m up to 200, ~1.2M-weight CNNs) run fast on CPU, and
the identical code path runs under pjit on a mesh (the learner axis then
shards over devices).

Communication is accounted exactly: model transfers and scalar messages as
integers, converted to bytes in ``comm_bytes``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig, TrainConfig
from repro.core import operators as ops
from repro.core.divergence import divergence, flat_size
from repro.optim import make_optimizer


class ProtocolMetrics(NamedTuple):
    loss_per_learner: jnp.ndarray    # (m,) this-round in-place loss
    comm: ops.CommRecord
    divergence: jnp.ndarray


class DecentralizedLearner:
    """m local learners + a synchronization protocol Pi = (phi, sigma)."""

    def __init__(
        self,
        loss_fn: Callable[[Any, Any], jnp.ndarray],
        init_fn: Callable[[jax.Array], Any],
        m: int,
        protocol: ProtocolConfig,
        train: TrainConfig = TrainConfig(),
        seed: int = 0,
        init_heterogeneity: float = 0.0,
        sample_weights: Optional[jnp.ndarray] = None,
        track_divergence: bool = False,
    ):
        self.m = m
        self.protocol = protocol
        self.train = train
        self.loss_fn = loss_fn
        self.opt = make_optimizer(train)
        self.track_divergence = track_divergence
        key = jax.random.PRNGKey(seed)
        k_init, k_noise, k_state = jax.random.split(key, 3)

        base = init_fn(k_init)
        # paper init: all learners start from ONE random model; Fig. 6.2
        # studies heterogeneous inits parameterized by a noise scale epsilon
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (m,) + x.shape).copy(), base)
        if init_heterogeneity > 0.0:
            # noise at scale eps *relative to the init scale of each leaf*
            # (paper Fig. 6.2 / A.8: eps measured relative to the scale of
            # the homogeneous Glorot initialization)
            noise_keys = jax.random.split(k_noise, m)
            leaves, treedef = jax.tree.flatten(base)
            new_leaves = []
            for li, x in enumerate(leaves):
                scale = init_heterogeneity * (jnp.std(x) + 1e-12)

                def one(k, x=x, li=li, scale=scale):
                    return jax.random.normal(
                        jax.random.fold_in(k, li), x.shape, x.dtype) * scale

                new_leaves.append(x[None] + jax.vmap(one)(noise_keys))
            stacked = jax.tree.unflatten(treedef, new_leaves)

        self.params = stacked
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        self.sync_state = ops.init_state(base, seed)
        self.sample_weights = sample_weights
        self.model_size = flat_size(base)

        # cumulative counters (host-side python ints / floats)
        self.cumulative_loss = 0.0
        self.cumulative_loss_per_learner = jnp.zeros((m,))
        self.comm_totals = {k: 0 for k in ops.CommRecord._fields}
        self.rounds = 0

        self._step = jax.jit(self._make_step())
        self._chunk = jax.jit(self._make_chunk())

    # ------------------------------------------------------------------
    def _make_step(self):
        loss_fn, opt = self.loss_fn, self.opt
        proto, weights = self.protocol, self.sample_weights
        track_div = self.track_divergence

        def local_update(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, loss

        def step(params, opt_state, sync_state, batches):
            params, opt_state, losses = jax.vmap(local_update)(
                params, opt_state, batches)
            params, sync_state, rec = ops.apply_operator(
                proto, params, sync_state, weights)
            div = divergence(params) if track_div else jnp.zeros(())
            return params, opt_state, sync_state, ProtocolMetrics(losses, rec, div)

        return step

    def _make_chunk(self):
        """n rounds as ONE compiled program: jax.lax.scan over the round
        axis, carry = (params, opt_state, sync_state), stacked per-round
        ``ProtocolMetrics`` as the scan output. Dispatch (and the host
        sync on counters) happens once per chunk instead of once per round,
        which is where the per-round Python loop spent nearly all of its
        wall-clock at simulator scale."""
        step = self._make_step()

        def chunk(params, opt_state, sync_state, batches):
            def body(carry, batch):
                params, opt_state, sync_state = carry
                params, opt_state, sync_state, metrics = step(
                    params, opt_state, sync_state, batch)
                return (params, opt_state, sync_state), metrics

            (params, opt_state, sync_state), metrics = jax.lax.scan(
                body, (params, opt_state, sync_state), batches)
            return params, opt_state, sync_state, metrics

        return chunk

    # ------------------------------------------------------------------
    def step(self, batches) -> ProtocolMetrics:
        """One round. ``batches``: pytree with leading (m, B, ...) leaves."""
        self.params, self.opt_state, self.sync_state, metrics = self._step(
            self.params, self.opt_state, self.sync_state, batches)
        self.rounds += 1
        self.cumulative_loss += float(jnp.sum(metrics.loss_per_learner))
        self.cumulative_loss_per_learner = (
            self.cumulative_loss_per_learner + metrics.loss_per_learner)
        for k in ops.CommRecord._fields:
            self.comm_totals[k] += int(getattr(metrics.comm, k))
        return metrics

    # ------------------------------------------------------------------
    def run_chunk(self, batches) -> ProtocolMetrics:
        """n rounds in one compiled program (the scanned dual of ``step``).

        ``batches``: pytree with leading (n, m, B, ...) leaves — round t of
        the chunk is ``batches[t]``. Returns stacked ``ProtocolMetrics``
        whose leaves carry the round axis: ``loss_per_learner`` is (n, m),
        every ``CommRecord`` field is (n,). Host-side cumulative counters
        are folded in once per chunk; protocol numerics are identical to n
        calls of ``step`` (same traced round function), so comm counters
        match bitwise and losses to float32 summation order.

        jit recompiles per distinct chunk length n — drive it with a fixed
        chunk size (plus at most one remainder) as ``train.loop`` does.
        """
        n = int(jax.tree.leaves(batches)[0].shape[0])
        self.params, self.opt_state, self.sync_state, metrics = self._chunk(
            self.params, self.opt_state, self.sync_state, batches)
        self.rounds += n
        self.cumulative_loss += float(jnp.sum(metrics.loss_per_learner))
        self.cumulative_loss_per_learner = (
            self.cumulative_loss_per_learner
            + jnp.sum(metrics.loss_per_learner, axis=0))
        for k in ops.CommRecord._fields:
            self.comm_totals[k] += int(jnp.sum(getattr(metrics.comm, k)))
        return metrics

    # ------------------------------------------------------------------
    def comm_bytes_of(self, totals, msg_bytes: int = 64) -> int:
        """Bytes for a comm-counter dict (paper's c(f) accounting)."""
        model_bytes = self.model_size * self.protocol.bytes_per_param
        return (
            (totals["model_up"] + totals["model_down"]) * model_bytes
            + totals["messages"] * msg_bytes
        )

    def comm_bytes(self, msg_bytes: int = 64) -> int:
        """Cumulative communication in bytes (paper's c(f) accounting)."""
        return self.comm_bytes_of(self.comm_totals, msg_bytes)

    def mean_model(self):
        from repro.core.divergence import tree_mean
        return tree_mean(self.params)

    def learner_model(self, i: int):
        return jax.tree.map(lambda x: x[i], self.params)


# ---------------------------------------------------------------------------
# serial baseline (paper's ``serial``: one model, all data)
# ---------------------------------------------------------------------------

class SerialLearner:
    def __init__(self, loss_fn, init_fn, train: TrainConfig = TrainConfig(),
                 seed: int = 0):
        self.loss_fn = loss_fn
        self.opt = make_optimizer(train)
        self.params = init_fn(jax.random.PRNGKey(seed))
        self.opt_state = self.opt.init(self.params)
        self.cumulative_loss = 0.0

        @jax.jit
        def _step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = self.opt.update(params, grads, opt_state)
            return params, opt_state, loss

        self._step = _step

    def step(self, batch):
        self.params, self.opt_state, loss = self._step(
            self.params, self.opt_state, batch)
        self.cumulative_loss += float(loss)
        return loss


def make_protocol(kind: str, **kw) -> ProtocolConfig:
    return ProtocolConfig(kind=kind, **kw)
