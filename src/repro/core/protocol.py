"""The decentralized learning simulator: m learners, one protocol.

Faithful to the paper's setting (Section 2): in each round t every learner i
observes a sample E_t^i of size B, updates its local model with the learning
algorithm phi (vmap'd over the learner axis), and every b rounds the
synchronization operator sigma runs (``repro.core.operators``).

The whole round — local updates + protocol — is one jitted function, so the
paper's experiments (m up to 200, ~1.2M-weight CNNs) run fast on CPU, and
the identical code path runs under pjit on a mesh (the learner axis then
shards over devices).

Communication is accounted exactly: model transfers and scalar messages as
integers, converted to bytes in ``comm_bytes``.

With a ``NetworkConfig`` the round runs inside a simulated network
environment (``repro.network``): per-round availability masks are sampled
inside the scanned round (pure in the round counter — no host sync), the
operators become availability-aware, and the link-cost model turns each
round's transfers into simulated wall-clock (``net_time``) and per-link
bytes. ``network=None`` is the ideal always-on star and reproduces the
pre-network engine bitwise.

Synchronization runs through the staged sync kernel (``repro.core.sync``):
the protocol argument — a ``ProtocolConfig`` (sugar for a ``PROTOCOLS``
preset) or a ``ProtocolSpec`` directly — resolves to a compiled stage
composition, and the spec's capabilities (``uses_overlay``,
``uses_coordinator``, ``extra_state``) drive the engine's wiring instead
of kind strings. The kernel also supplies the per-round **bytes ledger**:
every link's exact byte count (model payloads at that link's tier payload
size + control messages attributed to the link that sent them),
accumulated host-side in int64.
With ``ProtocolConfig.tiers`` (a ``HierarchyConfig``) the round becomes the
two-tier star-of-stars: the configured protocol runs inside each cluster,
``tiers.inter`` runs among the edge aggregators, and the ledger grows g
aggregator-uplink rows priced at the inter tier's payload size.
``tiers=None`` reproduces the flat engine bitwise.
"""
from __future__ import annotations

import contextlib
import functools
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (
    AsyncConfig, FaultConfig, NetworkConfig, ProtocolConfig, TelemetryConfig,
    TrainConfig,
)
from repro.core import operators as ops
from repro.core import shard
from repro.core.divergence import divergence, flat_size
from repro.core.sync.async_sync import asyncify
from repro.core.sync.hierarchy import (
    apply_hierarchical, init_hier_state, validate_hierarchy,
)
from repro.core.sync.spec import resolve_spec
from repro.network import availability as net_availability
from repro.network import cost as net_cost
from repro.network import faults as net_faults
from repro.network import topology as net_topology
from repro.optim import make_optimizer
from repro.telemetry import sink


class ProtocolMetrics(NamedTuple):
    loss_per_learner: jnp.ndarray    # (m,) this-round in-place loss
    comm: ops.CommRecord
    divergence: jnp.ndarray
    num_active: jnp.ndarray          # scalar int32 — reachable learners
    net_time: jnp.ndarray            # scalar float32 — simulated seconds
    link_xfers: jnp.ndarray          # (m,) int32 — models per learner link
    link_counts: jnp.ndarray         # (L, 2) int32 — the ledger's inputs:
    #   [model transfers, control messages] per link this round. L = m
    #   learner links, plus num_clusters aggregator uplinks under a
    #   hierarchy. Counts stay small int32 on device; the HOST prices them
    #   into int64 bytes (per-link payload size × transfers + msg_bytes ×
    #   messages), so billion-parameter payloads never overflow
    num_inflight: jnp.ndarray        # scalar int32 — learners whose sync
    #   exchange is in flight after this round (0 without an async
    #   timeline)
    max_age: jnp.ndarray             # scalar int32 — the oldest
    #   rounds-since-sync counter the trigger carries (staleness/async
    #   age; 0 for stateless triggers)
    num_faulty: jnp.ndarray          # scalar int32 — learners under ANY
    #   injected fault this round (crashed/restarting/bursting/corrupted/
    #   Byzantine; 0 with faults=None)
    num_quarantined: jnp.ndarray     # scalar int32 — learners currently
    #   quarantined (health counter > 0; 0 for non-robust triggers)
    num_recovered: jnp.ndarray       # scalar int32 — learners whose
    #   commit came back clean THIS round after a quarantine (0 for
    #   non-robust triggers)


class DecentralizedLearner:
    """m local learners + a synchronization protocol Pi = (phi, sigma).

    ``protocol`` is a ``ProtocolConfig`` (kind sugar resolving to a
    ``PROTOCOLS`` preset) or a ``ProtocolSpec`` directly — any registered
    stage composition, e.g. one loaded from JSON, runs through the same
    scanned engine."""

    def __init__(
        self,
        loss_fn: Callable[[Any, Any], jnp.ndarray],
        init_fn: Callable[[jax.Array], Any],
        m: int,
        protocol,
        train: TrainConfig = TrainConfig(),
        seed: int = 0,
        init_heterogeneity: float = 0.0,
        sample_weights: Optional[jnp.ndarray] = None,
        track_divergence: bool = False,
        network: Optional[NetworkConfig] = None,
        telemetry: Optional[TelemetryConfig] = None,
        async_net: Optional[AsyncConfig] = None,
        faults: Optional[FaultConfig] = None,
    ):
        self.m = m
        self.protocol = protocol
        # the engine consumes the protocol as a spec: a ProtocolConfig is
        # sugar for its PROTOCOLS preset, and a ProtocolSpec (e.g. loaded
        # from JSON, or a custom registered composition) runs directly
        self.spec = resolve_spec(protocol)
        self.train = train
        self.loss_fn = loss_fn
        self.opt = make_optimizer(train)
        self.track_divergence = track_divergence
        self.network = network
        # fault-injection plane (repro.network.faults): gated STATICALLY
        # on ``faults is not None`` — a fault-free run traces none of it
        # and stays bitwise vs the fault-unaware engine
        self.faults = faults
        self._nonfinite_reported = False
        key = jax.random.PRNGKey(seed)
        k_init, k_noise, k_state = jax.random.split(key, 3)

        base = init_fn(k_init)
        # paper init: all learners start from ONE random model; Fig. 6.2
        # studies heterogeneous inits parameterized by a noise scale epsilon
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (m,) + x.shape).copy(), base)
        if init_heterogeneity > 0.0:
            # noise at scale eps *relative to the init scale of each leaf*
            # (paper Fig. 6.2 / A.8: eps measured relative to the scale of
            # the homogeneous Glorot initialization)
            noise_keys = jax.random.split(k_noise, m)
            leaves, treedef = jax.tree.flatten(base)
            new_leaves = []
            for li, x in enumerate(leaves):
                scale = init_heterogeneity * (jnp.std(x) + 1e-12)

                def one(k, x=x, li=li, scale=scale):
                    return jax.random.normal(
                        jax.random.fold_in(k, li), x.shape, x.dtype) * scale

                new_leaves.append(x[None] + jax.vmap(one)(noise_keys))
            stacked = jax.tree.unflatten(treedef, new_leaves)

        self.params = stacked
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        self.sample_weights = sample_weights
        self.model_size = flat_size(base)
        self.model_bytes = self.model_size * self.spec.bytes_per_param

        # event-driven async timeline (AsyncConfig): rewrite the protocol
        # onto per-learner local clocks with messages in flight BEFORE any
        # state init — the rewritten spec carries the timeline's ring
        # buffers / clocks in SyncState.extra and (with aircomp) the
        # over-the-air stages. Under a hierarchy the intra tier runs the
        # rewritten spec; tiers.inter stays synchronous.
        self.async_net = async_net
        if async_net is not None:
            self.spec = asyncify(self.spec, async_net, network,
                                 self.model_bytes)

        # two-tier hierarchy (ProtocolConfig.tiers): per-cluster intra
        # state + inter-tier state; aggregator uplinks get their own
        # ledger rows and payload size (tiers.inter.bytes_per_param)
        self.tiers = getattr(protocol, "tiers", None)
        if self.tiers is not None:
            validate_hierarchy(self.tiers, m)
            self.sync_state = init_hier_state(
                base, self.tiers, seed, m=m, intra_spec=self.spec,
                inter_spec=resolve_spec(self.tiers.inter))
            self.inter_model_bytes = (
                self.model_size * self.tiers.inter.bytes_per_param)
            self.num_links = m + self.tiers.num_clusters
        else:
            self.sync_state = ops.init_state(base, seed, spec=self.spec,
                                             m=m)
            self.inter_model_bytes = 0
            self.num_links = m

        # network environment: link profile + peer overlay. A static
        # topology is built once here (concrete matrix closed over by the
        # jitted round); a mobile one is re-derived per scanned round from
        # the round counter. An overlay-using spec (``uses_overlay``, e.g.
        # gossip) needs SOME overlay — an ideal network means the implied
        # star.
        self._link_bw = self._link_lat = None
        self._agg_bw = self._agg_lat = None
        self._static_adj = None
        self._mobile = False
        if network is not None:
            self._link_bw, self._link_lat = net_cost.link_profile(network, m)
            self._mobile = net_topology.is_mobile(network)
            if not self._mobile:
                self._static_adj = net_topology.adjacency(network, m)
            if self.tiers is not None:
                self._agg_bw, self._agg_lat = net_cost.uniform_profile(
                    self.tiers.link_class, self.tiers.num_clusters)
        elif self.spec.uses_overlay:
            self._static_adj = net_topology.star(m)

        # device-sharded fleet plane (layout="sharded"): build the fleet
        # mesh and give the scan carry its NamedSharding home — learner-
        # stacked leaves (params, opt state, staleness ages) split over
        # the "fleet" axis, the reference model and scalar counters
        # replicated. The jitted round then traces against committed
        # sharded inputs (plus the constrain_rows pins the compiled round
        # inserts under the active fleet below), so per-learner updates,
        # sqdist rows, and (m, P) commits execute per-shard and only
        # trigger votes + cohort means cross devices.
        self.fleet = None
        if self.spec.param("layout") == "sharded":
            self.fleet = shard.fleet_sharding(
                m, self.spec.param("shard_devices"))
            self.params = shard.put_fleet(self.fleet, self.params)
            self.opt_state = shard.put_fleet(self.fleet, self.opt_state)
            if self.tiers is None:
                self.sync_state = shard.put_sync_state(
                    self.fleet, self.sync_state)
            else:
                # per-cluster hierarchy state carries (g, ...) leaves —
                # cluster-indexed, not learner-indexed — so it replicates;
                # the per-cluster sync runs flat arithmetic under vmap
                # (constrain_rows no-ops on the (k, P) cluster planes)
                # while the fleet carry around it stays device-sharded
                self.sync_state = shard.put_replicated(
                    self.fleet, self.sync_state)

        # cumulative counters (host-side python ints / floats / numpy)
        self.cumulative_loss = 0.0
        self.cumulative_loss_per_learner = np.zeros((m,), np.float32)
        self.comm_totals = {k: 0 for k in ops.CommRecord._fields}
        self.rounds = 0
        self.network_time = 0.0                    # simulated seconds
        self.active_rounds_total = 0               # sum of per-round |active|
        self.link_xfer_totals = np.zeros((m,), np.int64)
        # the bytes ledger: int64 cumulative bytes per link (learner links,
        # then aggregator uplinks under a hierarchy) — exact even when the
        # tiers move different payload sizes. Pricing happens host-side:
        # per-link payload sizes × device-side transfer counts.
        self.link_bytes_totals = np.zeros((self.num_links,), np.int64)
        self.msg_bytes = network.msg_bytes if network is not None else 64
        self.link_payload_bytes = np.full((m,), self.model_bytes, np.int64)
        if self.tiers is not None:
            self.link_payload_bytes = np.concatenate([
                self.link_payload_bytes,
                np.full((self.tiers.num_clusters,), self.inter_model_bytes,
                        np.int64)])

        # under a fleet the jitted callables run (and hence TRACE) inside
        # the active-fleet context, so the compiled round's constrain_rows
        # pins resolve to this engine's mesh
        self._step = self._with_fleet(jax.jit(self._make_step()))
        self._chunk = self._with_fleet(jax.jit(self._make_chunk()))
        self._fold_step = jax.jit(self._make_fold(chunked=False))
        self._fold_chunk = jax.jit(self._make_fold(chunked=True))

        # telemetry plane (repro.telemetry): a recorder streaming one
        # schema'd record per round, materialized from the SAME per-chunk
        # fold fetch — the telemetry=None path above is untouched (and
        # stays bitwise vs the goldens)
        self.telemetry = telemetry
        self.recorder = None
        self._profiler = None
        if telemetry is not None:
            from repro.telemetry.recorder import RoundRecorder
            from repro.telemetry.trace import ChunkProfiler
            self._profiler = ChunkProfiler()
            self.recorder = RoundRecorder(
                telemetry, m=m, num_links=self.num_links,
                model_size=self.model_size, model_bytes=self.model_bytes,
                msg_bytes=self.msg_bytes,
                link_payload_bytes=self.link_payload_bytes,
                link_classes=self.link_class_names(),
                spec=self.spec.to_dict(),
                tiers=self._tiers_meta())
            self._fold_step_t = jax.jit(
                self._make_fold(chunked=False, telemetry=True))
            self._fold_chunk_t = jax.jit(
                self._make_fold(chunked=True, telemetry=True))

    # ------------------------------------------------------------------
    def _with_fleet(self, fn):
        """Run ``fn`` under this engine's active-fleet context (identity
        without one). The compiled round reads the fleet at trace time —
        and tracing happens inside the jitted call — so the wrapper must
        surround every dispatch, not just the first."""
        if self.fleet is None:
            return fn

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with shard.use_fleet(self.fleet):
                return fn(*args, **kwargs)

        return wrapped

    # ------------------------------------------------------------------
    def _make_step(self):
        loss_fn, opt = self.loss_fn, self.opt
        weights = self.sample_weights
        spec = self.spec
        faults = self.faults
        tiers = self.tiers
        track_div = self.track_divergence
        fleet = self.fleet
        m, net = self.m, self.network
        model_bytes = self.model_bytes
        inter_model_bytes = self.inter_model_bytes
        static_adj, mobile = self._static_adj, self._mobile
        bw, lat = self._link_bw, self._link_lat
        agg_bw, agg_lat = self._agg_bw, self._agg_lat
        # full availability needs no mask at all — the operators then follow
        # the pre-network code path, bitwise
        sample_masks = net is not None and not net.full_availability

        def local_update(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, loss

        def step(params, opt_state, sync_state, batches):
            t = (sync_state.step if tiers is None
                 else sync_state.inter.step)          # this round's index
            if faults is not None:
                # fault plane, pure in (fault_seed, t) like availability.
                # A learner REJOINING this round (crashed at t-1, up now)
                # lost its local state: its params / optimizer / per-
                # learner sync-state rows are zeroed — it rejoins COLD.
                # (Hierarchy extra state is cluster-indexed, not learner-
                # indexed, so it is left alone under tiers.)
                crashed = net_faults.crash_mask(faults, m, t)
                restart = net_faults.restart_mask(faults, m, t)
                params = net_faults.lose_state(params, restart, m)
                opt_state = net_faults.lose_state(opt_state, restart, m)
                if tiers is None:
                    sync_state = sync_state._replace(
                        extra=net_faults.lose_state(
                            sync_state.extra, restart, m))
            # availability means REACHABILITY: every learner still takes its
            # local SGD step; unavailable ones just cannot communicate
            upd, opt_upd, losses = jax.vmap(local_update)(
                params, opt_state, batches)
            if faults is not None:
                # a learner mid-outage is STATELESS, not just unreachable:
                # its training freezes (the update is discarded) and it
                # observes no loss this round
                params = net_faults.freeze_state(upd, params, crashed, m)
                opt_state = net_faults.freeze_state(
                    opt_upd, opt_state, crashed, m)
                losses = jnp.where(crashed, jnp.zeros_like(losses), losses)
                # corrupted / Byzantine rows are perturbed IN the carry:
                # the garbage is what the fleet syncs against, and it
                # persists until a commit (or quarantine warm-start)
                # overwrites the row
                params = net_faults.perturb_params(faults, params, m, t)
            else:
                params, opt_state = upd, opt_upd
            active = (net_availability.sample(net, m, t)
                      if sample_masks else None)
            if faults is not None:
                # crashed + bursting learners drop out of the availability
                # mask — the composition only ever REMOVES learners
                active = net_faults.compose_active(faults, active, m, t)
            if tiers is None:
                adj = (net_topology.adjacency(net, m, t) if mobile
                       else static_adj)
                res = ops.apply_staged(
                    spec, params, sync_state, weights, active=active,
                    adjacency=adj)
                params, sync_state, rec = res.params, res.state, res.rec
                xfers = res.xfers
                # the ledger's inputs: transfer + message counts per link
                # (priced into bytes host-side, in int64)
                link_counts = jnp.stack([xfers, res.link_msgs], axis=-1)
                if net is not None:
                    net_time = net_cost.round_network_time(
                        xfers, res.link_msgs, model_bytes, bw, lat)
                else:
                    net_time = jnp.float32(0.0)
            else:
                # the intra tier runs THIS engine's (possibly asyncified)
                # spec — resolve_spec on a spec is the identity, so the
                # hierarchy sees exactly the stages the flat path would
                hres = apply_hierarchical(
                    spec, tiers, params, sync_state, weights, active)
                params, sync_state, rec = hres.params, hres.state, hres.rec
                xfers = hres.member_xfers
                link_counts = jnp.stack([
                    jnp.concatenate([hres.member_xfers, hres.agg_xfers]),
                    jnp.concatenate([hres.member_msgs, hres.agg_msgs]),
                ], axis=-1)
                if net is not None:
                    # the round's network time is the two tiers back to
                    # back: members sync with their aggregator, then the
                    # aggregators with the top coordinator
                    net_time = (
                        net_cost.round_network_time(
                            hres.member_xfers, hres.member_msgs,
                            model_bytes, bw, lat)
                        + net_cost.round_network_time(
                            hres.agg_xfers, hres.agg_msgs,
                            inter_model_bytes, agg_bw, agg_lat))
                else:
                    net_time = jnp.float32(0.0)
            if fleet is not None:
                # pin the committed carry back to its input placement so
                # chunk-to-chunk carry sharding is a fixpoint (no reshard
                # between calls); leaves without a leading learner axis
                # (e.g. scalar optimizer counts) pass through untouched
                params = shard.constrain_fleet(fleet, params)
                opt_state = shard.constrain_fleet(fleet, opt_state)
            div = divergence(params) if track_div else jnp.zeros(())
            num_active = (jnp.sum(active).astype(jnp.int32)
                          if active is not None else jnp.int32(m))
            # async-timeline observability: summarize the trigger-carried
            # state AFTER the round. Key membership is static, so
            # protocols without a timeline/age trade zero device work for
            # the constant zeros.
            extra = (sync_state.extra if tiers is None
                     else sync_state.intra.extra)
            num_inflight = (jnp.sum(extra["inflight"] > 0).astype(jnp.int32)
                            if "inflight" in extra else jnp.int32(0))
            age_key = next(
                (k for k in ("age", "staleness") if k in extra), None)
            max_age = (jnp.max(extra[age_key]).astype(jnp.int32)
                       if age_key is not None else jnp.int32(0))
            # fault/robustness observability — same static-key-membership
            # pattern: fault-free runs of non-robust specs trade zero
            # device work for the constant zeros
            num_faulty = (net_faults.num_faulty(faults, m, t)
                          if faults is not None else jnp.int32(0))
            num_quar = (jnp.sum(extra["health"] > 0).astype(jnp.int32)
                        if "health" in extra else jnp.int32(0))
            num_rec = (jnp.sum(extra["recovered"]).astype(jnp.int32)
                       if "recovered" in extra else jnp.int32(0))
            return params, opt_state, sync_state, ProtocolMetrics(
                losses, rec, div, num_active, net_time, xfers, link_counts,
                num_inflight, max_age, num_faulty, num_quar, num_rec)

        return step

    def _make_chunk(self):
        """n rounds as ONE compiled program: jax.lax.scan over the round
        axis, carry = (params, opt_state, sync_state), stacked per-round
        ``ProtocolMetrics`` as the scan output. Dispatch (and the host
        sync on counters) happens once per chunk instead of once per round,
        which is where the per-round Python loop spent nearly all of its
        wall-clock at simulator scale."""
        step = self._make_step()

        def chunk(params, opt_state, sync_state, batches):
            def body(carry, batch):
                params, opt_state, sync_state = carry
                params, opt_state, sync_state, metrics = step(
                    params, opt_state, sync_state, batch)
                return (params, opt_state, sync_state), metrics

            (params, opt_state, sync_state), metrics = jax.lax.scan(
                body, (params, opt_state, sync_state), batches)
            return params, opt_state, sync_state, metrics

        return chunk

    # ------------------------------------------------------------------
    def _make_fold(self, chunked: bool, telemetry: bool = False):
        """The host-counter fold as ONE device program: every per-call
        reduction the cumulative counters need, computed on device and
        fetched in a single transfer — ``step``/``run_chunk`` used to pay
        ~6 separate ``float(...)``/``int(...)``/``np.asarray(...)``
        device syncs per call.

        With ``telemetry`` the fold additionally carries the PER-ROUND
        series the recorder materializes records from (``per_round``: a
        dict of (n, ...) arrays) — still one device program and one
        transfer; the ``telemetry=False`` program is byte-identical to
        the pre-telemetry fold."""
        fields = ops.CommRecord._fields
        carries_state = bool(self.spec.extra_state)
        has_faults = self.faults is not None
        carries_health = "health" in self.spec.extra_state

        def fold(metrics: ProtocolMetrics):
            if chunked:     # leaves carry a leading round axis: reduce it
                out = {
                    "loss": jnp.sum(metrics.loss_per_learner),
                    "loss_per_learner": jnp.sum(
                        metrics.loss_per_learner, axis=0),
                    "comm": {k: jnp.sum(getattr(metrics.comm, k))
                             for k in fields},
                    "net_time": jnp.sum(metrics.net_time),
                    "num_active": jnp.sum(metrics.num_active),
                    "link_xfers": jnp.sum(metrics.link_xfers, axis=0),
                    "link_counts": jnp.sum(metrics.link_counts, axis=0),
                }
            else:
                out = {
                    "loss": jnp.sum(metrics.loss_per_learner),
                    "loss_per_learner": metrics.loss_per_learner,
                    "comm": {k: getattr(metrics.comm, k) for k in fields},
                    "net_time": metrics.net_time,
                    "num_active": metrics.num_active,
                    "link_xfers": metrics.link_xfers,
                    "link_counts": metrics.link_counts,
                }
            if telemetry:
                # normalize the single-round case to a length-1 round axis
                lead = (lambda x: x) if chunked else (lambda x: x[None])
                out["per_round"] = {
                    "loss": jnp.sum(lead(metrics.loss_per_learner), axis=1),
                    "divergence": lead(metrics.divergence),
                    "num_active": lead(metrics.num_active),
                    "net_time": lead(metrics.net_time),
                    "comm": {k: lead(getattr(metrics.comm, k))
                             for k in fields},
                    "link_counts": lead(metrics.link_counts),
                }
                if carries_state:
                    # in-flight / staleness-age series, only for triggers
                    # that actually carry state (async timeline, stale) —
                    # records of stateless runs stay unchanged
                    out["per_round"]["num_inflight"] = lead(
                        metrics.num_inflight)
                    out["per_round"]["max_age"] = lead(metrics.max_age)
                # fault-plane / robust-trigger series: key membership is
                # static, so JSONL streams of fault-free runs of the
                # non-robust presets stay byte-identical
                if has_faults:
                    out["per_round"]["num_faulty"] = lead(
                        metrics.num_faulty)
                if carries_health:
                    out["per_round"]["num_quarantined"] = lead(
                        metrics.num_quarantined)
                    out["per_round"]["num_recovered"] = lead(
                        metrics.num_recovered)
            return out

        return fold

    def _accumulate(self, host: dict, n: int) -> None:
        """Fold one call's (already host-side) reductions into the
        cumulative counters."""
        self.rounds += n
        per = host.get("per_round")
        if per is None:
            self.cumulative_loss += float(host["loss"])
            self.network_time += float(host["net_time"])
        else:
            # telemetry attached: accumulate the float counters as the
            # SEQUENTIAL float64 sum of the per-round series — exactly
            # the ``base + np.cumsum`` arithmetic the recorder's cum_*
            # columns use, so the stream's last record equals these
            # counters bitwise (np.sum pairwise-reassociates; cumsum[-1]
            # is the running sum)
            self.cumulative_loss += float(
                np.cumsum(np.asarray(per["loss"], np.float64))[-1])
            self.network_time += float(
                np.cumsum(np.asarray(per["net_time"], np.float64))[-1])
        self.cumulative_loss_per_learner += host["loss_per_learner"]
        if not self._nonfinite_reported:
            bad = ~np.isfinite(self.cumulative_loss_per_learner)
            if bad.any() or not np.isfinite(self.cumulative_loss):
                # one-shot: the first fold where any loss counter goes
                # non-finite names the offending learners, then stays
                # quiet — a diverging fleet would otherwise flood
                self._nonfinite_reported = True
                sink.get_logger().event(
                    "nonfinite_loss", round=self.rounds,
                    learners=[int(i) for i in np.flatnonzero(bad)])
        for k in ops.CommRecord._fields:
            self.comm_totals[k] += int(host["comm"][k])
        self.active_rounds_total += int(host["num_active"])
        self.link_xfer_totals += host["link_xfers"].astype(np.int64)
        self.link_bytes_totals += self.price_link_counts(
            host["link_counts"].astype(np.int64))

    # ------------------------------------------------------------------
    def step(self, batches) -> ProtocolMetrics:
        """One round. ``batches``: pytree with leading (m, B, ...) leaves."""
        if self.fleet is not None:
            # each device receives only its own learners' samples — the
            # batch never materializes whole on any single device
            batches = shard.put_fleet(self.fleet, batches, axis=0)
        if self.recorder is not None:
            return self._run_observed(self._step, self._fold_step_t,
                                      batches, 1)
        self.params, self.opt_state, self.sync_state, metrics = self._step(
            self.params, self.opt_state, self.sync_state, batches)
        self._accumulate(jax.device_get(self._fold_step(metrics)), 1)
        return metrics

    # ------------------------------------------------------------------
    def run_chunk(self, batches) -> ProtocolMetrics:
        """n rounds in one compiled program (the scanned dual of ``step``).

        ``batches``: pytree with leading (n, m, B, ...) leaves — round t of
        the chunk is ``batches[t]``. Returns stacked ``ProtocolMetrics``
        whose leaves carry the round axis: ``loss_per_learner`` is (n, m),
        every ``CommRecord`` field is (n,). Host-side cumulative counters
        are folded in once per chunk — one device reduction program plus
        one transfer; protocol numerics are identical to n
        calls of ``step`` (same traced round function), so comm counters
        match bitwise and losses to float32 summation order.

        jit recompiles per distinct chunk length n — drive it with a fixed
        chunk size (plus at most one remainder) as ``train.loop`` does.
        """
        n = int(jax.tree.leaves(batches)[0].shape[0])
        if self.fleet is not None:   # (n, m, B, ...): the learner axis is 1
            batches = shard.put_fleet(self.fleet, batches, axis=1)
        if self.recorder is not None:
            return self._run_observed(self._chunk, self._fold_chunk_t,
                                      batches, n)
        self.params, self.opt_state, self.sync_state, metrics = self._chunk(
            self.params, self.opt_state, self.sync_state, batches)
        self._accumulate(jax.device_get(self._fold_chunk(metrics)), n)
        return metrics

    # ------------------------------------------------------------------
    def _run_observed(self, compute, fold, batches, n: int):
        """The telemetered dual of ``step``/``run_chunk``: identical
        device programs (the round/chunk computation is byte-for-byte the
        untelemetered one — only the fold carries the extra ``per_round``
        reductions), ONE ``device_get`` of (fold output, trigger-carried
        state snapshot), then host-side record materialization."""
        cfg = self.telemetry
        profiling = cfg.profile
        compiled = self._profiler.begin(n) if profiling else None
        base = self.counters_snapshot()
        t0 = time.perf_counter() if profiling else None
        ctx = (self._step_annotation() if cfg.jax_profiler
               else contextlib.nullcontext())
        with ctx:
            self.params, self.opt_state, self.sync_state, metrics = compute(
                self.params, self.opt_state, self.sync_state, batches)
            # one transfer, and it blocks on the whole round program —
            # the wall-clock below covers execution, not async dispatch
            host, extra = jax.device_get(
                (fold(metrics), self._state_extra()))
        wall = time.perf_counter() - t0 if profiling else None
        if profiling:
            self._profiler.observe(n, wall)
        self._accumulate(host, n)
        self.recorder.observe(
            host["per_round"], base, extra, n, wall_s=wall,
            compiled=compiled,
            recompiles=self._profiler.recompiles if profiling else None)
        return metrics

    def _step_annotation(self):
        from repro.telemetry.trace import step_annotation
        return step_annotation("repro_round", self.rounds)

    def _state_extra(self):
        """The trigger-declared carried state (e.g. staleness ages) as a
        device pytree — snapshotted once per observed chunk."""
        if self.tiers is not None:
            return {"intra": self.sync_state.intra.extra,
                    "inter": self.sync_state.inter.extra}
        return self.sync_state.extra

    def link_class_names(self):
        """(L,) link-class names matching the ledger's rows: learner
        links in round-robin ``NetworkConfig.link_classes`` order
        (``"ideal"`` without a network), then the aggregator uplinks'
        class under a hierarchy."""
        if self.network is None:
            names = ["ideal"] * self.m
        else:
            lc = self.network.link_classes
            names = [lc[i % len(lc)] for i in range(self.m)]
        if self.tiers is not None:
            names += [self.tiers.link_class] * self.tiers.num_clusters
        return tuple(names)

    def _tiers_meta(self):
        if self.tiers is None:
            return None
        return {
            "num_clusters": self.tiers.num_clusters,
            "link_class": self.tiers.link_class,
            "inter": resolve_spec(self.tiers.inter).to_dict(),
        }

    # ------------------------------------------------------------------
    def counters_snapshot(self) -> dict:
        """The cumulative counters the telemetry plane bases its per-round
        ``cum_*`` series on — taken BEFORE a chunk is accumulated."""
        return {
            "rounds": self.rounds,
            "cumulative_loss": self.cumulative_loss,
            "network_time": self.network_time,
            "syncs": self.comm_totals["syncs"],
            "cum_bytes": self.comm_bytes(),
            "link_bytes_totals": self.link_bytes_totals.copy(),
        }

    def counters_state(self) -> dict:
        """JSON-ready snapshot of ALL cumulative counters, for
        checkpointing (``repro.checkpoint.io.save_protocol_state``): a
        resumed run restores these and its telemetry stream continues as
        one continuous record."""
        return {
            "rounds": int(self.rounds),
            "cumulative_loss": float(self.cumulative_loss),
            "cumulative_loss_per_learner": [
                float(x) for x in self.cumulative_loss_per_learner],
            "comm_totals": {k: int(v) for k, v in self.comm_totals.items()},
            "network_time": float(self.network_time),
            "active_rounds_total": int(self.active_rounds_total),
            "link_xfer_totals": [int(x) for x in self.link_xfer_totals],
            "link_bytes_totals": [int(x) for x in self.link_bytes_totals],
        }

    def restore_counters(self, d: dict) -> None:
        """Restore counters saved by :meth:`counters_state`. With a
        recorder attached, re-emits the stream's meta record tagged with
        the resume point so the JSONL stays self-describing."""
        if len(d["cumulative_loss_per_learner"]) != self.m:
            raise ValueError(
                f"counters were saved for m="
                f"{len(d['cumulative_loss_per_learner'])} learners, "
                f"this engine has m={self.m}")
        if len(d["link_bytes_totals"]) != self.num_links:
            raise ValueError(
                f"counters were saved for {len(d['link_bytes_totals'])} "
                f"links, this engine has {self.num_links} (did the "
                f"hierarchy change?)")
        unknown = sorted(set(d["comm_totals"]) - set(self.comm_totals))
        if unknown:
            raise ValueError(f"unknown comm counters in checkpoint: "
                             f"{unknown}")
        self.rounds = int(d["rounds"])
        self.cumulative_loss = float(d["cumulative_loss"])
        self.cumulative_loss_per_learner = np.asarray(
            d["cumulative_loss_per_learner"], np.float32)
        self.comm_totals = {k: int(v) for k, v in d["comm_totals"].items()}
        self.network_time = float(d["network_time"])
        self.active_rounds_total = int(d["active_rounds_total"])
        self.link_xfer_totals = np.asarray(d["link_xfer_totals"], np.int64)
        self.link_bytes_totals = np.asarray(
            d["link_bytes_totals"], np.int64)
        if self.recorder is not None:
            self.recorder.resume(self.rounds)

    # ------------------------------------------------------------------
    def price_link_counts(self, counts: np.ndarray) -> np.ndarray:
        """(..., L, 2) int64 [transfers, messages] -> (..., L) int64 bytes:
        each link's tier payload size times its transfers, plus the control
        messages it sent — exact host-side int64 math, immune to the
        billion-parameter payload sizes that would overflow device int32."""
        return (counts[..., 0] * self.link_payload_bytes
                + counts[..., 1] * self.msg_bytes)

    # ------------------------------------------------------------------
    def comm_bytes_of(self, totals, msg_bytes: Optional[int] = None) -> int:
        """Bytes for a comm-counter dict (paper's c(f) accounting).
        ``msg_bytes`` defaults to the configured ``NetworkConfig.msg_bytes``
        (64 on an ideal network)."""
        if msg_bytes is None:
            msg_bytes = self.network.msg_bytes if self.network else 64
        return (
            (totals["model_up"] + totals["model_down"]) * self.model_bytes
            + totals["messages"] * msg_bytes
        )

    def comm_bytes(self, msg_bytes: Optional[int] = None) -> int:
        """Cumulative communication in bytes (paper's c(f) accounting).

        Under a hierarchy the tiers move different payload sizes, so the
        scalar ``transfers × model_bytes`` formula no longer applies — the
        total is the bytes ledger's sum (exact; ``msg_bytes`` overrides are
        ignored because the configured size is already priced in)."""
        if self.tiers is not None:
            return int(self.link_bytes_totals.sum())
        return self.comm_bytes_of(self.comm_totals, msg_bytes)

    def per_link_bytes(self) -> np.ndarray:
        """The bytes ledger: (L,) cumulative int64 bytes each link carried
        — model payloads at that link's tier payload size PLUS the control
        messages the link sent (violation notices on violators' links,
        poll requests on polled members' links). Rows ``0..m-1`` are the
        learner links; under a hierarchy rows ``m..m+g-1`` are the
        aggregator↔top-coordinator uplinks.

        For coordinator protocols (periodic/fedavg/dynamic, flat or
        hierarchical) ``sum(per_link_bytes()) == comm_bytes()`` — the
        ledger is the per-link breakdown of the paper's c(f), exact even
        with per-tier payload sizes. For ``gossip`` every transfer
        occupies BOTH endpoints' links, so the ledger's sum is exactly
        ``2 * comm_bytes()`` (link occupancy, not fleet throughput)."""
        return self.link_bytes_totals.copy()

    def mean_active(self) -> float:
        """Average fraction of the fleet reachable per executed round."""
        if self.rounds == 0:
            return 1.0
        return self.active_rounds_total / (self.rounds * self.m)

    def mean_model(self):
        from repro.core.divergence import tree_mean
        return tree_mean(self.params)

    def learner_model(self, i: int):
        return jax.tree.map(lambda x: x[i], self.params)


# ---------------------------------------------------------------------------
# serial baseline (paper's ``serial``: one model, all data)
# ---------------------------------------------------------------------------

class SerialLearner:
    """One model, all data — scanned the same way the fleet engine is:
    ``run_chunk`` rolls n rounds into one ``lax.scan`` program, so
    benchmarks sweeping the serial reference pay one jitted dispatch per
    chunk instead of one per round."""

    def __init__(self, loss_fn, init_fn, train: TrainConfig = TrainConfig(),
                 seed: int = 0):
        self.loss_fn = loss_fn
        self.opt = make_optimizer(train)
        self.params = init_fn(jax.random.PRNGKey(seed))
        self.opt_state = self.opt.init(self.params)
        self.cumulative_loss = 0.0

        def _round(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = self.opt.update(params, grads, opt_state)
            return params, opt_state, loss

        @jax.jit
        def _chunk(params, opt_state, batches):
            def body(carry, batch):
                params, opt_state = carry
                params, opt_state, loss = _round(params, opt_state, batch)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), batches)
            return params, opt_state, losses

        self._step = jax.jit(_round)
        self._chunk = _chunk

    def step(self, batch):
        self.params, self.opt_state, loss = self._step(
            self.params, self.opt_state, batch)
        self.cumulative_loss += float(loss)
        return loss

    def run_chunk(self, batches) -> jnp.ndarray:
        """n rounds as one compiled program. ``batches``: pytree with
        leading (n, B, ...) leaves — round t consumes ``batches[t]``.
        Returns the (n,) per-round losses; numerics are identical to n
        ``step`` calls (same traced round body, and ``cumulative_loss``
        accumulates the per-round losses in float64 exactly like the
        per-round driver), so both the loss curve and the running total
        match the ``step`` loop bitwise. jit recompiles per distinct chunk
        length — drive it with a fixed chunk size as ``train.loop`` does."""
        self.params, self.opt_state, losses = self._chunk(
            self.params, self.opt_state, batches)
        # one host transfer + one float64 sum instead of a Python loop.
        # Bitwise-identical to the per-round accumulation whenever the
        # chunk's float32 losses stay within ~29 bits of dynamic range of
        # each other (then every float64 partial sum of the 24-bit-
        # mantissa terms is exact and association cannot matter — pinned
        # by test_serial_run_chunk_matches_step_loop_bitwise); a chunk
        # mixing wildly diverged and normal losses may differ from the
        # step loop in the last ulp
        self.cumulative_loss += float(np.asarray(losses, np.float64).sum())
        return losses


def make_protocol(kind: str, **kw) -> ProtocolConfig:
    return ProtocolConfig(kind=kind, **kw)
