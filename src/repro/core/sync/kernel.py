"""The preset table: the paper's sigmas as ``ProtocolSpec`` compositions.

The six built-in protocol kinds are nothing but specs over the registered
stage library (``repro.core.sync.stages``), entered into the ``PROTOCOLS``
registry — ``ProtocolConfig(kind=...)`` is sugar that resolves to the
preset with the config's parameter fields overlaid
(``repro.core.sync.spec.resolve_spec``):

  * ``nosync``      — trigger=never (identity)
  * ``periodic``    — sigma_b: cadence -> all-reachable -> mean -> average
  * ``continuous``  — sigma_b with b=1, same composition
  * ``fedavg``      — cadence -> random C-fraction -> mean -> subset
                      (McMahan et al.)
  * ``dynamic``     — sigma_Delta: divergence -> balancing augmentation ->
                      mean -> balancing commit (Algorithm 1 / Algorithm 2)
  * ``gossip``      — cadence -> neighborhood -> M–H mix -> mix
                      (coordinator-free, over the network topology)

Every preset compiles through the one generic skeleton in ``spec.py`` and
is bitwise-identical to the pre-spec monolithic operators
(``tests/golden_pr2_engine.json`` pins the PR-2 engine). New protocols
register stages + a spec (see ``repro.core.sync.staleness``) — this
module and the engine need no edits.

``apply_operator`` keeps the pre-kernel 4-tuple signature
``(new_config, new_state, CommRecord, xfers)``; ``apply_staged`` is the
same dispatch returning the full ``StageResult`` (its extra ``link_msgs``
field carries the per-link control-message counts — violation notices on
the violators' links, poll requests on the polled members' links — the
second input of the engine's per-link bytes ledger; ``sum(link_msgs) ==
CommRecord.messages`` always). Both accept a ``ProtocolConfig`` or a
``ProtocolSpec``.

Availability (``active``: optional (m,) bool mask from
``repro.network.availability``): unavailable learners keep training
locally but cannot communicate — they neither violate, nor get polled,
nor receive averages. ``active=None`` is the ideal always-on network and
preserves the pre-network engine's numerics bitwise.

Layout (the global ``layout`` spec param, ``ProtocolConfig.layout``
sugar): every preset runs either on the per-leaf pytree expressions
(``"tree"``, the default — bitwise vs the goldens) or on the flat
(m, P) fleet-plane (``"flat"``, ``repro.core.flatten`` — params to
float-reassociation tolerance, identical sync decisions hence bitwise
comm counters away from razor-edge threshold ties, balancing in
O(m*P)). The same registered stages serve both; no preset is
layout-specific.
"""
from __future__ import annotations

from typing import Optional

import jax

# re-exported shared types (the historical import surface)
from repro.core.sync.registry import (  # noqa: F401
    CommRecord, PROTOCOLS, StageContract, StageResult, SyncState,
    register_protocol,
)
from repro.core.sync.spec import (
    _CONFIG_PARAM_FIELDS, ProtocolSpec, resolve_spec,
)


def init_state(ref_model, seed: int = 0,
               spec: Optional[ProtocolSpec] = None,
               m: Optional[int] = None) -> SyncState:
    """Fresh carried state. ``spec`` + ``m`` build the spec's extra
    carried state (e.g. the staleness counters); the built-in presets
    carry none, so plain ``init_state(ref)`` keeps working."""
    extra = {}
    if spec is not None and spec.extra_state:
        if m is None:
            raise ValueError(
                f"spec {spec.name or spec.trigger!r} carries extra state "
                f"{spec.extra_state} — init_state needs the fleet size m")
        extra = spec.init_extra(m)
    return SyncState(
        ref=ref_model,
        v=jax.numpy.zeros((), jax.numpy.int32),
        rng=jax.random.PRNGKey(seed),
        step=jax.numpy.zeros((), jax.numpy.int32),
        extra=extra,
    )


# ---------------------------------------------------------------------------
# the preset table — the ONLY place protocol kinds are enumerated
# ---------------------------------------------------------------------------

register_protocol("nosync", ProtocolSpec(name="nosync", trigger="never"))
register_protocol("periodic", ProtocolSpec(name="periodic",
                                           trigger="cadence"))
register_protocol("continuous", ProtocolSpec(name="continuous",
                                             trigger="cadence"))
register_protocol("fedavg", ProtocolSpec(name="fedavg", trigger="cadence",
                                         cohort="fraction",
                                         commit="subset"))
register_protocol("dynamic", ProtocolSpec(name="dynamic",
                                          trigger="divergence",
                                          cohort="balanced",
                                          commit="balancing"))
register_protocol("gossip", ProtocolSpec(name="gossip", trigger="cadence",
                                         cohort="neighborhood",
                                         aggregate="mix", commit="mix"))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def apply_staged(proto, stacked, state: SyncState, weights=None,
                 active=None, adjacency=None) -> StageResult:
    """Run one round of the configured protocol (a ``ProtocolConfig`` or a
    ``ProtocolSpec``), returning the full ``StageResult`` (the engine's
    entry — per-link control-message counts feed the bytes ledger).

    ``active``: optional (m,) bool reachability mask for this round;
    ``adjacency``: optional (m, m) bool peer overlay (required by specs
    with ``uses_overlay``, e.g. gossip).
    """
    spec = resolve_spec(proto)
    if not spec.param("weighted"):
        weights = None
    return spec.compile()(stacked, state, weights, active=active,
                          adjacency=adjacency)


def apply_operator(proto, stacked, state: SyncState, weights=None,
                   active=None, adjacency=None):
    """The pre-kernel entry point, signature unchanged: returns
    ``(new_config, new_state, CommRecord, xfers)``."""
    res = apply_staged(proto, stacked, state, weights, active=active,
                       adjacency=adjacency)
    return res.params, res.state, res.rec, res.xfers


# ---------------------------------------------------------------------------
# legacy named operators (compatibility surface): each forces its preset's
# composition and reads parameters from the passed config
# ---------------------------------------------------------------------------

def _preset_op(kind: str):
    def op(cfg, stacked, state: SyncState, weights=None, active=None,
           adjacency=None) -> StageResult:
        preset = PROTOCOLS[kind]
        overrides = {f: getattr(cfg, f) for f in _CONFIG_PARAM_FIELDS
                     if f in preset.known_params and hasattr(cfg, f)}
        spec = preset.with_params(**overrides)
        # pre-spec contract of the NAMED operators: an explicitly passed
        # ``weights`` is used as-is — the weighted/unweighted gate lives
        # in ``apply_staged``, not here
        return spec.compile()(stacked, state, weights, active=active,
                              adjacency=adjacency)
    op.__name__ = kind
    op.__doc__ = (f"The {kind!r} preset as a standalone operator "
                  f"(weights, when passed, are applied as-is).")
    return op


nosync = _preset_op("nosync")
periodic = _preset_op("periodic")
fedavg = _preset_op("fedavg")
dynamic = _preset_op("dynamic")
gossip = _preset_op("gossip")

OPERATORS = {
    "nosync": nosync,
    "periodic": periodic,
    "continuous": periodic,     # cfg.b == 1
    "fedavg": fedavg,
    "dynamic": dynamic,
    "gossip": gossip,
}
