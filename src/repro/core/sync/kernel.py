"""Operator compositions: the paper's sigmas as staged-kernel pipelines.

Every operator below is a thin wiring of the stage library
(``repro.core.sync.stages``): trigger → cohort → aggregate → commit. The
compositions return a ``StageResult`` whose extra ``link_msgs`` field
carries the per-link control-message counts (violation notices sent on the
violators' links, poll requests on the polled members' links), the second
input of the engine's per-link bytes ledger; ``sum(link_msgs) ==
CommRecord.messages`` always.

``apply_operator`` keeps the pre-kernel 4-tuple signature
``(new_config, new_state, CommRecord, xfers)`` and its numerics are
bitwise-identical to the monolithic operators it replaced
(``tests/golden_pr2_engine.json`` pins the PR-2 engine); ``apply_staged``
is the same dispatch returning the full ``StageResult``.

Implemented operators:
  * ``nosync``      — identity
  * ``periodic_b``  — sigma_b: full average every b rounds (b=1: continuous)
  * ``fedavg``      — sigma_b over a random C-fraction subset (McMahan et al.)
  * ``dynamic``     — sigma_Delta: local conditions + coordinator balancing
                      (Algorithm 1), optionally weighted (Algorithm 2)
  * ``gossip``      — coordinator-free neighborhood averaging over the
                      network topology (Metropolis–Hastings mixing)

Availability (``active``: optional (m,) bool mask from
``repro.network.availability``): unavailable learners keep training locally
but cannot communicate — they neither violate, nor get polled, nor receive
averages, and ``dynamic``'s balancing cohort augments only over reachable
learners. ``active=None`` is the ideal always-on network and preserves the
pre-network engine's numerics bitwise.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig
from repro.core.sync import stages


class SyncState(NamedTuple):
    ref: object          # reference model r (single-model pytree)
    v: jnp.ndarray       # violation counter (scalar int32)
    rng: jnp.ndarray     # PRNG key for subsampling / random augmentation
    step: jnp.ndarray    # round counter t (scalar int32)


class CommRecord(NamedTuple):
    model_up: jnp.ndarray     # models sent learner -> coordinator
    model_down: jnp.ndarray   # models sent coordinator -> learner
    messages: jnp.ndarray     # small control messages (violations, polls)
    syncs: jnp.ndarray        # 1 if any averaging happened this round
    full_syncs: jnp.ndarray   # 1 if ALL (reachable) learners were averaged

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.int32)
        return CommRecord(z, z, z, z, z)


class StageResult(NamedTuple):
    """What one staged round produces: the committed configuration, the
    carried sync state, the scalar comm record, and the per-link counts
    (model transfers + control messages) the bytes ledger prices."""
    params: object
    state: SyncState
    rec: CommRecord
    xfers: jnp.ndarray       # (m,) int32 models crossing each learner's link
    link_msgs: jnp.ndarray   # (m,) int32 control messages per learner link


def init_state(ref_model, seed: int = 0) -> SyncState:
    return SyncState(
        ref=ref_model,
        v=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(seed),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# trivial composition
# ---------------------------------------------------------------------------

def nosync(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
           active=None, adjacency=None) -> StageResult:
    m = stages.num_learners(stacked)
    return StageResult(stacked, state._replace(step=state.step + 1),
                       CommRecord.zero(), stages.zeros_i32(m),
                       stages.zeros_i32(m))


# ---------------------------------------------------------------------------
# sigma_b: trigger=cadence, cohort=all-reachable, aggregate=mean
# ---------------------------------------------------------------------------

def periodic(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
             active=None, adjacency=None) -> StageResult:
    """sigma_b: replace every reachable model by their mean every b rounds."""
    m = stages.num_learners(stacked)
    t = state.step + 1

    def sync(_):
        if active is None:
            mean = stages.aggregate_mean_ideal(stacked, m, weights)
            newcfg = stages.broadcast_model(mean, m)
            rec = CommRecord(
                model_up=jnp.int32(m), model_down=jnp.int32(m),
                messages=jnp.int32(0), syncs=jnp.int32(1),
                full_syncs=jnp.int32(1))
            return newcfg, mean, rec, jnp.full((m,), 2, jnp.int32)
        mask = stages.cohort_all(m, active)
        nsync = jnp.sum(mask).astype(jnp.int32)
        mean = stages.aggregate_mean(stacked, mask, weights)
        newcfg = stages.commit_select(stacked, mask, mean)
        # the reference only moves when somebody was actually averaged
        new_ref = stages.commit_ref_if(nsync > 0, mean, state.ref)
        rec = CommRecord(
            model_up=nsync, model_down=nsync, messages=jnp.int32(0),
            syncs=(nsync > 0).astype(jnp.int32),
            # sigma_b always averages every reachable learner
            full_syncs=(nsync > 0).astype(jnp.int32))
        return newcfg, new_ref, rec, stages.xfers_cohort(mask)

    def skip(_):
        return stacked, state.ref, CommRecord.zero(), stages.zeros_i32(m)

    do = stages.cadence_fire(cfg, t)
    newcfg, ref, rec, xfers = jax.lax.cond(do, sync, skip, None)
    return StageResult(newcfg, state._replace(ref=ref, step=t), rec, xfers,
                       stages.zeros_i32(m))


# ---------------------------------------------------------------------------
# fedavg: trigger=cadence, cohort=random C-fraction, aggregate=mean
# ---------------------------------------------------------------------------

def fedavg(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
           active=None, adjacency=None) -> StageResult:
    """sigma_b on a random subset of ceil(C*m) learners (McMahan et al. '17).
    Under availability masks the subset is drawn from the REACHABLE
    learners only (partial client participation)."""
    m = stages.num_learners(stacked)
    t = state.step + 1
    k = max(1, int(round(cfg.fedavg_c * m)))

    def sync(rng):
        rng, sub = jax.random.split(rng)
        if active is None:
            mask = stages.cohort_fraction_ideal(sub, m, k)
            mean = stages.aggregate_mean(stacked, mask, weights)
            newcfg = stages.commit_select(stacked, mask, mean)
            rec = CommRecord(
                model_up=jnp.int32(k), model_down=jnp.int32(k),
                messages=jnp.int32(0), syncs=jnp.int32(1),
                full_syncs=jnp.int32(1 if k == m else 0))
            return newcfg, mean, rec, rng, stages.xfers_cohort(mask)
        mask = stages.cohort_fraction_masked(sub, m, k, active)
        nsel = jnp.sum(mask).astype(jnp.int32)
        mean = stages.aggregate_mean(stacked, mask, weights)
        newcfg = stages.commit_select(stacked, mask, mean)
        new_ref = stages.commit_ref_if(nsel > 0, mean, state.ref)
        rec = CommRecord(
            model_up=nsel, model_down=nsel, messages=jnp.int32(0),
            syncs=(nsel > 0).astype(jnp.int32),
            # full = the subset covered every reachable learner
            full_syncs=((nsel > 0) & (nsel == jnp.sum(active)))
            .astype(jnp.int32))
        return newcfg, new_ref, rec, rng, stages.xfers_cohort(mask)

    def skip(rng):
        return stacked, state.ref, CommRecord.zero(), rng, stages.zeros_i32(m)

    do = stages.cadence_fire(cfg, t)
    newcfg, ref, rec, rng, xfers = jax.lax.cond(do, sync, skip, state.rng)
    return StageResult(newcfg, state._replace(ref=ref, rng=rng, step=t), rec,
                       xfers, stages.zeros_i32(m))


# ---------------------------------------------------------------------------
# sigma_Delta: trigger=cadence+divergence, cohort=balancing augmentation
# (Algorithm 1 / Algorithm 2)
# ---------------------------------------------------------------------------

def dynamic(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
            active=None, adjacency=None) -> StageResult:
    """sigma_Delta with local conditions and balancing (Algorithm 1; with
    ``weights`` = B^i it is Algorithm 2 for unbalanced sampling rates).
    With an ``active`` mask only reachable learners violate, get polled,
    or receive averages; a "full" sync (reference reset, counter reset)
    is one that covers every reachable learner."""
    m = stages.num_learners(stacked)
    t = state.step + 1
    reach = jnp.ones((m,), bool) if active is None else active

    def check(args):
        stacked, state = args
        _, violated, nviol = stages.divergence_trigger(
            cfg, stacked, state.ref, reach)

        def no_violation(rng):
            return (stacked, state.ref, state.v,
                    CommRecord(jnp.int32(0), jnp.int32(0), jnp.int32(0),
                               jnp.int32(0), jnp.int32(0)), rng,
                    stages.zeros_i32(m), stages.zeros_i32(m))

        def violation(rng):
            rng, sub = jax.random.split(rng)
            v_new = state.v + nviol
            # if the counter reaches m, force a sync of every reachable
            # learner and reset it
            force_full = v_new >= m
            base = jnp.where(force_full, reach, violated)
            v_reset = jnp.where(force_full, jnp.int32(0), v_new)
            mask, mean = stages.cohort_balanced(
                cfg, stacked, state.ref, base, sub, weights, reach)
            full = jnp.all(mask == reach)
            v_final = jnp.where(full, jnp.int32(0), v_reset)
            newcfg = stages.commit_select(stacked, mask, mean)
            # reference model updates only on full sync (Algorithm 1)
            new_ref = stages.commit_ref_if(full, mean, state.ref)
            nsync = jnp.sum(mask).astype(jnp.int32)
            # every member of the final B that did not itself violate was
            # polled by the coordinator — counting nsync - nviol covers the
            # balancing loop AND the forced-full path (where the balanced
            # cohort starts from an all-true mask). Per link that is one
            # violation notice on each true violator's link and one poll
            # request on each polled member's link, so the ledger sees the
            # same chatter the scalar record counts.
            polls = nsync - nviol
            link_msgs = (violated.astype(jnp.int32)
                         + (mask & ~violated).astype(jnp.int32))
            rec = CommRecord(
                model_up=nsync,          # violators push + coordinator polls
                model_down=nsync,        # partial average pushed back to B
                messages=nviol + polls,  # violation notices + poll requests
                syncs=jnp.int32(1),
                full_syncs=full.astype(jnp.int32))
            return (newcfg, new_ref, v_final, rec, rng,
                    stages.xfers_cohort(mask), link_msgs)

        newcfg, ref, v, rec, rng, xfers, link_msgs = jax.lax.cond(
            nviol > 0, violation, no_violation, state.rng)
        return StageResult(
            newcfg, state._replace(ref=ref, v=v, rng=rng, step=t), rec,
            xfers, link_msgs)

    def skip(args):
        stacked, state = args
        return StageResult(stacked, state._replace(step=t), CommRecord.zero(),
                           stages.zeros_i32(m), stages.zeros_i32(m))

    do = stages.cadence_fire(cfg, t)
    return jax.lax.cond(do, check, skip, (stacked, state))


# ---------------------------------------------------------------------------
# gossip: cohort=masked neighborhood, aggregate=Metropolis–Hastings mix
# ---------------------------------------------------------------------------

def gossip(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
           active=None, adjacency=None) -> StageResult:
    """Neighborhood averaging over the network topology, no coordinator.

    Every b rounds each reachable learner exchanges models with its
    reachable neighbors and applies one Metropolis–Hastings mixing step
    (``stages.cohort_neighborhood``). ``weights`` (Algorithm 2 sample
    weights) are ignored — there is no coordinator to reweight the
    average; use a coordinator operator for unbalanced fleets.
    """
    m = stages.num_learners(stacked)
    t = state.step + 1
    if adjacency is None:
        raise ValueError(
            "gossip needs an adjacency matrix — configure a NetworkConfig "
            "topology (the engine passes it through)")
    act = jnp.ones((m,), bool) if active is None else active
    A, W = stages.cohort_neighborhood(m, active, adjacency)

    def sync(_):
        mixed = stages.aggregate_mix(stacked, W)
        edges = jnp.sum(A).astype(jnp.int32)           # directed count = 2E
        up = edges // 2
        na = jnp.sum(act).astype(jnp.int32)
        rec = CommRecord(
            model_up=up, model_down=edges - up,         # == up by symmetry
            messages=jnp.int32(0),
            syncs=(edges > 0).astype(jnp.int32),
            # "all reachable averaged": the active subgraph is complete, so
            # one mixing step couples every reachable learner
            full_syncs=((edges > 0) & (edges == na * (na - 1)))
            .astype(jnp.int32))
        return mixed, rec, stages.xfers_neighborhood(A)

    def skip(_):
        return stacked, CommRecord.zero(), stages.zeros_i32(m)

    do = stages.cadence_fire(cfg, t)
    newcfg, rec, xfers = jax.lax.cond(do, sync, skip, None)
    return StageResult(newcfg, state._replace(step=t), rec, xfers,
                       stages.zeros_i32(m))


OPERATORS = {
    "nosync": nosync,
    "periodic": periodic,
    "continuous": periodic,     # cfg.b == 1
    "fedavg": fedavg,
    "dynamic": dynamic,
    "gossip": gossip,
}


def apply_staged(cfg: ProtocolConfig, stacked, state: SyncState,
                 weights=None, active=None, adjacency=None) -> StageResult:
    """Dispatch to the configured composition, returning the full
    ``StageResult`` (the engine's entry — per-link control-message counts
    feed the bytes ledger).

    ``active``: optional (m,) bool reachability mask for this round;
    ``adjacency``: optional (m, m) bool peer overlay (required by gossip).
    """
    op = OPERATORS[cfg.kind]
    if not cfg.weighted:
        weights = None
    return op(cfg, stacked, state, weights, active=active,
              adjacency=adjacency)


def apply_operator(cfg: ProtocolConfig, stacked, state: SyncState,
                   weights=None, active=None, adjacency=None):
    """The pre-kernel entry point, signature unchanged: returns
    ``(new_config, new_state, CommRecord, xfers)``."""
    res = apply_staged(cfg, stacked, state, weights, active=active,
                       adjacency=adjacency)
    return res.params, res.state, res.rec, res.xfers
