"""Byzantine-robust synchronization: registered defenses for faulty fleets.

The fault plane (``repro.network.faults``) makes learners crash and
rejoin cold, ship NaN/Inf payloads, or adversarially sign-flip/scale
their updates. Against that, plain ``aggregate_mean`` is defenseless —
one non-finite row poisons the committed configuration AND the
reference model forever, and a single sign-flipper drags the mean far
from the honest fleet. This module lands the defenses as registered
stages — zero kernel/engine edits, the PR-4 contract:

* **robust aggregates** — coordinate-wise ``trimmed_mean`` (drop the
  ``trim_frac`` smallest and largest finite values per coordinate, mean
  the rest) and ``median``. Both are finite-guarded: a NaN/Inf entry is
  simply excluded from its coordinate's order statistics, so corrupted
  payloads cannot poison the aggregate. Both have tree and flat/sharded
  duals behind one registration and ignore Algorithm-2 weights by
  design (weighting by self-reported sample counts is itself an attack
  surface — an adversary would just claim the largest B^i).
* **the ``quarantine`` commit** — flags suspect cohort rows (any
  non-finite row, or one whose squared distance to the reference
  exceeds ``quarantine_mult`` x the cohort's finite median distance),
  withholds the aggregate from them, and warm-starts them from the
  reference model instead — the recovery path for crashed learners that
  rejoined cold AND for adversaries (whose rows get forcibly reset
  every sync). Its scalar CommRecord and per-link counts are
  expression-identical to ``commit_average``, so on an honest fleet the
  comm counters stay bitwise vs the ``mean``/``average`` pipeline.
* **robust triggers** — ``robust_cadence`` / ``robust_divergence`` are
  the cadence/divergence triggers plus per-learner health counters in
  ``SyncState.extra``: ``health`` counts CONSECUTIVE quarantined
  commits (reset to zero the first clean commit), ``recovered`` flags
  this round's recovery commits (a previously-quarantined learner whose
  commit came back clean). The engine surfaces them as
  ``num_quarantined``/``num_recovered`` per round.

Pair the quarantine commit with a robust aggregate: the aggregate
excludes bad values from WHAT is agreed on, the commit excludes bad
rows from WHO adopts it and heals them. (Quarantine + plain ``mean``
still warm-starts bad rows, but the mean they do not adopt — and the
reference — can still be dragged or poisoned.)

Presets: ``robust_periodic`` (robust_cadence -> all_reachable ->
trimmed_mean -> quarantine) and ``robust_dynamic`` (the same with the
divergence condition gating syncs). ``hardened(spec)`` rewrites any
cadence/divergence-triggered mean/average spec onto its robust
counterpart, mirroring ``asyncify``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.divergence import (
    per_learner_sq_distance, per_learner_sq_distance_flat,
)
from repro.core.sync.registry import (
    CohortOut, CommRecord, StageContract, StageCtx, SyncOut, carried_v,
    register_aggregate, register_commit, register_trigger,
)
from repro.core.sync.spec import ProtocolSpec
from repro.core.sync.kernel import register_protocol
from repro.core.sync.stages import (
    _broadcast_commit, _divergence_condition, _ref_if_commit,
    _select_commit, _validate_b, _validate_delta, broadcast_model,
    cadence_fire, tree_select, xfers_cohort, zeros_i32,
)

# absolute slack on the outlier threshold so a perfectly-converged
# cohort (median distance exactly zero) does not flag honest rows over
# float dust
_SUSPECT_EPS = 1e-12


# ---------------------------------------------------------------------------
# suspect-row detection (shared by the quarantine commit and the robust
# triggers' health counters — XLA CSE dedupes the repeated computation)
# ---------------------------------------------------------------------------

def _finite_rows(ctx: StageCtx) -> jnp.ndarray:
    """(m,) bool — rows whose every parameter is finite."""
    if ctx.flat is not None:
        return jnp.all(jnp.isfinite(ctx.flat), axis=1)
    finite = None
    for leaf in jax.tree.leaves(ctx.stacked):
        f = jnp.all(jnp.isfinite(leaf.reshape(leaf.shape[0], -1)), axis=1)
        finite = f if finite is None else finite & f
    return finite


def _row_dists(ctx: StageCtx) -> jnp.ndarray:
    """(m,) f32 squared distances to the reference model."""
    if ctx.flat is not None:
        return per_learner_sq_distance_flat(ctx.flat, ctx.ref_flat)
    return per_learner_sq_distance(ctx.stacked, ctx.state.ref)


def _masked_median(x: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Median of ``x[valid]`` (scalar; 0 when nothing is valid)."""
    order = jnp.sort(jnp.where(valid, x, jnp.inf))
    n = jnp.sum(valid).astype(jnp.int32)
    lo = order[jnp.maximum((n - 1) // 2, 0)]
    hi = order[n // 2]
    return jnp.where(n > 0, 0.5 * (lo + hi), jnp.zeros_like(lo))


def _suspect_rows(ctx: StageCtx, mask: jnp.ndarray) -> jnp.ndarray:
    """(m,) bool — cohort rows the quarantine flags: non-finite, or a
    distance-to-reference outlier (squared distance beyond
    ``quarantine_mult`` x the cohort's finite median). The median keeps
    its robustness as long as suspect rows stay a minority of the
    cohort — at >= 50% adversaries the median itself is captured, the
    classical breakdown point."""
    finite = _finite_rows(ctx)
    d = _row_dists(ctx)
    med = _masked_median(d, mask & finite)
    far = d > ctx.params["quarantine_mult"] * med + _SUSPECT_EPS
    return mask & (~finite | far)


# ---------------------------------------------------------------------------
# robust triggers: cadence/divergence + per-learner health counters
# ---------------------------------------------------------------------------

_HEALTH_STATE = (("health", "int32"), ("recovered", "int32"))


def _health(ctx: StageCtx):
    if "health" not in ctx.state.extra:
        raise ValueError(
            "the robust triggers carry per-learner health counters in "
            "SyncState.extra['health'/'recovered'] — build the state with "
            "init_state(ref, seed, spec=spec, m=m) (the engine does this "
            "automatically)")
    return ctx.state.extra["health"], ctx.state.extra["recovered"]


def _health_init(params, m: int):
    return {"health": jnp.zeros((m,), jnp.int32),
            "recovered": jnp.zeros((m,), jnp.int32)}


def _health_commit(ctx: StageCtx, mask):
    # ``health``: consecutive commits a learner was quarantined —
    # suspect rows increment, a clean commit resets to zero (that reset
    # IS the recovery: the learner re-adopted the fleet's aggregate),
    # learners outside the cohort keep their count. ``recovered`` marks
    # THIS round's recoveries (previously-quarantined learners whose
    # commit came back clean); it is per-round, not cumulative — an
    # unbounded int32 scan carry is exactly what the jaxpr auditor
    # forbids — so the engine folds the running total host-side, the
    # bytes-ledger pattern.
    h, _ = _health(ctx)
    bad = _suspect_rows(ctx, mask)
    cleared = mask & ~bad
    rec = (cleared & (h > 0)).astype(jnp.int32)
    h = jnp.where(bad, h + 1, jnp.where(cleared, jnp.int32(0), h))
    return {"health": h, "recovered": rec}


def _health_skip(ctx: StageCtx):
    h, _ = _health(ctx)
    return {"health": h, "recovered": jnp.zeros_like(h)}


def _robust_divergence_condition(ctx: StageCtx):
    # sigma_Delta's condition with a finite guard: a NaN distance
    # compares False against delta, so a NaN-corrupted learner would
    # never trip the plain condition and would drift unhealed between
    # cadence-less syncs. Here a reachable row with a non-finite
    # distance IS a violation — corruption forces the sync that
    # quarantines it. (Inf distances already violate; this closes NaN.)
    violated, _, aux = _divergence_condition(ctx)
    violated = violated | (~jnp.isfinite(aux["dists"]) & ctx.reach)
    return violated, jnp.sum(violated).astype(jnp.int32), aux


def _validate_mult(params):
    mult = params["quarantine_mult"]
    if not mult > 1.0:
        raise ValueError(
            f"quarantine_mult must be > 1 (a multiple of the cohort's "
            f"median squared distance), got {mult!r}")


def _validate_robust_cadence(params):
    _validate_b(params)
    _validate_mult(params)


def _validate_robust_divergence(params):
    _validate_delta(params)
    _validate_mult(params)


@register_trigger("robust_cadence", init_extra=_health_init,
                  commit_extra=_health_commit, skip_extra=_health_skip,
                  params={"b": 1, "quarantine_mult": 16.0},
                  validate=_validate_robust_cadence,
                  contract=StageContract(
                      summary="cadence gate + per-learner quarantine "
                              "health counters",
                      extra_state=_HEALTH_STATE))
def trigger_robust_cadence(ctx: StageCtx):
    """sigma_b's schedule with the quarantine health counters carried in
    ``SyncState.extra`` — the robust counterpart of ``cadence``."""
    return cadence_fire(ctx.params["b"], ctx.t)


@register_trigger("robust_divergence",
                  condition=_robust_divergence_condition,
                  init_extra=_health_init, commit_extra=_health_commit,
                  skip_extra=_health_skip,
                  params={"b": 1, "delta": 0.5, "quarantine_mult": 16.0},
                  validate=_validate_robust_divergence,
                  contract=StageContract(
                      summary="divergence condition + per-learner "
                              "quarantine health counters",
                      extra_state=_HEALTH_STATE, cond_aux=("dists",)))
def trigger_robust_divergence(ctx: StageCtx):
    """sigma_Delta's condition with the quarantine health counters — the
    robust counterpart of ``divergence``. The condition doubles as the
    fault alarm: an adversarial or cold-restarted row is far from the
    reference and a corrupted row has a non-finite distance
    (``_robust_divergence_condition``'s finite guard), so either pulls
    the fleet into a (robust) sync instead of drifting unhealed."""
    return cadence_fire(ctx.params["b"], ctx.t)


# ---------------------------------------------------------------------------
# robust aggregates: coordinate-wise trimmed mean and median
# ---------------------------------------------------------------------------

def _sorted_valid(X: jnp.ndarray, mask: jnp.ndarray):
    """Per-coordinate ascending sort of the masked FINITE entries
    (invalid entries pushed to the end as +inf) and the (P,) count of
    valid entries per coordinate."""
    valid = mask[:, None] & jnp.isfinite(X)
    order = jnp.sort(jnp.where(valid, X, jnp.inf), axis=0)
    n = jnp.sum(valid, axis=0).astype(jnp.int32)
    return order, n


def flat_trimmed_mean(X: jnp.ndarray, mask: jnp.ndarray,
                      trim_frac: float) -> jnp.ndarray:
    """Coordinate-wise trimmed mean over the plane's masked rows: per
    coordinate, drop the ``floor(trim_frac * n)`` smallest and largest
    finite values and mean the rest. ``trim_frac=0`` is the plain
    finite-guarded mean (to reassociation tolerance: the sum runs in
    sorted order). An all-invalid coordinate yields 0 — commits keep
    the previous configuration via their selects."""
    order, n = _sorted_valid(X, mask)
    k = jnp.floor(trim_frac * n.astype(X.dtype)).astype(jnp.int32)
    idx = jnp.arange(X.shape[0], dtype=jnp.int32)[:, None]
    keep = (idx >= k[None, :]) & (idx < (n - k)[None, :])
    cnt = jnp.maximum(n - 2 * k, 1).astype(X.dtype)
    out = jnp.sum(jnp.where(keep, order, jnp.zeros_like(order)),
                  axis=0) / cnt
    return jnp.where(n > 0, out, jnp.zeros_like(out))


def flat_median(X: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Coordinate-wise median over the plane's masked finite entries
    (midpoint of the two central order statistics for even counts)."""
    order, n = _sorted_valid(X, mask)
    lo = jnp.take_along_axis(order, jnp.maximum((n - 1) // 2, 0)[None, :],
                             axis=0)[0]
    hi = jnp.take_along_axis(order, (n // 2)[None, :], axis=0)[0]
    out = jnp.asarray(0.5, X.dtype) * (lo + hi)
    return jnp.where(n > 0, out, jnp.zeros_like(out))


def _tree_rowwise(stacked, fn):
    """Tree dual of a per-coordinate plane aggregate: each leaf runs the
    plane form on its own (m, cols) view in the promoted accumulation
    dtype (at least f32) and narrows back to the leaf dtype."""
    def leaf(x):
        acc = jnp.promote_types(x.dtype, jnp.float32)
        out = fn(x.reshape(x.shape[0], -1).astype(acc))
        return out.astype(x.dtype).reshape(x.shape[1:])
    return jax.tree.map(leaf, stacked)


def _validate_trim(params):
    tf = params["trim_frac"]
    if not 0.0 <= tf < 0.5:
        raise ValueError(
            f"trim_frac must be in [0, 0.5) — trimming half the cohort "
            f"from each side leaves nothing — got {tf!r}")


@register_aggregate("trimmed_mean", params={"trim_frac": 0.2},
                    validate=_validate_trim,
                    contract=StageContract(
                        summary="coordinate-wise finite-guarded trimmed "
                                "mean; ignores Algorithm-2 weights",
                        out="model"))
def aggregate_trimmed_mean(ctx: StageCtx, cout: CohortOut):
    """Coordinate-wise trimmed mean of the cohort. Robust to
    ``floor(trim_frac * n)`` arbitrary (even non-finite) values per
    coordinate; unweighted by design (see the module docstring)."""
    tf = ctx.params["trim_frac"]
    mask = jnp.ones((ctx.m,), bool) if cout.ideal else cout.mask
    if ctx.flat is not None:
        return flat_trimmed_mean(ctx.flat, mask, tf)
    return _tree_rowwise(ctx.stacked, lambda P: flat_trimmed_mean(P, mask, tf))


@register_aggregate("median", contract=StageContract(
    summary="coordinate-wise finite-guarded median; ignores "
            "Algorithm-2 weights",
    out="model"))
def aggregate_median(ctx: StageCtx, cout: CohortOut):
    """Coordinate-wise median of the cohort — the maximal trim, robust
    up to (but not at) 50% arbitrary values per coordinate."""
    mask = jnp.ones((ctx.m,), bool) if cout.ideal else cout.mask
    if ctx.flat is not None:
        return flat_median(ctx.flat, mask)
    return _tree_rowwise(ctx.stacked, lambda P: flat_median(P, mask))


# ---------------------------------------------------------------------------
# the quarantine commit
# ---------------------------------------------------------------------------

def _quarantine_select(ctx: StageCtx, bad, newcfg):
    """Suspect rows are warm-started from the reference model instead of
    adopting the committed configuration."""
    if ctx.flat is not None:
        return jnp.where(bad[:, None], ctx.ref_flat[None, :], newcfg)
    return tree_select(bad, broadcast_model(ctx.state.ref, ctx.m), newcfg)


@register_commit("quarantine", needs=("full-cohort",),
                 params={"quarantine_mult": 16.0}, validate=_validate_mult,
                 contract=StageContract(
                     summary="cohort adopts the aggregate except suspect "
                             "rows, which warm-start from the reference; "
                             "ledger identical to 'average'"))
def commit_quarantine(ctx: StageCtx, cout: CohortOut, mean, hot,
                      nhot) -> SyncOut:
    """``commit_average`` with a quarantine: suspect cohort rows
    (non-finite or distance outliers, ``_suspect_rows``) do NOT adopt
    the aggregate — they are warm-started from the reference model,
    which both resets adversarial rows every sync and gives a
    cold-restarted learner a live model to rejoin from. The scalar
    CommRecord and per-link counts are expression-identical to
    ``commit_average`` — a quarantined member still shipped its model
    up and got one pushed back down, it just received the reference —
    so honest-fleet comm counters stay bitwise vs the plain pipeline."""
    m = ctx.m
    if cout.ideal:
        bad = _suspect_rows(ctx, jnp.ones((m,), bool))
        newcfg = _quarantine_select(ctx, bad,
                                    _broadcast_commit(ctx, mean, m))
        rec = CommRecord(
            model_up=jnp.int32(m), model_down=jnp.int32(m),
            messages=jnp.int32(0), syncs=jnp.int32(1),
            full_syncs=jnp.int32(1))
        return SyncOut(newcfg, mean, carried_v(ctx, cout), cout.rng,
                       ctx.state.extra, rec, jnp.full((m,), 2, jnp.int32),
                       zeros_i32(m))
    mask = cout.mask
    bad = _suspect_rows(ctx, mask)
    nsync = jnp.sum(mask).astype(jnp.int32)
    newcfg = _quarantine_select(ctx, bad, _select_commit(ctx, mask, mean))
    new_ref = _ref_if_commit(ctx, nsync > 0, mean)
    rec = CommRecord(
        model_up=nsync, model_down=nsync, messages=jnp.int32(0),
        syncs=(nsync > 0).astype(jnp.int32),
        full_syncs=(nsync > 0).astype(jnp.int32))
    return SyncOut(newcfg, new_ref, carried_v(ctx, cout), cout.rng,
                   ctx.state.extra, rec, xfers_cohort(mask), zeros_i32(m))


# ---------------------------------------------------------------------------
# hardened(spec): the robust rewriter, mirroring asyncify
# ---------------------------------------------------------------------------

_ROBUST_TRIGGER = {
    "cadence": "robust_cadence",
    "divergence": "robust_divergence",
    "robust_cadence": "robust_cadence",        # idempotent
    "robust_divergence": "robust_divergence",
}

_ROBUST_AGGREGATE = {
    "mean": "trimmed_mean",
    "trimmed_mean": "trimmed_mean",
    "median": "median",
}

_ROBUST_COMMIT = {"average": "quarantine", "quarantine": "quarantine"}


def hardened(spec: ProtocolSpec, *, aggregate=None, trim_frac=None,
             quarantine_mult=None) -> ProtocolSpec:
    """Rewrite ``spec`` onto its Byzantine-robust counterpart: the
    trigger gains the health counters, ``mean`` becomes the robust
    ``aggregate`` (default ``trimmed_mean``), ``average`` becomes
    ``quarantine``. Parameters are preserved; ``trim_frac`` /
    ``quarantine_mult`` override the robust knobs. Raises for
    compositions with no robust counterpart (staleness/events triggers,
    mix/aircomp aggregates, balancing/subset/mix commits) — for a
    divergence-balanced protocol use the ``robust_dynamic`` preset,
    which trades the balancing augmentation for a full robust sync."""
    if spec.trigger not in _ROBUST_TRIGGER:
        raise ValueError(
            f"don't know the robust counterpart of trigger "
            f"{spec.trigger!r} (hardened rewrites: "
            f"{sorted(set(_ROBUST_TRIGGER))})")
    agg = aggregate if aggregate is not None else \
        _ROBUST_AGGREGATE.get(spec.aggregate)
    if agg not in ("trimmed_mean", "median"):
        raise ValueError(
            f"don't know the robust counterpart of aggregate "
            f"{spec.aggregate!r} (hardened rewrites "
            f"{sorted(_ROBUST_AGGREGATE)}; aggregate= accepts "
            f"'trimmed_mean' or 'median', got {aggregate!r})")
    if spec.commit not in _ROBUST_COMMIT:
        raise ValueError(
            f"don't know the robust counterpart of commit "
            f"{spec.commit!r} (hardened rewrites "
            f"{sorted(_ROBUST_COMMIT)}) — for the balancing pipeline "
            f"use the 'robust_dynamic' preset instead")
    params = dict(spec.params)
    if trim_frac is not None:
        params["trim_frac"] = trim_frac
    if quarantine_mult is not None:
        params["quarantine_mult"] = quarantine_mult
    return ProtocolSpec(
        name=f"robust_{spec.name or spec.trigger}",
        trigger=_ROBUST_TRIGGER[spec.trigger], cohort=spec.cohort,
        aggregate=agg, commit=_ROBUST_COMMIT[spec.commit], params=params)


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

ROBUST_PERIODIC = ProtocolSpec(
    name="robust_periodic", trigger="robust_cadence",
    cohort="all_reachable", aggregate="trimmed_mean", commit="quarantine")

ROBUST_DYNAMIC = ProtocolSpec(
    name="robust_dynamic", trigger="robust_divergence",
    cohort="all_reachable", aggregate="trimmed_mean", commit="quarantine")

register_protocol("robust_periodic", ROBUST_PERIODIC)
register_protocol("robust_dynamic", ROBUST_DYNAMIC)
