"""ProtocolSpec: a synchronization protocol as a declarative, serializable
composition of registered stages.

    spec = ProtocolSpec(trigger="divergence", cohort="balanced",
                        aggregate="mean", commit="balancing",
                        params={"b": 2, "delta": 0.5})

A spec names one stage per slot (``repro.core.sync.registry``), carries
the stages' static parameters, validates the composition at CONSTRUCTION
(unknown stages, incompatible combinations, bad parameter values — never
at trace time), and ``compile()``s into the staged round function the
scanned engine runs: ``(stacked, state, weights, active, adjacency) ->
StageResult``. Specs are frozen and hashable, so compilation is cached
and a spec can key a jit trace.

Serialization: ``to_dict``/``from_dict`` and ``to_json``/``from_json``
round-trip exactly, so checkpoints restore the precise protocol and
benchmarks can run arbitrary specs from a file
(``python -m benchmarks.run --protocol spec.json``).

``resolve_spec`` maps the legacy sugar onto this API: a ``ProtocolConfig``
resolves to its ``PROTOCOLS`` preset with the config's parameter fields
overlaid — the six built-in kinds are just presets (``kernel.py``), and
``register_protocol`` makes new compositions available to
``ProtocolConfig(kind=...)`` too.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Tuple

import jax

from repro.core import flatten, shard
from repro.core.sync import registry, stages
from repro.core.sync.registry import (
    CommRecord, StageCtx, StageResult, SyncOut, get_protocol,
)

# parameters every spec understands regardless of its stages. ``layout``
# picks the fleet arithmetic: "tree" (per-leaf pytree expressions,
# bitwise vs the goldens), "flat" (the one-(m, P)-matrix fleet plane,
# repro.core.flatten — params to float reassociation tolerance, and the
# same sync decisions hence bitwise comm counters, except in the
# measure-zero case where a distance lands within reassociation error
# of the Delta threshold and the differently-associated sums disagree
# about the comparison), or "sharded" (the same flat plane with its m
# axis split over a device mesh, repro.core.shard — identical arithmetic
# to "flat"; ``shard_devices`` caps how many visible devices the fleet
# mesh uses, 0 = all of them, and m % n_devices must be 0)
GLOBAL_PARAMS: Dict[str, Any] = {"weighted": False, "bytes_per_param": 4,
                                 "layout": "tree", "shard_devices": 0}

# the registered fleet layouts. A new backend joins by adding its name
# here and branching in the stages — the static contract checker
# (repro.analysis.contracts) then holds every registered preset to
# abstract tree-equivalence automatically.
LAYOUTS = ("tree", "flat", "sharded")

# layouts that run the dense (m, P) fleet-plane arithmetic. "sharded" is
# "flat" plus sharding constraints that are identity off-mesh, so both
# the compile below and the contract checker treat them as one family.
PLANE_LAYOUTS = ("flat", "sharded")

# the ProtocolConfig fields that overlay onto a preset's params (only the
# ones the preset's stages actually consume are applied)
_CONFIG_PARAM_FIELDS = ("b", "delta", "fedavg_c", "augmentation",
                        "weighted", "bytes_per_param", "layout",
                        "shard_devices")


def _canonical(v):
    """Numpy scalar -> plain Python number; everything else untouched."""
    import numbers
    if isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return float(v)
    return v


@dataclass(frozen=True)
class ProtocolSpec:
    """One protocol = four named stages + their static parameters.

    ``params`` accepts a dict at construction and is canonicalized to a
    sorted tuple of items, so specs are hashable and order-insensitive.
    ``name`` is cosmetic (presets carry their kind)."""
    trigger: str
    cohort: str = "all_reachable"
    aggregate: str = "mean"
    commit: str = "average"
    params: Any = ()
    name: str = ""

    def __post_init__(self):
        raw = self.params
        if isinstance(raw, dict):
            items = raw.items()
        else:
            items = (tuple(kv) for kv in raw)
        # canonicalize: numpy scalars become plain Python numbers so specs
        # built from np sweeps validate, hash and JSON-serialize the same
        # as hand-written ones; anything else non-scalar (jax arrays,
        # lists) would only explode later — at the compile cache or in
        # to_json — so reject it here, at construction
        items = tuple(sorted((k, _canonical(v)) for k, v in items))
        for k, v in items:
            if not isinstance(v, (bool, int, float, str, type(None))):
                raise ValueError(
                    f"spec param {k!r} must be a plain Python scalar "
                    f"(bool/int/float/str), got {type(v).__name__}: {v!r}")
        object.__setattr__(self, "params", items)
        self._validate()

    # ---- stage access ------------------------------------------------
    def stage_records(self):
        return (registry.get_trigger(self.trigger),
                registry.get_cohort(self.cohort),
                registry.get_aggregate(self.aggregate),
                registry.get_commit(self.commit))

    @property
    def known_params(self) -> Dict[str, Any]:
        """name -> default for every parameter this spec's stages (plus
        the globals) consume."""
        merged = dict(GLOBAL_PARAMS)
        for rec in self.stage_records():
            merged.update(rec.params)
        return merged

    def resolved_params(self) -> Dict[str, Any]:
        p = self.known_params
        p.update(dict(self.params))
        return p

    def param(self, name: str):
        return self.resolved_params()[name]

    def with_params(self, **overrides) -> "ProtocolSpec":
        merged = dict(self.params)
        merged.update(overrides)
        return dataclasses.replace(self, params=merged)

    # ---- capabilities ------------------------------------------------
    @property
    def uses_overlay(self) -> bool:
        """Needs the (m, m) peer adjacency (the engine supplies the
        implied star on an ideal network)."""
        return registry.get_cohort(self.cohort).uses_overlay

    @property
    def uses_coordinator(self) -> bool:
        """Traffic is a star to a hub — the shape hierarchies require."""
        return registry.get_cohort(self.cohort).uses_coordinator

    @property
    def extra_state(self) -> Tuple[str, ...]:
        """Names of the extra carried-state arrays this spec's trigger
        threads through ``SyncState.extra``."""
        trig = registry.get_trigger(self.trigger)
        return tuple(sorted(trig.init_extra(self.resolved_params(), 1)))

    @property
    def bytes_per_param(self) -> int:
        return self.param("bytes_per_param")

    def init_extra(self, m: int) -> Dict[str, Any]:
        """Initial extra carried state for an m-learner fleet."""
        trig = registry.get_trigger(self.trigger)
        return trig.init_extra(self.resolved_params(), m)

    # ---- construction-time validation --------------------------------
    def _validate(self) -> None:
        trig, coh, agg, com = self.stage_records()   # KeyError on unknowns
        label = self.name or (
            f"{self.trigger}/{self.cohort}/{self.aggregate}/{self.commit}")
        if (coh.needs_condition or com.needs_condition) and not \
                trig.conditional:
            needer = coh.name if coh.needs_condition else com.name
            raise ValueError(
                f"spec {label!r}: stage {needer!r} needs a conditional "
                f"trigger (one that marks hot learners, e.g. divergence "
                f"or staleness), but trigger {trig.name!r} is "
                f"unconditional")
        for rec, slot in ((agg, "aggregate"), (com, "commit")):
            missing = rec.needs - coh.provides
            if missing:
                raise ValueError(
                    f"spec {label!r}: {slot} stage {rec.name!r} needs "
                    f"{sorted(missing)} which cohort {coh.name!r} does "
                    f"not provide (provides: {sorted(coh.provides)})")
        known = self.known_params
        unknown = [k for k, _ in self.params if k not in known]
        if unknown:
            raise ValueError(
                f"spec {label!r}: params {unknown} are not consumed by "
                f"any of its stages (known: {sorted(known)})")
        resolved = self.resolved_params()
        if not (isinstance(resolved["bytes_per_param"], int)
                and resolved["bytes_per_param"] >= 1):
            raise ValueError(
                f"bytes_per_param must be an int >= 1, got "
                f"{resolved['bytes_per_param']!r}")
        if resolved["layout"] not in LAYOUTS:
            raise ValueError(
                f"layout must be one of {LAYOUTS}, got "
                f"{resolved['layout']!r}")
        if not (isinstance(resolved["shard_devices"], int)
                and not isinstance(resolved["shard_devices"], bool)
                and resolved["shard_devices"] >= 0):
            raise ValueError(
                f"shard_devices must be an int >= 0 (0 = all visible "
                f"devices), got {resolved['shard_devices']!r}")
        for rec in (trig, coh, agg, com):
            if rec.validate is not None:
                rec.validate(resolved)

    # ---- serialization -----------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "trigger": self.trigger,
            "cohort": self.cohort,
            "aggregate": self.aggregate,
            "commit": self.commit,
            "params": dict(self.params),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ProtocolSpec":
        allowed = {"name", "trigger", "cohort", "aggregate", "commit",
                   "params"}
        unknown = set(d) - allowed
        if unknown:
            raise ValueError(
                f"unknown ProtocolSpec keys {sorted(unknown)}; "
                f"schema: {sorted(allowed)}")
        if "trigger" not in d:
            raise ValueError("a ProtocolSpec dict needs at least 'trigger'")
        kw = dict(d)
        # JSON has no tuples; params may round-trip as a dict (canonical)
        kw["params"] = dict(kw.get("params", {}))
        return cls(**kw)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ProtocolSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_file(cls, path: str) -> "ProtocolSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # ---- compilation -------------------------------------------------
    def compile(self):
        """The staged round function:
        ``(stacked, state, weights=None, active=None, adjacency=None) ->
        StageResult``. Cached per spec (specs are frozen + hashable)."""
        return _compiled_round(self)


@functools.lru_cache(maxsize=None)
def _compiled_round(spec: ProtocolSpec):
    """Wire the spec's four stages into one scanned round.

    The skeleton mirrors the shape the monolithic operators shared, so
    preset specs reproduce the PR-2 engine bitwise (pinned by
    ``tests/golden_pr2_engine.json``):

        gate = trigger.gate(ctx)                  # every round
        lax.cond(gate):
          true:  [hot, nhot = trigger.condition(ctx)   # conditional
                  lax.cond(nhot > 0):]                 # triggers only
                    cohort -> aggregate -> commit
          false: identity + zero accounting (extra state still ages)

    Under the plane layouts ("flat"/"sharded") the gated branch
    additionally ravels the configuration onto the flat fleet-plane
    (``repro.core.flatten``) — the stages then run their dense (m, P)
    forms and the committed plane is unraveled back to the pytree before
    the branches join, so the scan carry (and everything outside the
    sync machinery) keeps the pytree layout either way. A round whose
    gate does not fire never pays for the ravel. ``layout="sharded"``
    runs the identical plane arithmetic and only adds
    ``shard.constrain_rows`` pins on the raveled and committed planes:
    at trace time they read the fleet mesh the ENGINE activated
    (``shard.use_fleet``) and split the m axis over its devices; with no
    active fleet (eval_shape in the contract gate, the jaxpr audit) they
    are the identity, so "sharded" stays abstractly equal to "flat".
    """
    trig, coh, agg, com = spec.stage_records()
    p = spec.resolved_params()
    flat_layout = p["layout"] in PLANE_LAYOUTS

    def round_fn(stacked, state, weights=None, active=None, adjacency=None):
        m = stages.num_learners(stacked)
        t = state.step + 1
        reach = stages.cohort_all(m, active)
        adapter = flatten.fleet_adapter(stacked) if flat_layout else None
        ctx = StageCtx(params=p, stacked=stacked, state=state,
                       weights=weights, active=active, adjacency=adjacency,
                       m=m, t=t, reach=reach, adapter=adapter)

        def skip_out(rng):
            return SyncOut(stacked, state.ref, state.v, rng,
                           trig.skip_extra(ctx), CommRecord.zero(),
                           stages.zeros_i32(m), stages.zeros_i32(m))

        def pipeline(sctx, hot, nhot, rng):
            cout = coh.fn(sctx, hot, nhot, rng)
            out = com.fn(sctx, cout, agg.fn(sctx, cout), hot, nhot)
            out = out._replace(extra=trig.commit_extra(sctx, cout.mask))
            if adapter is not None:
                out = out._replace(
                    params=adapter.unravel(shard.constrain_rows(out.params)),
                    ref=adapter.unravel_model(out.ref))
            return out

        def sync(rng):
            sctx = ctx
            if adapter is not None:
                sctx = ctx._replace(
                    flat=shard.constrain_rows(adapter.ravel(stacked)),
                    ref_flat=adapter.ravel_model(state.ref))
            if trig.condition is None:
                return pipeline(sctx, reach, None, rng)
            cond = trig.condition(sctx)
            hot, nhot = cond[0], cond[1]
            if len(cond) > 2:     # condition extras -> downstream stages
                sctx = sctx._replace(cond_aux=cond[2])
            return jax.lax.cond(
                nhot > 0, lambda r: pipeline(sctx, hot, nhot, r),
                skip_out, rng)

        gate = trig.gate(ctx)
        if gate is False:      # statically-never trigger (nosync): no cond
            out = skip_out(state.rng)
        else:
            out = jax.lax.cond(gate, sync, skip_out, state.rng)
        new_state = state._replace(ref=out.ref, v=out.v, rng=out.rng,
                                   step=t, extra=out.extra)
        return StageResult(out.params, new_state, out.rec, out.xfers,
                           out.link_msgs)

    return round_fn


# ---------------------------------------------------------------------------
# ProtocolConfig sugar -> spec resolution
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _resolve_config(proto) -> ProtocolSpec:
    preset = get_protocol(proto.kind)
    known = preset.known_params
    # params a preset PINS explicitly are part of its identity and win
    # over the config overlay — a ProtocolConfig cannot distinguish its
    # dataclass defaults from user-set fields, so letting the overlay
    # through would silently clobber e.g. a registered preset's tuned b
    # with the config default. Pinned knobs are tuned via the spec API
    # (preset.with_params(...)), not the kind sugar.
    pinned = dict(preset.params)
    overrides = {f: getattr(proto, f) for f in _CONFIG_PARAM_FIELDS
                 if f in known and f not in pinned}
    return preset.with_params(**overrides)


def resolve_spec(proto) -> ProtocolSpec:
    """A ``ProtocolSpec`` passes through; a ``ProtocolConfig`` (anything
    with a ``.kind``) resolves to its preset with the config's parameter
    fields overlaid — only the fields the preset's stages consume apply,
    so e.g. ``delta`` never leaks into ``periodic``."""
    if isinstance(proto, ProtocolSpec):
        return proto
    if hasattr(proto, "kind"):
        return _resolve_config(proto)
    raise TypeError(
        f"expected a ProtocolSpec or a ProtocolConfig, got {proto!r}")
