"""Bounded-staleness synchronization — the spec API's worked example.

The first ROADMAP async open item, landed WITHOUT touching ``kernel.py``
or the engine: one registered trigger plus a spec, composed with the
existing cohort/aggregate/commit stages.

Each learner carries a staleness counter s_i — completed rounds since it
last participated in a sync — threaded through ``SyncState.extra`` inside
the scanned round and accumulated against the availability mask: every
round ages every learner by one, a sync commit resets exactly the cohort
members (the committed mask), and only REACHABLE learners can raise the
alarm. The trigger's condition marks ``hot = reach & (s + 1 >= tau)``:
the sync machinery runs the moment any reachable learner has gone ``tau``
rounds unsynchronized — learners that were dark past their deadline
trigger it the round they reappear. Between alarms the fleet is silent,
so communication adapts to availability instead of a lockstep cadence.

``BOUNDED_STALENESS`` composes the trigger with the all-reachable cohort
(everyone reachable averages when anyone is too stale); it is registered
as preset ``"stale"``, so ``ProtocolConfig(kind="stale")`` works like any
built-in kind — hierarchies included. The trigger composes with the other
cohort families too: ``cohort="fraction", commit="subset"`` is
staleness-triggered FedAvg, ``cohort="balanced", commit="balancing"``
runs the coordinator's balancing augmentation off staleness instead of
divergence.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sync.registry import StageContract, StageCtx, register_trigger
from repro.core.sync.spec import ProtocolSpec
from repro.core.sync.kernel import register_protocol
from repro.core.sync.stages import _validate_b, cadence_fire


def _counters(ctx: StageCtx) -> jnp.ndarray:
    if "staleness" not in ctx.state.extra:
        raise ValueError(
            "the staleness trigger carries per-learner counters in "
            "SyncState.extra['staleness'] — build the state with "
            "init_state(ref, seed, spec=spec, m=m) (the engine does this "
            "automatically)")
    return ctx.state.extra["staleness"]


def _staleness_condition(ctx: StageCtx):
    age = _counters(ctx) + 1                      # age after this round
    hot = ctx.reach & (age >= ctx.params["tau"])
    return hot, jnp.sum(hot).astype(jnp.int32)


def _staleness_init(params, m: int):
    return {"staleness": jnp.zeros((m,), jnp.int32)}


def _staleness_commit(ctx: StageCtx, mask):
    # cohort members synced this round: their counters reset; everyone
    # else (including dark learners) keeps aging
    age = _counters(ctx) + 1
    return {"staleness": jnp.where(mask, jnp.int32(0), age)}


def _staleness_skip(ctx: StageCtx):
    return {"staleness": _counters(ctx) + 1}


def _validate(params):
    _validate_b(params)
    tau = params["tau"]
    if not (isinstance(tau, int) and tau >= 1):
        raise ValueError(f"staleness bound tau must be an int >= 1, "
                         f"got {tau!r}")


@register_trigger("staleness", condition=_staleness_condition,
                  init_extra=_staleness_init,
                  commit_extra=_staleness_commit,
                  skip_extra=_staleness_skip,
                  params={"b": 1, "tau": 5}, validate=_validate,
                  contract=StageContract(
                      summary="conditional gate on the per-learner "
                              "rounds-since-sync counters",
                      extra_state=(("staleness", "int32"),)))
def trigger_staleness(ctx: StageCtx):
    """Gate: check every ``b`` rounds (b=1: every round); the condition
    fires when any reachable learner's rounds-since-last-sync reach
    ``tau``."""
    return cadence_fire(ctx.params["b"], ctx.t)


# b=1 is PINNED: the staleness condition must be checked every round or
# alarms land late. Pinned preset params win over the ProtocolConfig
# sugar's field overlay (whose b default is 10), so kind="stale" behaves
# identically to running this spec directly; tau (and b) are tuned via
# BOUNDED_STALENESS.with_params(...).
BOUNDED_STALENESS = ProtocolSpec(
    name="stale", trigger="staleness", cohort="all_reachable",
    aggregate="mean", commit="average", params={"b": 1})

register_protocol("stale", BOUNDED_STALENESS)
