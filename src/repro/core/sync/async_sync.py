"""Event-driven asynchronous synchronization + over-the-air aggregation.

The ROADMAP's async open item, landed the PR-4 way: registered stages
plus specs, zero kernel/engine edits. Three pieces:

* **Event-driven triggers** ``"events"`` / ``"events_divergence"`` — the
  cadence/staleness/divergence conditions re-based on a per-learner
  LOCAL clock with messages in flight. Each learner carries, in
  ``SyncState.extra``:

  - ``lclock`` (m,) int32 — the local cadence phase: how many idle
    rounds into its current period the learner is. It only advances
    while the learner is idle, so a learner's cadence period is ``b``
    local rounds plus however long its last exchange flew.
  - ``inflight`` (m,) int32 — rounds until its launched exchange lands.
  - ``ring`` (m, max_delay) int32 — the bounded-delay arrival buffer
    (``repro.network.events``): slot ``t % max_delay`` marks whose
    exchange lands at round ``t``.
  - ``age`` (m,) int32 — rounds since the learner last synced (the
    PR-4 staleness counter, carried by every async trigger so the
    telemetry chunk snapshots always expose staleness ages).

  When a learner's alarm condition holds (local cadence tick, staleness
  deadline, or divergence violation) it LAUNCHES an exchange: the
  message flies for ``k = ceil(round_trip / budget) - 1`` whole rounds
  (``events.flight_rounds``, from the ``repro.network.cost`` link
  classes), and the learner participates in a sync only when the
  arrival round is reached. ``k = 0`` — an ideal network, or a round
  budget that covers the slowest link's round trip — reduces every
  composition EXACTLY to its synchronous original: same gate values,
  same hot sets, same rng stream, bitwise-equal counters, ledger and
  parameters (pinned by ``tests/test_async.py``).

  Arrivals landing while their learner is unreachable are dropped (the
  fleet's availability mask wins); the learner goes idle again and
  re-launches at its next alarm.

* **``"aircomp"`` aggregate** — the cohort mean computed over an analog
  multiple-access channel: every member transmits simultaneously and
  the channel itself sums the waveforms (the ``air_comp`` hook in the
  Federated-Edge-AI-For-6G exemplar, SNIPPETS.md). The receiver sees
  the mean plus Gaussian noise at ``snr_db`` relative to the
  aggregate's RMS, attenuated by the cohort size (n aligned
  transmissions add amplitudes, the receiver noise does not). The draw
  is pure in ``(air_seed, t)``. Noise is drawn per leaf on the tree
  layout and once over the plane row on flat/sharded layouts, so
  parameters are layout-consistent only per layout family; counters
  and ledger are layout-invariant as always.

* **``"aircomp"`` commit** — the pricing dual: one shared-medium
  transmission in the paper's c(f) (``model_up = model_down = 1`` per
  sync, however large the cohort), while the per-link ledger bills each
  member's analog frame occupancy (1 transfer per member link). Like
  gossip's 2x occupancy note in ``per_link_bytes``, the ledger's sum is
  deliberately NOT c(f) here — it is ``nsync * model_bytes`` of radio
  airtime against ``2 * model_bytes`` of effective fleet throughput.

``asyncify`` rewrites any synchronous spec into its event-driven
counterpart (the ``AsyncConfig`` engine hook); ``"aircomp"``,
``"async_periodic"`` and ``"async_dynamic"`` are registered presets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.divergence import (
    per_learner_sq_distance, per_learner_sq_distance_flat,
)
from repro.core.sync.kernel import register_protocol
from repro.core.sync.registry import (
    CohortOut, CommRecord, StageContract, StageCtx, SyncOut, carried_v,
    register_aggregate, register_commit, register_trigger,
)
from repro.core.sync.spec import ProtocolSpec
from repro.core.sync.stages import (
    _broadcast_commit, _ref_if_commit, _select_commit, _validate_b,
    aggregate_mean_stage, zeros_i32,
)
from repro.network import events


# ---------------------------------------------------------------------------
# the shared timeline: extra-state keys + per-round transition
# ---------------------------------------------------------------------------

_EXTRA_KEYS = ("age", "inflight", "lclock", "ring")
_EXTRA_CONTRACT = (("age", "int32"), ("inflight", "int32"),
                   ("lclock", "int32"), ("ring", "int32"))


def _timeline(ctx: StageCtx) -> dict:
    """The decoded timeline state this round: who is due (their exchange
    lands at round t), who is idle (free to launch), and whose local
    cadence phase ticks."""
    extra = ctx.state.extra
    missing = [k for k in _EXTRA_KEYS if k not in extra]
    if missing:
        raise ValueError(
            f"the event-driven triggers carry {list(_EXTRA_KEYS)} in "
            f"SyncState.extra (missing: {missing}) — build the state with "
            f"init_state(ref, seed, spec=spec, m=m) (the engine does this "
            f"automatically)")
    p = ctx.params
    k = events.flight_rounds(p["link_classes"], ctx.m, p["payload_bytes"],
                             p["budget"])
    due = events.due_mask(extra["ring"], ctx.t)
    idle = extra["inflight"] == 0
    # the LOCAL cadence: lclock is the learner's idle-round phase within
    # its period, so the tick fires b idle rounds after its last one —
    # flight rounds (and the arrival round itself) do not advance it
    tick = ((extra["lclock"] + 1) % p["b"]) == 0
    return {"ring": extra["ring"], "inflight": extra["inflight"],
            "lclock": extra["lclock"], "age": extra["age"],
            "k": k, "due": due, "idle": idle, "tick": tick}


def _advance(ctx: StageCtx, tl: dict, launch, mask) -> dict:
    """One timeline transition: consume arrivals, schedule launches,
    advance idle local clocks, age everyone (``mask`` — the committed
    cohort — resets its ages; None on skip rounds)."""
    ring = events.ring_step(tl["ring"], ctx.t, launch, tl["k"])
    inflight = jnp.where(launch, tl["k"],
                         jnp.maximum(tl["inflight"] - 1, 0))
    advance = tl["idle"] & ~tl["due"]
    lclock = jnp.where(advance, (tl["lclock"] + 1) % ctx.params["b"],
                       tl["lclock"])
    age = tl["age"] + 1
    if mask is not None:
        age = jnp.where(mask, jnp.int32(0), age)
    return {"age": age, "inflight": inflight, "lclock": lclock,
            "ring": ring}


def _events_init(params, m: int) -> dict:
    return {"age": jnp.zeros((m,), jnp.int32),
            "inflight": jnp.zeros((m,), jnp.int32),
            "lclock": jnp.zeros((m,), jnp.int32),
            "ring": events.empty_ring(m, params["max_delay"])}


def _validate_delay(params) -> None:
    budget = params["budget"]
    if not (isinstance(budget, (int, float)) and budget > 0):
        raise ValueError(f"round budget must be > 0 seconds, got {budget!r}")
    depth = params["max_delay"]
    if not (isinstance(depth, int) and depth >= 1):
        raise ValueError(f"max_delay must be an int >= 1, got {depth!r}")
    payload = params["payload_bytes"]
    if not (isinstance(payload, int) and payload >= 0):
        raise ValueError(
            f"payload_bytes must be an int >= 0, got {payload!r}")
    kmax = events.max_flight_rounds(params["link_classes"], payload,
                                    float(budget))
    if kmax >= depth:
        raise ValueError(
            f"slowest link class flies {kmax} rounds but the arrival ring "
            f"only holds max_delay={depth} — raise max_delay above {kmax}, "
            f"raise the round budget, or shrink the payload")


# ---------------------------------------------------------------------------
# trigger "events": cadence / staleness alarms on the local clock
# ---------------------------------------------------------------------------

def _events_alarm(ctx: StageCtx, tl: dict):
    """Who wants to launch this round. The cadence base is UNMASKED like
    ``trigger_cadence`` (the schedule does not depend on reachability);
    the staleness base mirrors ``trigger_staleness``'s reach-masked
    deadline on the carried ages."""
    alarm = tl["tick"] & tl["idle"] & ~tl["due"]
    if ctx.params["base"] == "staleness":
        alarm &= ctx.reach & (tl["age"] + 1 >= ctx.params["tau"])
    return alarm


def _events_condition(ctx: StageCtx):
    tl = _timeline(ctx)
    alarm = _events_alarm(ctx, tl)
    # alarms on a zero-flight link fire immediately (the synchronous
    # limit); the rest launch, and participate at their arrival round.
    # nhot counts UNMASKED fires so the pipeline always runs when the
    # synchronous original would have (the fedavg rng stream depends on
    # pipeline entries, not on who was reachable).
    fire = (tl["due"] & ctx.reach) | (alarm & (tl["k"] == 0))
    hot = fire & ctx.reach
    return hot, jnp.sum(fire).astype(jnp.int32)


def _events_commit(ctx: StageCtx, mask) -> dict:
    tl = _timeline(ctx)
    launch = _events_alarm(ctx, tl) & (tl["k"] > 0)
    return _advance(ctx, tl, launch, mask)


def _events_skip(ctx: StageCtx) -> dict:
    # launch-only rounds land here (nothing due, nothing immediate, so
    # the pipeline is skipped) — the ring still has to record them
    tl = _timeline(ctx)
    launch = _events_alarm(ctx, tl) & (tl["k"] > 0)
    return _advance(ctx, tl, launch, None)


def _validate_events(params) -> None:
    _validate_b(params)
    _validate_delay(params)
    if params["base"] not in ("cadence", "staleness"):
        raise ValueError(
            f"events base must be cadence|staleness, got {params['base']!r}")
    tau = params["tau"]
    if not (isinstance(tau, int) and tau >= 1):
        raise ValueError(f"staleness bound tau must be an int >= 1, "
                         f"got {tau!r}")


@register_trigger(
    "events", condition=_events_condition, init_extra=_events_init,
    commit_extra=_events_commit, skip_extra=_events_skip,
    params={"base": "cadence", "b": 1, "tau": 5, "budget": 1.0,
            "max_delay": 8, "link_classes": "", "payload_bytes": 0},
    validate=_validate_events,
    contract=StageContract(
        summary="event-driven cadence/staleness alarm on the per-learner "
                "local clock; launches fly k rounds through the bounded-"
                "delay arrival ring",
        extra_state=_EXTRA_CONTRACT))
def trigger_events(ctx: StageCtx):
    """Gate: any local tick on an idle learner, or any arrival landing
    this round — between those events the round skips the sync machinery
    entirely."""
    tl = _timeline(ctx)
    return jnp.any(tl["tick"] & tl["idle"]) | jnp.any(tl["due"])


# ---------------------------------------------------------------------------
# trigger "events_divergence": sigma_Delta's condition on the local clock
# ---------------------------------------------------------------------------

def _div_dists(ctx: StageCtx):
    if ctx.flat is not None:
        return per_learner_sq_distance_flat(ctx.flat, ctx.ref_flat)
    return per_learner_sq_distance(ctx.stacked, ctx.state.ref)


def _events_div_alarm(ctx: StageCtx, tl: dict, dists):
    violated = (dists > ctx.params["delta"]) & ctx.reach
    return violated & tl["tick"] & tl["idle"] & ~tl["due"]


def _events_div_condition(ctx: StageCtx):
    tl = _timeline(ctx)
    dists = _div_dists(ctx)
    alarm = _events_div_alarm(ctx, tl, dists)
    launch = alarm & (tl["k"] > 0)
    fire = (tl["due"] & ctx.reach) | (alarm & (tl["k"] == 0))
    # fire is already reach-masked (violations and arrivals both are);
    # its count feeds the balanced cohort's violation counter exactly
    # like the synchronous nviol — a learner is counted once, the round
    # its violation PARTICIPATES, never at launch
    return fire, jnp.sum(fire).astype(jnp.int32), \
        {"dists": dists, "launch": launch}


def _events_div_launch(ctx: StageCtx, tl: dict):
    """The launch set. On commit rounds the condition already computed it
    (threaded via ``cond_aux``); on skip rounds — the engine's skip path
    sees the pre-condition ctx — the monitoring pass reruns, which is the
    documented extra cost of divergence monitoring on non-sync rounds."""
    if isinstance(ctx.cond_aux, dict) and "launch" in ctx.cond_aux:
        return ctx.cond_aux["launch"]
    return _events_div_alarm(ctx, tl, _div_dists(ctx)) & (tl["k"] > 0)


def _events_div_commit(ctx: StageCtx, mask) -> dict:
    tl = _timeline(ctx)
    return _advance(ctx, tl, _events_div_launch(ctx, tl), mask)


def _events_div_skip(ctx: StageCtx) -> dict:
    tl = _timeline(ctx)
    return _advance(ctx, tl, _events_div_launch(ctx, tl), None)


def _validate_events_div(params) -> None:
    _validate_b(params)
    _validate_delay(params)
    if not params["delta"] > 0:
        raise ValueError(
            f"divergence threshold delta must be > 0, got {params['delta']!r}")


@register_trigger(
    "events_divergence", condition=_events_div_condition,
    init_extra=_events_init, commit_extra=_events_div_commit,
    skip_extra=_events_div_skip,
    params={"b": 1, "delta": 0.5, "budget": 1.0, "max_delay": 8,
            "link_classes": "", "payload_bytes": 0},
    validate=_validate_events_div,
    contract=StageContract(
        summary="sigma_Delta's divergence condition checked on idle "
                "learners' local ticks; violations on slow links fly "
                "before participating",
        extra_state=_EXTRA_CONTRACT,
        cond_aux=("dists", "launch")))
def trigger_events_divergence(ctx: StageCtx):
    """Gate: any idle learner's local tick (a divergence check might
    fire) or any arrival landing this round."""
    tl = _timeline(ctx)
    return jnp.any(tl["tick"] & tl["idle"]) | jnp.any(tl["due"])


# ---------------------------------------------------------------------------
# aggregate + commit "aircomp": over-the-air analog superposition
# ---------------------------------------------------------------------------

def _validate_air(params) -> None:
    snr = params["snr_db"]
    if not isinstance(snr, (int, float)):
        raise ValueError(f"snr_db must be a number, got {snr!r}")
    if not isinstance(params["air_seed"], int):
        raise ValueError(f"air_seed must be an int, "
                         f"got {params['air_seed']!r}")


@register_aggregate(
    "aircomp", params={"snr_db": 20.0, "air_seed": 0},
    validate=_validate_air,
    contract=StageContract(
        summary="cohort mean over the analog MAC: Gaussian receiver "
                "noise at snr_db, attenuated by the cohort size; draw "
                "pure in (air_seed, t)",
        out="model"))
def aggregate_aircomp(ctx: StageCtx, cout: CohortOut):
    """The cohort mean as the analog channel computes it: every member
    transmits simultaneously, the superposed waveform IS the sum, and
    the receiver adds Gaussian noise at ``snr_db`` below the aggregate's
    RMS. n aligned transmissions add amplitudes while the receiver noise
    stays fixed, so the post-averaging noise std shrinks as 1/n."""
    mean = aggregate_mean_stage(ctx, cout)
    n = (jnp.float32(ctx.m) if cout.ideal
         else jnp.maximum(jnp.sum(cout.mask), 1).astype(jnp.float32))
    scale = jnp.float32(10.0 ** (-float(ctx.params["snr_db"]) / 20.0))
    key = jax.random.fold_in(
        jax.random.PRNGKey(ctx.params["air_seed"] ^ 0xA17C0), ctx.t)

    def noisy(i, x):
        xf = x.astype(jnp.float32)
        rms = jnp.sqrt(jnp.mean(xf * xf) + jnp.float32(1e-12))
        eps = jax.random.normal(jax.random.fold_in(key, i), x.shape,
                                jnp.float32)
        return (xf + (rms * scale / n) * eps).astype(x.dtype)

    if ctx.flat is not None:
        return noisy(0, mean)
    leaves, treedef = jax.tree.flatten(mean)
    return jax.tree.unflatten(
        treedef, [noisy(i, x) for i, x in enumerate(leaves)])


@register_commit(
    "aircomp", needs=("full-cohort",),
    contract=StageContract(
        summary="cohort adopts the noisy analog aggregate; c(f) counts "
                "ONE shared-medium exchange; the ledger bills each "
                "member's analog frame airtime"))
def commit_aircomp(ctx: StageCtx, cout: CohortOut, mean, hot,
                   nhot) -> SyncOut:
    """The analog channel's pricing: the simultaneous uplink plus the
    broadcast downlink are ONE exchange in the paper's c(f)
    (``model_up = model_down = 1`` regardless of cohort size — the
    physics that makes aircomp fundamentally cheaper), while the
    per-link ledger bills every member's radio one analog frame of
    airtime. Like gossip's both-endpoints occupancy, the ledger's sum is
    intentionally not c(f): nsync frames of airtime vs 2 payloads of
    fleet throughput."""
    m = ctx.m
    if cout.ideal:
        newcfg = _broadcast_commit(ctx, mean, m)
        rec = CommRecord(
            model_up=jnp.int32(1), model_down=jnp.int32(1),
            messages=jnp.int32(0), syncs=jnp.int32(1),
            full_syncs=jnp.int32(1))
        return SyncOut(newcfg, mean, carried_v(ctx, cout), cout.rng,
                       ctx.state.extra, rec, jnp.ones((m,), jnp.int32),
                       zeros_i32(m))
    mask = cout.mask
    nsync = jnp.sum(mask).astype(jnp.int32)
    newcfg = _select_commit(ctx, mask, mean)
    new_ref = _ref_if_commit(ctx, nsync > 0, mean)
    moved = (nsync > 0).astype(jnp.int32)
    rec = CommRecord(model_up=moved, model_down=moved,
                     messages=jnp.int32(0), syncs=moved, full_syncs=moved)
    return SyncOut(newcfg, new_ref, carried_v(ctx, cout), cout.rng,
                   ctx.state.extra, rec, mask.astype(jnp.int32),
                   zeros_i32(m))


# ---------------------------------------------------------------------------
# asyncify: any synchronous spec -> its event-driven counterpart
# ---------------------------------------------------------------------------

_ASYNC_TRIGGER = {
    "cadence": "events",
    "staleness": "events",
    "divergence": "events_divergence",
    "events": "events",
    "events_divergence": "events_divergence",
}


def asyncify(spec: ProtocolSpec, async_net, network=None,
             model_bytes=None) -> ProtocolSpec:
    """Rewrite ``spec`` to run on the event-driven timeline: the trigger
    is re-based on the local clock with the ``AsyncConfig``'s delay
    regime (flight times from the ``network``'s link classes and
    ``model_bytes`` payload), and — with ``async_net.aircomp`` — the
    mean/average pair is swapped for the over-the-air stages. The engine
    calls this when an ``AsyncConfig`` is attached; ``"never"`` passes
    through untouched (there is no timeline to rewrite)."""
    params = dict(spec.params)
    new_trigger = spec.trigger
    if spec.trigger != "never":
        if spec.trigger not in _ASYNC_TRIGGER:
            raise ValueError(
                f"don't know the event-driven counterpart of trigger "
                f"{spec.trigger!r} — register it (or extend "
                f"async_sync._ASYNC_TRIGGER)")
        new_trigger = _ASYNC_TRIGGER[spec.trigger]
        if spec.trigger in ("cadence", "staleness"):
            params["base"] = spec.trigger
        payload = async_net.payload_bytes
        if payload is None:
            payload = int(model_bytes) if model_bytes else 0
        params.update(
            budget=float(async_net.round_budget),
            max_delay=int(async_net.max_delay),
            link_classes=(",".join(network.link_classes)
                          if network is not None else ""),
            payload_bytes=int(payload))
    aggregate, commit = spec.aggregate, spec.commit
    if async_net.aircomp:
        if not (spec.aggregate == "mean" and spec.commit == "average"):
            raise ValueError(
                f"aircomp models the coordinator mean/average exchange "
                f"over the analog channel — aggregate={spec.aggregate!r}, "
                f"commit={spec.commit!r} has no over-the-air counterpart")
        aggregate, commit = "aircomp", "aircomp"
        params.update(snr_db=float(async_net.snr_db),
                      air_seed=int(async_net.air_seed))
    return ProtocolSpec(
        name=f"async_{spec.name or spec.trigger}", trigger=new_trigger,
        cohort=spec.cohort, aggregate=aggregate, commit=commit,
        params=params)


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

# cadence-synced fleet over the analog channel; b stays overridable via
# the ProtocolConfig sugar like "periodic"
AIRCOMP = ProtocolSpec(
    name="aircomp", trigger="cadence", cohort="all_reachable",
    aggregate="aircomp", commit="aircomp")
register_protocol("aircomp", AIRCOMP)

# sigma_b on the event timeline over a heterogeneous lte/edge fleet:
# edge learners' exchanges fly 1 round at the default 1 s budget, lte
# learners land synchronously — the smallest preset that exercises
# launches, flights and arrival waves (and the jaxpr audit over them)
ASYNC_PERIODIC = ProtocolSpec(
    name="async_periodic", trigger="events", cohort="all_reachable",
    aggregate="mean", commit="average",
    params={"link_classes": "lte,edge", "payload_bytes": 100_000})
register_protocol("async_periodic", ASYNC_PERIODIC)

# sigma_Delta on the event timeline: violations on slow links fly before
# they participate in the balancing augmentation
ASYNC_DYNAMIC = ProtocolSpec(
    name="async_dynamic", trigger="events_divergence", cohort="balanced",
    aggregate="mean", commit="balancing",
    params={"link_classes": "lte,edge", "payload_bytes": 100_000})
register_protocol("async_dynamic", ASYNC_DYNAMIC)
