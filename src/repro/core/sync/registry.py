"""Named stage registries: the extension points of the protocol-spec API.

A synchronization protocol Π = (φ, σ) is declared as a ``ProtocolSpec``
(``repro.core.sync.spec``) naming one stage per slot:

    trigger  -> cohort  -> aggregate -> commit
    (fire?)     (who)      (what)       (apply + account)

Each slot has a registry (``TRIGGERS`` / ``COHORTS`` / ``AGGREGATES`` /
``COMMITS``) populated through the ``@register_*`` decorators; the built-in
stages live in ``repro.core.sync.stages``, and new protocols add stages
here WITHOUT touching the kernel or the engine (see
``repro.core.sync.staleness`` for the worked example). Name collisions
raise at import time — two stages may not share a slot name.

``PROTOCOLS`` is the preset registry: complete specs under a protocol
name. The six built-in kinds (nosync/periodic/continuous/fedavg/dynamic/
gossip) are registered by ``kernel.py``; ``register_protocol`` makes a new
composition available to ``ProtocolConfig(kind=...)`` as well.

Stage contracts (all pure, jit/vmap/scan-compatible; ``StageCtx`` carries
the round's inputs):

* **trigger** — the decorated function is the *gate*: ``gate(ctx) ->
  scalar bool`` (or the Python constant ``False`` for a never-firing
  trigger), evaluated every round. An optional ``condition(ctx) ->
  (hot, nhot)`` runs inside the gated branch and yields the per-learner
  "wants to sync" mask; when present the cohort/aggregate/commit pipeline
  only runs when ``nhot > 0`` (sigma_Delta's shape). A condition may
  return a third element — a dict of auxiliary arrays (e.g. the (m,)
  distances the divergence check already paid for) — which the compiled
  round threads to the downstream stages as ``StageCtx.cond_aux`` so the
  round computes them exactly once. Triggers own their
  extra carried state via ``init_extra(params, m) -> dict``,
  ``commit_extra(ctx, mask) -> dict`` (after a sync; ``mask`` is the
  committed cohort) and ``skip_extra(ctx) -> dict`` (any round without a
  sync commit).
* **cohort** — ``fn(ctx, hot, nhot, rng) -> CohortOut``: WHO participates.
  Declares capabilities: ``uses_overlay`` (needs the peer adjacency),
  ``uses_coordinator`` (star traffic to a hub — hierarchies require it),
  ``provides`` (labels downstream stages can depend on), and
  ``needs_condition`` (requires a conditional trigger's hot/nhot).
* **aggregate** — ``fn(ctx, cohort_out) -> model``: WHAT the cohort
  agrees on. ``needs`` names the cohort labels it depends on.
* **commit** — ``fn(ctx, cohort_out, aggregate, hot, nhot) -> SyncOut``:
  APPLY the agreement and ACCOUNT for it (CommRecord + the per-link
  transfer/message counts the bytes ledger prices).

Every stage may declare ``params`` (name -> default, merged into the
spec's parameter space) and ``validate(params)`` (raise ``ValueError`` at
spec CONSTRUCTION, not trace time).

Every stage MUST declare a ``StageContract`` — the shape/dtype promises
the static contract checker (``repro.analysis.contracts``) verifies by
abstract evaluation across every preset × layout × hierarchy combination:
counters stay int32, masks stay bool, the committed configuration keeps
the input dtypes exactly, aggregate outputs match their declared kind
((P,)/model vs (m, P)/fleet duals), and trigger-owned extra state keeps
its declared dtypes through commit and skip paths. The repo lint
(``repro.analysis.lint``) rejects ``register_*`` calls without one.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# shared carried-state / result types (kernel.py re-exports these)
# ---------------------------------------------------------------------------

class SyncState(NamedTuple):
    ref: Any             # reference model r (single-model pytree)
    v: jnp.ndarray       # violation counter (scalar int32)
    rng: jnp.ndarray     # PRNG key for subsampling / random augmentation
    step: jnp.ndarray    # round counter t (scalar int32)
    extra: Any = {}      # trigger-declared extra carried state (dict of
    #   arrays, e.g. the staleness counters); {} for the built-in presets,
    #   so the carry pytree is unchanged vs the pre-spec engine


class CommRecord(NamedTuple):
    model_up: jnp.ndarray     # models sent learner -> coordinator
    model_down: jnp.ndarray   # models sent coordinator -> learner
    messages: jnp.ndarray     # small control messages (violations, polls)
    syncs: jnp.ndarray        # 1 if any averaging happened this round
    full_syncs: jnp.ndarray   # 1 if ALL (reachable) learners were averaged

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.int32)
        return CommRecord(z, z, z, z, z)


class StageResult(NamedTuple):
    """What one staged round produces: the committed configuration, the
    carried sync state, the scalar comm record, and the per-link counts
    (model transfers + control messages) the bytes ledger prices."""
    params: Any
    state: SyncState
    rec: CommRecord
    xfers: jnp.ndarray       # (m,) int32 models crossing each learner's link
    link_msgs: jnp.ndarray   # (m,) int32 control messages per learner link


class StageCtx(NamedTuple):
    """One round's inputs, shared by every stage.

    Under ``layout="flat"`` the compiled round additionally carries the
    flat fleet-plane (``repro.core.flatten``): ``flat`` is the whole
    configuration as one (m, P) matrix, ``ref_flat`` the reference model
    as its (P,) row, and ``adapter`` the static ravel/unravel maps.
    Stages branch on ``ctx.flat is not None`` to run their dense-matrix
    form; under the default ``layout="tree"`` all three stay ``None`` and
    the per-leaf pytree expressions run bitwise-unchanged. ``cond_aux``
    carries whatever a conditional trigger computed beyond (hot, nhot) —
    e.g. the divergence trigger's (m,) distances, which the balancing
    cohort reuses as its augmentation priority instead of recomputing
    them from scratch."""
    params: Dict[str, Any]           # the spec's resolved (static) params
    stacked: Any                     # (m, ...) model pytree
    state: SyncState
    weights: Optional[jnp.ndarray]   # Algorithm-2 B^i weights (or None)
    active: Optional[jnp.ndarray]    # (m,) reachability, None = ideal net
    adjacency: Optional[jnp.ndarray]  # (m, m) peer overlay (or None)
    m: int                           # fleet size (static)
    t: jnp.ndarray                   # this round's index (state.step + 1)
    reach: jnp.ndarray               # (m,) bool; all-ones when active=None
    flat: Optional[jnp.ndarray] = None      # (m, P) plane (flat layout)
    ref_flat: Optional[jnp.ndarray] = None  # (P,) reference row
    adapter: Any = None              # static FleetAdapter (flat layout)
    cond_aux: Any = None             # trigger-condition extras (e.g. dists)


class CohortOut(NamedTuple):
    """A cohort stage's output. ``v``/``full`` are None unless the cohort
    manages the violation counter (the balancing cohort); ``ideal`` is a
    PYTHON bool marking the ideal-network full-participation fast path
    (``active is None`` + everyone in), which downstream stages use to
    keep the pre-network expressions bitwise."""
    mask: jnp.ndarray                # (m,) bool participants
    rng: jnp.ndarray                 # carried PRNG key (split or untouched)
    v: Optional[jnp.ndarray] = None
    full: Optional[jnp.ndarray] = None
    ideal: bool = False
    aux: Any = None                  # stage-specific extras (e.g. A, W)


class SyncOut(NamedTuple):
    """A commit stage's output — everything that crosses the trigger's
    ``lax.cond`` boundary."""
    params: Any
    ref: Any
    v: jnp.ndarray
    rng: jnp.ndarray
    extra: Any
    rec: CommRecord
    xfers: jnp.ndarray
    link_msgs: jnp.ndarray


def carried_v(ctx: StageCtx, cout: CohortOut) -> jnp.ndarray:
    """The violation counter a commit stage should carry forward."""
    return ctx.state.v if cout.v is None else cout.v


# ---------------------------------------------------------------------------
# stage contracts
# ---------------------------------------------------------------------------

class StageContract(NamedTuple):
    """The static shape/dtype promises of one registered stage.

    Declared at registration (``register_*(..., contract=...)``) and
    verified — never trusted — by ``repro.analysis.contracts``, which
    abstract-evaluates the stage (``jax.eval_shape``, zero FLOPs) under
    every registered preset × layout × hierarchy combination. Slots read
    only the fields that concern them:

    * **trigger** — ``extra_state``: ``(name, dtype)`` pairs for the
      arrays the trigger threads through ``SyncState.extra`` (each (m,);
      ``init_extra``/``commit_extra``/``skip_extra`` must all produce
      exactly this structure). ``cond_aux``: the keys of the condition's
      auxiliary-output dict (each an (m,) array).
    * **cohort** — ``manages_v``: the stage owns the violation counter
      (returns a scalar int32 ``v`` and a scalar bool ``full``;
      everything else must leave both ``None``). ``aux``: the keys of
      ``CohortOut.aux``.
    * **aggregate** — ``out``: ``"model"`` (a single-model pytree on the
      tree layout / a (P,) row on the plane) or ``"fleet"`` (an (m, ...)
      stacked pytree / the full (m, P) plane).

    Universal promises (not declarable — always enforced): the committed
    configuration and reference keep the input shapes AND dtypes bitwise,
    ``v``/``CommRecord``/``xfers``/``link_msgs`` are int32, masks are
    bool, the RNG key dtype is preserved.
    """
    summary: str = ""
    extra_state: tuple = ()       # trigger: ((name, dtype-str), ...)
    cond_aux: tuple = ()          # trigger: condition aux dict keys
    manages_v: bool = False       # cohort: owns v/full
    aux: tuple = ()               # cohort: CohortOut.aux dict keys
    out: str = "model"            # aggregate: "model" | "fleet"


# ---------------------------------------------------------------------------
# stage records
# ---------------------------------------------------------------------------

def _default_init_extra(params, m):
    return {}


def _default_commit_extra(ctx, mask):
    return ctx.state.extra


def _default_skip_extra(ctx):
    return ctx.state.extra


class TriggerStage(NamedTuple):
    name: str
    gate: Callable                    # ctx -> scalar bool (or False)
    condition: Optional[Callable]     # ctx -> (hot, nhot); None = always
    init_extra: Callable              # (params, m) -> dict of arrays
    commit_extra: Callable            # (ctx, mask) -> dict
    skip_extra: Callable              # ctx -> dict
    params: Dict[str, Any]
    validate: Optional[Callable]
    contract: Optional[StageContract] = None

    @property
    def conditional(self) -> bool:
        return self.condition is not None


class CohortStage(NamedTuple):
    name: str
    fn: Callable                      # (ctx, hot, nhot, rng) -> CohortOut
    provides: frozenset               # labels downstream stages may need
    uses_overlay: bool                # needs the peer adjacency matrix
    uses_coordinator: bool            # star traffic to a hub (hierarchies)
    needs_condition: bool             # requires a conditional trigger
    params: Dict[str, Any]
    validate: Optional[Callable]
    contract: Optional[StageContract] = None


class AggregateStage(NamedTuple):
    name: str
    fn: Callable                      # (ctx, cohort_out) -> model pytree
    needs: frozenset                  # cohort labels this stage depends on
    params: Dict[str, Any]
    validate: Optional[Callable]
    contract: Optional[StageContract] = None


class CommitStage(NamedTuple):
    name: str
    fn: Callable                      # (ctx, cout, agg, hot, nhot) -> SyncOut
    needs: frozenset
    needs_condition: bool
    params: Dict[str, Any]
    validate: Optional[Callable]
    contract: Optional[StageContract] = None


# ---------------------------------------------------------------------------
# the registries + decorators
# ---------------------------------------------------------------------------

TRIGGERS: Dict[str, TriggerStage] = {}
COHORTS: Dict[str, CohortStage] = {}
AGGREGATES: Dict[str, AggregateStage] = {}
COMMITS: Dict[str, CommitStage] = {}


def _enter(registry: Dict[str, Any], slot: str, name: str, record) -> None:
    if name in registry:
        raise ValueError(
            f"{slot} stage {name!r} is already registered — stage names "
            f"must be unique per slot (known: {sorted(registry)})")
    registry[name] = record


def register_trigger(name: str, *, condition: Optional[Callable] = None,
                     init_extra: Optional[Callable] = None,
                     commit_extra: Optional[Callable] = None,
                     skip_extra: Optional[Callable] = None,
                     params: Optional[Dict[str, Any]] = None,
                     validate: Optional[Callable] = None,
                     contract: Optional[StageContract] = None):
    """Register the decorated function as trigger ``name``'s gate."""
    def deco(gate: Callable) -> Callable:
        _enter(TRIGGERS, "trigger", name, TriggerStage(
            name=name, gate=gate, condition=condition,
            init_extra=init_extra or _default_init_extra,
            commit_extra=commit_extra or _default_commit_extra,
            skip_extra=skip_extra or _default_skip_extra,
            params=dict(params or {}), validate=validate,
            contract=contract))
        return gate
    return deco


def register_cohort(name: str, *, provides=(), uses_overlay: bool = False,
                    uses_coordinator: bool = True,
                    needs_condition: bool = False,
                    params: Optional[Dict[str, Any]] = None,
                    validate: Optional[Callable] = None,
                    contract: Optional[StageContract] = None):
    def deco(fn: Callable) -> Callable:
        _enter(COHORTS, "cohort", name, CohortStage(
            name=name, fn=fn, provides=frozenset(provides),
            uses_overlay=uses_overlay, uses_coordinator=uses_coordinator,
            needs_condition=needs_condition, params=dict(params or {}),
            validate=validate, contract=contract))
        return fn
    return deco


def register_aggregate(name: str, *, needs=(),
                       params: Optional[Dict[str, Any]] = None,
                       validate: Optional[Callable] = None,
                       contract: Optional[StageContract] = None):
    def deco(fn: Callable) -> Callable:
        _enter(AGGREGATES, "aggregate", name, AggregateStage(
            name=name, fn=fn, needs=frozenset(needs),
            params=dict(params or {}), validate=validate,
            contract=contract))
        return fn
    return deco


def register_commit(name: str, *, needs=(), needs_condition: bool = False,
                    params: Optional[Dict[str, Any]] = None,
                    validate: Optional[Callable] = None,
                    contract: Optional[StageContract] = None):
    def deco(fn: Callable) -> Callable:
        _enter(COMMITS, "commit", name, CommitStage(
            name=name, fn=fn, needs=frozenset(needs),
            needs_condition=needs_condition, params=dict(params or {}),
            validate=validate, contract=contract))
        return fn
    return deco


def _get(registry: Dict[str, Any], slot: str, name: str):
    if name not in registry:
        raise KeyError(
            f"unknown {slot} stage {name!r}; known: {sorted(registry)}")
    return registry[name]


def get_trigger(name: str) -> TriggerStage:
    return _get(TRIGGERS, "trigger", name)


def get_cohort(name: str) -> CohortStage:
    return _get(COHORTS, "cohort", name)


def get_aggregate(name: str) -> AggregateStage:
    return _get(AGGREGATES, "aggregate", name)


def get_commit(name: str) -> CommitStage:
    return _get(COMMITS, "commit", name)


# ---------------------------------------------------------------------------
# protocol presets: complete specs under a name
# ---------------------------------------------------------------------------

PROTOCOLS: Dict[str, Any] = {}   # name -> ProtocolSpec


def register_protocol(name: str, spec) -> None:
    """Make ``spec`` available as preset ``name`` — and thereby as a valid
    ``ProtocolConfig(kind=name)``."""
    if name in PROTOCOLS:
        raise ValueError(
            f"protocol {name!r} is already registered "
            f"(known: {sorted(PROTOCOLS)})")
    PROTOCOLS[name] = spec


def get_protocol(name: str):
    if name not in PROTOCOLS:
        raise KeyError(
            f"unknown protocol {name!r}; known: {sorted(PROTOCOLS)}")
    return PROTOCOLS[name]
