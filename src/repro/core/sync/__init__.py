"""The staged sync kernel: protocols as declarative stage compositions.

Every synchronization operator is a composition of four registered stages
(``repro.core.sync.registry`` + ``repro.core.sync.stages``):

    trigger  -> cohort  -> aggregate -> commit
    (fire?)     (who)      (what)       (apply + account)

A ``ProtocolSpec`` (``spec.py``) names one stage per slot, validates the
composition at construction, serializes to/from JSON, and compiles to the
scanned round the engine runs. The six built-in kinds are presets in the
``PROTOCOLS`` registry (``kernel.py``) — ``ProtocolConfig(kind=...)`` is
sugar resolving onto them, bitwise-identical to the pre-spec monoliths —
and new protocols register stages + a spec with zero kernel/engine edits
(``staleness.py`` is the worked example: bounded-staleness sync, preset
``"stale"``). ``hierarchy.py`` composes two compiled protocols into the
two-tier star-of-stars coordinator (``HierarchyConfig``).
"""
from repro.core.sync import hierarchy, kernel, registry, spec, stages  # noqa: F401,E501
from repro.core.sync import staleness  # noqa: F401  (registers "stale")
from repro.core.sync import async_sync  # noqa: F401  (registers "aircomp",
#                                  "async_periodic", "async_dynamic")
from repro.core.sync import robust  # noqa: F401  (registers
#                                  "robust_periodic", "robust_dynamic")
from repro.core.sync.hierarchy import (  # noqa: F401
    HierResult, HierSyncState, apply_hierarchical, init_hier_state,
)
from repro.core.sync.kernel import (  # noqa: F401
    OPERATORS, PROTOCOLS, CommRecord, StageResult, SyncState,
    apply_operator, apply_staged, init_state, register_protocol,
)
from repro.core.sync.registry import (  # noqa: F401
    AGGREGATES, COHORTS, COMMITS, TRIGGERS, register_aggregate,
    register_cohort, register_commit, register_trigger,
)
from repro.core.sync.async_sync import asyncify  # noqa: F401
from repro.core.sync.robust import hardened  # noqa: F401
from repro.core.sync.spec import ProtocolSpec, resolve_spec  # noqa: F401
from repro.core.sync.staleness import BOUNDED_STALENESS  # noqa: F401
