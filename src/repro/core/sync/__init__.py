"""The staged sync kernel: sigma decomposed into reusable stages.

Every synchronization operator is a composition of four stages
(``repro.core.sync.stages``):

    trigger  -> cohort  -> aggregate -> commit
    (fire?)     (who)      (what)       (apply + account)

``kernel.py`` assembles the paper's operators (periodic/fedavg/dynamic/
gossip/nosync) from those stages behind the unchanged ``apply_operator``
signature — bitwise-identical to the pre-kernel monoliths — and exposes
the richer ``apply_staged`` entry the engine uses (adds the per-link
control-message counts that feed the bytes ledger). ``hierarchy.py``
composes two kernel instances into the two-tier star-of-stars
coordinator (``HierarchyConfig``).
"""
from repro.core.sync import hierarchy, kernel, stages  # noqa: F401
from repro.core.sync.hierarchy import (  # noqa: F401
    HierResult, HierSyncState, apply_hierarchical, init_hier_state,
)
from repro.core.sync.kernel import (  # noqa: F401
    OPERATORS, CommRecord, StageResult, SyncState, apply_operator,
    apply_staged, init_state,
)
