"""Two-tier star-of-stars coordination on top of the staged kernel.

The fleet of m learners is partitioned into g contiguous equal clusters
(cluster c owns learners ``c*k .. (c+1)*k-1``, k = m/g). Every round, fully
inside the scanned round body:

1. **intra tier** — the flat protocol (the learner's ``ProtocolConfig``)
   runs *vmapped over clusters*: each cluster has its own reference model,
   violation counter, and RNG (a ``SyncState`` with a leading cluster
   axis), and sees only its members' availability mask. A cluster's
   coordinator is its *edge aggregator*.
2. **edge aggregators** — each aggregator's model is its cluster's
   availability-masked (weighted) mean after the intra step; a cluster is
   reachable at the upper tier iff any member is.
3. **inter tier** — ``HierarchyConfig.inter`` runs the SAME staged kernel
   over the g aggregator models (own cadence/threshold/payload size), with
   per-cluster reference + violation state carried in a second
   ``SyncState``.
4. **commit down** — clusters whose aggregator synchronized push the
   inter-tier adjustment (new minus old aggregate) to their reachable
   members: intra-cluster diversity survives, cluster means move to the
   inter-tier agreement, and each receiving member's link carries one
   model download.

Accounting is exact per tier: member links count intra transfers +
down-pushes + intra control messages (priced at the intra payload size by
the engine's ledger); the g aggregator↔top-coordinator uplinks count the
inter tier's transfers and messages (priced at ``inter.bytes_per_param`` —
a quantized backhaul stays exact). Scalar ``CommRecord`` counts are merged
for reporting, but with mixed payload sizes the ledger — not
``transfers × model_bytes`` — is the source of truth for bytes.

Layout: an intra (or inter) spec with ``layout="flat"`` runs its staged
round on the flat fleet-plane INSIDE this composition with no edits here
— the compiled round ravels per cluster under the intra ``vmap`` (the
plane becomes a batched (g, k, P) matmul) and unravels before the stage
boundary, so the aggregator means, down-push and per-tier accounting
below always see pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import HierarchyConfig, ProtocolConfig
from repro.core.sync import stages
from repro.core.sync.kernel import (
    CommRecord, StageResult, SyncState, apply_staged,
)
from repro.core.sync.spec import ProtocolSpec, resolve_spec


class HierSyncState(NamedTuple):
    intra: SyncState   # leaves carry a leading (g,) cluster axis
    inter: SyncState   # over the g aggregator models


class HierResult(NamedTuple):
    params: object             # (m, ...) committed configuration
    state: HierSyncState
    rec: CommRecord            # merged scalar record (ledger is exact)
    member_xfers: jnp.ndarray  # (m,) models over member links
    member_msgs: jnp.ndarray   # (m,) control messages over member links
    agg_xfers: jnp.ndarray     # (g,) models over aggregator uplinks
    agg_msgs: jnp.ndarray      # (g,) control messages over aggregator uplinks


def validate_hierarchy(tiers: HierarchyConfig, m: int) -> int:
    """Cluster size k, or a clear error when the fleet doesn't partition."""
    g = tiers.num_clusters
    if m % g != 0:
        raise ValueError(
            f"hierarchy needs equal clusters: m={m} learners do not "
            f"partition into num_clusters={g} (m % g == {m % g}). "
            f"Pick g dividing m.")
    return m // g


def init_hier_state(base_model, tiers: HierarchyConfig, seed: int = 0,
                    m: Optional[int] = None,
                    intra_spec: Optional[ProtocolSpec] = None,
                    inter_spec: Optional[ProtocolSpec] = None
                    ) -> HierSyncState:
    """Per-cluster intra states (all clusters start from the shared init)
    plus one inter-tier state over the aggregators. Specs that carry
    extra state (e.g. bounded-staleness counters) get one instance per
    cluster at the intra tier (leading (g,) axis, vmapped with the rest
    of the intra state) and one over the g aggregators at the inter
    tier; ``m`` is required whenever the intra spec carries any."""
    g = tiers.num_clusters

    def extra_for(spec, n):
        if spec is None or not spec.extra_state:
            return {}
        return spec.init_extra(n)

    intra_extra = {}
    if intra_spec is not None and intra_spec.extra_state:
        if m is None:
            raise ValueError(
                "init_hier_state needs the fleet size m to build the "
                f"intra spec's extra state {intra_spec.extra_state}")
        k = validate_hierarchy(tiers, m)
        intra_extra = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g,) + x.shape).copy(),
            extra_for(intra_spec, k))
    intra = SyncState(
        ref=stages.broadcast_model(base_model, g),
        v=jnp.zeros((g,), jnp.int32),
        rng=jax.random.split(jax.random.PRNGKey(seed ^ 0x417E7), g),
        step=jnp.zeros((g,), jnp.int32),
        extra=intra_extra,
    )
    inter = SyncState(
        ref=base_model,
        v=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(seed ^ 0x1A7E2),
        step=jnp.zeros((), jnp.int32),
        extra=extra_for(inter_spec, g),
    )
    return HierSyncState(intra=intra, inter=inter)


def apply_hierarchical(cfg: ProtocolConfig, tiers: HierarchyConfig,
                       stacked, hstate: HierSyncState, weights=None,
                       active: Optional[jnp.ndarray] = None) -> HierResult:
    """One hierarchical round: intra tier (vmapped over clusters) →
    aggregators → inter tier → commit down. Pure and jit/scan-compatible;
    ``active`` is the flat (m,) reachability mask."""
    m = stages.num_learners(stacked)
    g = tiers.num_clusters
    k = m // g
    if not resolve_spec(cfg).param("weighted"):
        # same contract as the flat kernel: Algorithm-2 weights only enter
        # (the aggregator means and the inter tier's cluster weights) when
        # the intra config asks for them
        weights = None

    clustered = jax.tree.map(
        lambda x: x.reshape((g, k) + x.shape[1:]), stacked)
    w_gk = weights.reshape(g, k) if weights is not None else None
    act_gk = active.reshape(g, k) if active is not None else None

    # --- 1. intra tier: the flat staged operator, one instance per cluster
    def intra_fn(stk, st, w, act):
        return apply_staged(cfg, stk, st, w, active=act)

    res: StageResult = jax.vmap(
        intra_fn,
        in_axes=(0, 0, 0 if w_gk is not None else None,
                 0 if act_gk is not None else None),
    )(clustered, hstate.intra, w_gk, act_gk)

    # --- 2. edge aggregators: masked cluster means of the post-intra models
    member_mask = (act_gk if act_gk is not None
                   else jnp.ones((g, k), bool))
    if w_gk is not None:
        agg = jax.vmap(stages.aggregate_mean)(res.params, member_mask, w_gk)
        cluster_w = jnp.sum(w_gk, axis=1)
    else:
        agg = jax.vmap(lambda s, msk: stages.aggregate_mean(s, msk))(
            res.params, member_mask)
        cluster_w = None
    agg_active = jnp.any(member_mask, axis=1) if act_gk is not None else None

    # --- 3. inter tier: the same kernel over the g aggregator models.
    # Under Algorithm 2 each aggregator carries its cluster's sampling
    # mass (sum of member B^i): the inter tier MUST weight by it or a full
    # two-hop sync would land on the unweighted mean of cluster means, not
    # the weighted global mean — so the intra tier's weighting turns the
    # inter tier weighted too, whatever tiers.inter.weighted says.
    inter_cfg = tiers.inter
    if cluster_w is not None and not inter_cfg.weighted:
        inter_cfg = dataclasses.replace(inter_cfg, weighted=True)
    inter_res: StageResult = apply_staged(
        inter_cfg, agg, hstate.inter, cluster_w, active=agg_active)

    # --- 4. commit down: clusters that synchronized at the upper tier push
    # the inter-tier adjustment to their reachable members (keeps
    # intra-cluster diversity; moves the cluster mean to the agreement)
    delta = jax.tree.map(lambda a, b: a - b, inter_res.params, agg)
    participated = inter_res.xfers > 0                       # (g,)
    down_mask = participated[:, None] & member_mask          # (g, k)

    def push(c, d):
        dm = down_mask.reshape(down_mask.shape + (1,) * (c.ndim - 2))
        return jnp.where(dm, c + d[:, None], c)

    new_clustered = jax.tree.map(push, res.params, delta)
    n_down = jnp.sum(down_mask).astype(jnp.int32)

    # --- accounting: per-tier link counts + merged scalar record
    member_xfers = (res.xfers + down_mask.astype(jnp.int32)).reshape(m)
    member_msgs = res.link_msgs.reshape(m)
    intra_sum = CommRecord(*(jnp.sum(f).astype(jnp.int32) for f in res.rec))
    rec = CommRecord(
        model_up=intra_sum.model_up + inter_res.rec.model_up,
        model_down=intra_sum.model_down + inter_res.rec.model_down + n_down,
        messages=intra_sum.messages + inter_res.rec.messages,
        syncs=((intra_sum.syncs + inter_res.rec.syncs) > 0)
        .astype(jnp.int32),
        # "full" at the fleet level: the inter tier averaged every
        # reachable aggregator (the hierarchy's analogue of all-reachable)
        full_syncs=inter_res.rec.full_syncs)

    params = jax.tree.map(
        lambda x: x.reshape((m,) + x.shape[2:]), new_clustered)
    return HierResult(
        params=params,
        state=HierSyncState(intra=res.state, inter=inter_res.state),
        rec=rec,
        member_xfers=member_xfers,
        member_msgs=member_msgs,
        agg_xfers=inter_res.xfers,
        agg_msgs=inter_res.link_msgs,
    )
