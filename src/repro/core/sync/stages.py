"""Stage library: the four phases every synchronization operator shares.

A round of any operator factors into

* **trigger**   — should the sync machinery run at all? (cadence ``t % b``,
                  sigma_Delta's divergence condition, or the bounded-
                  staleness counters)
* **cohort**    — WHO participates: everyone reachable, a random
                  C-fraction, the balancing augmentation's growing set, or
                  a neighborhood mixing matrix — all availability-masked
* **aggregate** — WHAT they agree on: masked (weighted) mean, or one
                  Metropolis–Hastings mixing step
* **commit**    — APPLY and ACCOUNT: per-learner select, reference /
                  violation-counter updates, CommRecord math, per-link
                  transfer and control-message counts (the bytes ledger's
                  inputs)

The first half of this module is the arithmetic library — pure functions
of scalars and pytrees, kept expression-for-expression identical to the
pre-kernel monoliths so any composition of them reproduces the PR-2
engine bitwise (pinned by ``tests/golden_pr2_engine.json``). The second
half registers the built-in stages into the named registries
(``repro.core.sync.registry``) under the contracts a ``ProtocolSpec``
composes; the six preset protocols in ``kernel.py`` are nothing but
spec-level wirings of these registrations.

Every registered stage has TWO arithmetic forms behind one registration:
the per-leaf pytree expressions (``layout="tree"``, the default, bitwise
vs the goldens) and the dense matrix form over the flat fleet-plane
(``layout="flat"``, see ``repro.core.flatten``), selected by
``ctx.flat is not None``. On the plane the per-learner distances are one
batched row pass, the masked weighted mean is one ``w @ X`` matvec,
gossip's mixing step is one ``W @ X`` matmul, commits are one
``jnp.where`` on (m, P) — and the balancing augmentation maintains an
incremental running sum so each iteration costs O(P) instead of a full
O(m*P) fleet re-aggregation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.divergence import (
    per_learner_sq_distance, per_learner_sq_distance_flat, tree_mean,
    tree_weighted_mean,
)
from repro.core.sync.registry import (
    CohortOut, CommRecord, StageContract, StageCtx, SyncOut, carried_v,
    register_aggregate, register_cohort, register_commit, register_trigger,
)


# ---------------------------------------------------------------------------
# shared pytree helpers
# ---------------------------------------------------------------------------

def num_learners(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def zeros_i32(m: int) -> jnp.ndarray:
    return jnp.zeros((m,), jnp.int32)


def tree_select(mask, new, old):
    """Per-learner select: leaf (m, ...) <- new where mask[i] else old."""
    def sel(n, o):
        m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def broadcast_model(model, m: int):
    """Replicate a single-model pytree along a fresh leading learner axis."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape),
                        model)


# ---------------------------------------------------------------------------
# flat fleet-plane arithmetic (layout="flat"; repro.core.flatten)
# ---------------------------------------------------------------------------

def flat_weighted_mean(X, w):
    """Masked/weighted mean over the plane's rows: ``w @ X / sum(w)`` —
    ONE matvec for the whole fleet. Same all-zero guard as
    ``tree_weighted_mean``: an empty weight vector yields the zero row."""
    w = w.astype(X.dtype)
    wsum = jnp.sum(w)
    denom = jnp.where(wsum > 0, wsum, jnp.ones_like(wsum))
    return (w @ X) / denom


def flat_aggregate_mean(X, mask, weights=None):
    """The plane dual of ``aggregate_mean``."""
    w = mask.astype(X.dtype)
    if weights is not None:
        w = w * weights.astype(X.dtype)
    return flat_weighted_mean(X, w)


def _flat_sq_to_ref(row, ref):
    d = row - ref
    return jnp.sum(d * d)


# stage-internal helpers: pick the arithmetic form the ctx carries -----------

def _cfg_view(ctx):
    return ctx.flat if ctx.flat is not None else ctx.stacked


def _ref_view(ctx):
    return ctx.ref_flat if ctx.flat is not None else ctx.state.ref


def _select_commit(ctx, mask, mean):
    """Cohort members adopt the aggregate, on whichever layout the round
    carries (one (m, P) ``jnp.where`` on the plane)."""
    if ctx.flat is not None:
        return jnp.where(mask[:, None], mean[None, :], ctx.flat)
    return commit_select(ctx.stacked, mask, mean)


def _ref_if_commit(ctx, moved, mean):
    if ctx.flat is not None:
        return jnp.where(moved, mean, ctx.ref_flat)
    return commit_ref_if(moved, mean, ctx.state.ref)


def _broadcast_commit(ctx, mean, m: int):
    if ctx.flat is not None:
        return jnp.broadcast_to(mean[None, :], (m,) + mean.shape)
    return broadcast_model(mean, m)


def _cond_dists(ctx):
    """The (m,) distances a conditional trigger already computed this
    round (``StageCtx.cond_aux``), or None."""
    if isinstance(ctx.cond_aux, dict):
        return ctx.cond_aux.get("dists")
    return None


# ---------------------------------------------------------------------------
# trigger arithmetic
# ---------------------------------------------------------------------------

def cadence_fire(b: int, t) -> jnp.ndarray:
    """The schedule half of every trigger: sync machinery runs when
    ``t % b == 0``."""
    return (t % b) == 0


def divergence_trigger(delta: float, stacked, ref, reach):
    """sigma_Delta's condition half: which reachable learners violate
    ``||f_i - r||^2 > Delta``. Returns ``(dists, violated, nviol)`` — the
    distances double as the balancing cohort's augmentation priority."""
    dists = per_learner_sq_distance(stacked, ref)
    violated = (dists > delta) & reach
    return dists, violated, jnp.sum(violated).astype(jnp.int32)


# ---------------------------------------------------------------------------
# cohort arithmetic
# ---------------------------------------------------------------------------

def cohort_all(m: int, active: Optional[jnp.ndarray]) -> jnp.ndarray:
    """sigma_b's cohort: every reachable learner."""
    return jnp.ones((m,), bool) if active is None else active


def cohort_fraction_ideal(sub, m: int, k: int) -> jnp.ndarray:
    """FedAvg's cohort on an ideal network: a uniform random k-subset."""
    perm = jax.random.permutation(sub, m)
    return jnp.zeros((m,), bool).at[perm[:k]].set(True)


def cohort_fraction_masked(sub, m: int, k: int, active) -> jnp.ndarray:
    """FedAvg's cohort under availability: rank the reachable learners by
    a fresh uniform draw and take the first min(k, |active|) — the same
    C-fraction target, restricted to whoever answered this round."""
    r = jax.random.uniform(sub, (m,))
    ranks = jnp.argsort(jnp.argsort(jnp.where(active, r, -jnp.inf)))
    return (ranks >= m - jnp.minimum(k, jnp.sum(active))) & active


def cohort_balanced(delta: float, augmentation: str, stacked, ref, violated,
                    rng, weights=None, reach=None, dists=None):
    """sigma_Delta's cohort: coordinator balancing. Augment the violator
    set B until the partial average re-enters the safe zone
    ``||mean_B - r||^2 <= Delta`` or B covers every REACHABLE learner
    (B = [m] on an ideal network).

    This is the one stage where cohort and aggregate iterate together —
    each augmentation step re-aggregates to test the safe zone — so it
    returns both ``(mask B, mean_B)``. The caller derives poll counts from
    the mask: it is the single source of truth for who the coordinator
    contacted. ``dists`` accepts the (m,) distances the divergence
    trigger already computed this round (the augmentation priority), so a
    round never pays for the monitoring pass twice.
    """
    m = num_learners(stacked)
    if reach is None:
        reach = jnp.ones((m,), bool)
    if dists is None:
        dists = per_learner_sq_distance(stacked, ref)  # (m,) — priority

    if augmentation == "random":
        prio = jax.random.uniform(rng, (m,))
    elif augmentation == "max_distance":
        prio = dists
    else:  # "all": jump straight to full sync on any violation
        prio = jnp.full((m,), jnp.inf)

    def mean_dist(mask):
        mean = aggregate_mean(stacked, mask, weights)
        d = sum(
            jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
            for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)))
        return mean, d

    if augmentation == "all":
        mean = aggregate_mean(stacked, reach, weights)
        return reach, mean

    _, d0 = mean_dist(violated)

    def cond(carry):
        mask, d = carry
        return jnp.logical_and(jnp.any(reach & ~mask), d > delta)

    def body(carry):
        mask, _ = carry
        cand = jnp.where(mask | ~reach, -jnp.inf, prio)
        nxt = jnp.argmax(cand)
        mask = mask.at[nxt].set(True)
        _, d = mean_dist(mask)
        return mask, d

    mask, _ = jax.lax.while_loop(cond, body, (violated, d0))
    mean = aggregate_mean(stacked, mask, weights)
    return mask, mean


def cohort_balanced_flat(delta: float, augmentation: str, X, ref, violated,
                         rng, weights=None, reach=None, dists=None):
    """The balancing augmentation on the flat fleet-plane, with an
    INCREMENTAL running sum: the loop carries ``(sum_B, wsum_B)`` and each
    augmentation step adds one row (``sum += w[nxt] * X[nxt]``) and tests
    the safe zone on ``||sum/wsum - r||^2`` — O(P) per iteration, so the
    whole balancing pass is O(m*P) instead of the tree layout's
    O(m^2*P) worst case (a full fleet re-aggregation per step).

    Same contract as ``cohort_balanced``: returns ``(mask B, mean_B)``
    with the final mean recomputed as one masked matvec (matching the
    aggregate stage's expression, not the running sum's association)."""
    m = X.shape[0]
    if reach is None:
        reach = jnp.ones((m,), bool)

    if augmentation == "all":   # jump straight to full sync: no priority
        return reach, flat_aggregate_mean(X, reach, weights)

    if augmentation == "random":
        prio = jax.random.uniform(rng, (m,))
    else:  # "max_distance"
        prio = (per_learner_sq_distance_flat(X, ref) if dists is None
                else dists)

    w = (weights.astype(X.dtype) if weights is not None
         else jnp.ones((m,), X.dtype))

    def safe_dist(s, ws):
        denom = jnp.where(ws > 0, ws, jnp.ones_like(ws))
        return _flat_sq_to_ref(s / denom, ref)

    w0 = violated.astype(X.dtype) * w
    s0 = w0 @ X
    ws0 = jnp.sum(w0)

    def cond(carry):
        mask, _, _, d = carry
        return jnp.logical_and(jnp.any(reach & ~mask), d > delta)

    def body(carry):
        mask, s, ws, _ = carry
        cand = jnp.where(mask | ~reach, -jnp.inf, prio)
        nxt = jnp.argmax(cand)
        mask = mask.at[nxt].set(True)
        s = s + w[nxt] * X[nxt]
        ws = ws + w[nxt]
        return mask, s, ws, safe_dist(s, ws)

    mask, _, _, _ = jax.lax.while_loop(
        cond, body, (violated, s0, ws0, safe_dist(s0, ws0)))
    return mask, flat_aggregate_mean(X, mask, weights)


def cohort_neighborhood(m: int, active: Optional[jnp.ndarray], adjacency):
    """Gossip's cohort: the availability-masked peer overlay plus its
    Metropolis–Hastings mixing matrix
        W_ij = 1 / (1 + max(deg_i, deg_j))   for active edges i~j
        W_ii = 1 - sum_j W_ij
    which is doubly stochastic for a symmetric adjacency, so the
    configuration mean is preserved. Unreachable (or isolated) learners
    have W row e_i and keep their model bitwise. Returns ``(A, W)``."""
    act = jnp.ones((m,), bool) if active is None else active
    A = (jnp.asarray(adjacency, bool) & act[None, :] & act[:, None]
         & ~jnp.eye(m, dtype=bool))
    deg = jnp.sum(A, axis=1).astype(jnp.float32)
    W = jnp.where(A, 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])),
                  0.0)
    W = W + jnp.diag(1.0 - jnp.sum(W, axis=1))
    return A, W


# ---------------------------------------------------------------------------
# aggregate arithmetic
# ---------------------------------------------------------------------------

def aggregate_mean(stacked, mask, weights=None):
    """Mean of the masked subset of learners (optionally B^i-weighted).
    An empty mask yields the zero model (``tree_weighted_mean`` guards the
    0/0) — commits keep the previous configuration via their selects."""
    w = mask.astype(jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    return tree_weighted_mean(stacked, w)


def aggregate_mean_ideal(stacked, m: int, weights=None):
    """The ideal-network (no-mask) aggregate: ``tree_mean`` unweighted —
    the exact expression the pre-network engine used, preserved for the
    bitwise regression — or the all-ones weighted mean."""
    if weights is None:
        return tree_mean(stacked)
    return aggregate_mean(stacked, jnp.ones((m,), bool), weights)


def aggregate_mix(stacked, W):
    """One mixing step: every learner's model becomes its W-row combination
    of the neighborhood's models. The matmul runs in the promoted
    accumulation dtype (at least f32) and narrows back to the leaf dtype —
    the float32 Metropolis–Hastings weights are never downcast to a
    sub-f32 leaf dtype (f32 leaves: expression-identical to the goldens)."""
    def mix(x):
        acc = jnp.promote_types(x.dtype, jnp.float32)
        return jnp.tensordot(W.astype(acc), x.astype(acc),
                             axes=1).astype(x.dtype)
    return jax.tree.map(mix, stacked)


# ---------------------------------------------------------------------------
# commit arithmetic
# ---------------------------------------------------------------------------

def commit_select(stacked, mask, mean):
    """Cohort members adopt the aggregate; everyone else keeps their model."""
    m = num_learners(stacked)
    return tree_select(mask, broadcast_model(mean, m), stacked)


def commit_ref_if(moved, mean, ref):
    """Reference update gated on a scalar condition (``periodic``/``fedavg``:
    anyone averaged; ``dynamic``: the sync covered every reachable
    learner)."""
    return jax.tree.map(lambda a, b: jnp.where(moved, a, b), mean, ref)


def xfers_cohort(mask) -> jnp.ndarray:
    """Coordinator-link transfer counts: each cohort member's uplink
    carries its model up and the aggregate back down (2 per member), so
    ``sum(xfers) == model_up + model_down``."""
    return mask.astype(jnp.int32) * 2


def xfers_neighborhood(A) -> jnp.ndarray:
    """Gossip transfer counts: every exchanged model occupies the links of
    BOTH endpoints, so ``sum(xfers) == 2 * (model_up + model_down)``."""
    return (2 * jnp.sum(A, axis=1)).astype(jnp.int32)


# ===========================================================================
# registered stages: the built-in entries of the four registries
# ===========================================================================

def _validate_b(params):
    b = params["b"]
    if not (isinstance(b, int) and b >= 1):
        raise ValueError(f"cadence period b must be an int >= 1, got {b!r}")


def _validate_delta(params):
    _validate_b(params)
    if not params["delta"] > 0:
        raise ValueError(
            f"divergence threshold delta must be > 0, got {params['delta']!r}")


def _validate_fraction(params):
    if not 0.0 < params["fedavg_c"] <= 1.0:
        raise ValueError(
            f"fedavg_c must be in (0, 1], got {params['fedavg_c']!r}")


def _validate_balanced(params):
    if params["augmentation"] not in ("max_distance", "random", "all"):
        raise ValueError(
            f"augmentation must be max_distance|random|all, "
            f"got {params['augmentation']!r}")
    if not params["delta"] > 0:
        raise ValueError(
            f"balanced cohort needs delta > 0, got {params['delta']!r}")


# ---- triggers -------------------------------------------------------------

@register_trigger("never", contract=StageContract(
    summary="statically-never gate; no state, no condition"))
def trigger_never(ctx: StageCtx):
    """nosync's trigger: the Python constant False — the compiled round
    skips the sync machinery entirely (no ``lax.cond`` is traced)."""
    return False


@register_trigger("cadence", params={"b": 1}, validate=_validate_b,
                  contract=StageContract(
                      summary="scalar bool gate t % b == 0; stateless"))
def trigger_cadence(ctx: StageCtx):
    """sigma_b's trigger: fire every ``b`` rounds, unconditionally."""
    return cadence_fire(ctx.params["b"], ctx.t)


def _divergence_condition(ctx: StageCtx):
    if ctx.flat is not None:
        dists = per_learner_sq_distance_flat(ctx.flat, ctx.ref_flat)
        violated = (dists > ctx.params["delta"]) & ctx.reach
        nviol = jnp.sum(violated).astype(jnp.int32)
    else:
        dists, violated, nviol = divergence_trigger(
            ctx.params["delta"], ctx.stacked, ctx.state.ref, ctx.reach)
    # the distances double as the balancing cohort's augmentation
    # priority — thread them so the round pays for the monitoring pass
    # exactly once
    return violated, nviol, {"dists": dists}


@register_trigger("divergence", condition=_divergence_condition,
                  params={"b": 1, "delta": 0.5}, validate=_validate_delta,
                  contract=StageContract(
                      summary="conditional gate; threads the (m,) f32 "
                              "monitoring distances to downstream stages",
                      cond_aux=("dists",)))
def trigger_divergence(ctx: StageCtx):
    """sigma_Delta's trigger: check every ``b`` rounds (the gate); the
    condition marks reachable learners with ``||f_i - r||^2 > Delta``."""
    return cadence_fire(ctx.params["b"], ctx.t)


# ---- cohorts --------------------------------------------------------------

@register_cohort("all_reachable", provides=("full-cohort",),
                 contract=StageContract(
                     summary="(m,) bool mask = reachability; no counter"))
def cohort_all_stage(ctx: StageCtx, hot, nhot, rng) -> CohortOut:
    """sigma_b's cohort: every reachable learner; on the ideal network the
    full fleet (``ideal=True`` keeps the pre-network expressions)."""
    return CohortOut(mask=cohort_all(ctx.m, ctx.active), rng=rng,
                     ideal=ctx.active is None)


@register_cohort("fraction", provides=("subset",),
                 params={"fedavg_c": 0.3}, validate=_validate_fraction,
                 contract=StageContract(
                     summary="(m,) bool random C-fraction; static subset "
                             "size k in aux",
                     aux=("k",)))
def cohort_fraction_stage(ctx: StageCtx, hot, nhot, rng) -> CohortOut:
    """FedAvg's cohort: a random ceil(C*m)-subset, drawn from the
    REACHABLE learners under availability masks."""
    k = max(1, int(round(ctx.params["fedavg_c"] * ctx.m)))
    rng, sub = jax.random.split(rng)
    if ctx.active is None:
        mask = cohort_fraction_ideal(sub, ctx.m, k)
    else:
        mask = cohort_fraction_masked(sub, ctx.m, k, ctx.active)
    return CohortOut(mask=mask, rng=rng, aux={"k": k})


@register_cohort("balanced", provides=("balance",), needs_condition=True,
                 params={"delta": 0.5, "augmentation": "max_distance"},
                 validate=_validate_balanced,
                 contract=StageContract(
                     summary="balancing augmentation; owns the int32 "
                             "violation counter and the full-sync flag",
                     manages_v=True))
def cohort_balanced_stage(ctx: StageCtx, hot, nhot, rng) -> CohortOut:
    """sigma_Delta's cohort: coordinator balancing (Algorithm 1). Owns the
    violation counter: the hot count accumulates into ``v``, ``v >= m``
    forces a full sync, and any sync covering every reachable learner
    resets it."""
    rng, sub = jax.random.split(rng)
    v_new = ctx.state.v + nhot
    # if the counter reaches m, force a sync of every reachable learner
    # and reset it
    force_full = v_new >= ctx.m
    base = jnp.where(force_full, ctx.reach, hot)
    v_reset = jnp.where(force_full, jnp.int32(0), v_new)
    balance = (cohort_balanced_flat if ctx.flat is not None
               else cohort_balanced)
    mask, _ = balance(
        ctx.params["delta"], ctx.params["augmentation"], _cfg_view(ctx),
        _ref_view(ctx), base, sub, ctx.weights, ctx.reach,
        dists=_cond_dists(ctx))
    full = jnp.all(mask == ctx.reach)
    v_final = jnp.where(full, jnp.int32(0), v_reset)
    return CohortOut(mask=mask, rng=rng, v=v_final, full=full)


@register_cohort("neighborhood", provides=("mixing",), uses_overlay=True,
                 uses_coordinator=False,
                 contract=StageContract(
                     summary="peer overlay cohort: (m, m) bool active "
                             "adjacency A + f32 mixing matrix W in aux",
                     aux=("A", "W")))
def cohort_neighborhood_stage(ctx: StageCtx, hot, nhot, rng) -> CohortOut:
    """Gossip's cohort: the availability-masked peer overlay and its
    Metropolis–Hastings mixing matrix. No coordinator."""
    if ctx.adjacency is None:
        raise ValueError(
            "gossip needs an adjacency matrix — configure a NetworkConfig "
            "topology (the engine passes it through)")
    A, W = cohort_neighborhood(ctx.m, ctx.active, ctx.adjacency)
    return CohortOut(mask=cohort_all(ctx.m, ctx.active), rng=rng,
                     aux={"A": A, "W": W})


# ---- aggregates -----------------------------------------------------------

@register_aggregate("mean", contract=StageContract(
    summary="masked (weighted) cohort mean in the leaf dtypes",
    out="model"))
def aggregate_mean_stage(ctx: StageCtx, cout: CohortOut):
    """Masked (weighted) mean of the cohort; the full-fleet ideal path
    (``cout.ideal``) keeps the pre-network ``tree_mean`` expression
    bitwise. On the flat plane both paths are one matvec (the ideal
    unweighted one a plain row mean)."""
    if ctx.flat is not None:
        if cout.ideal and ctx.weights is None:
            return jnp.mean(ctx.flat, axis=0)
        mask = (jnp.ones((ctx.m,), bool) if cout.ideal else cout.mask)
        return flat_aggregate_mean(ctx.flat, mask, ctx.weights)
    if cout.ideal:
        return aggregate_mean_ideal(ctx.stacked, ctx.m, ctx.weights)
    return aggregate_mean(ctx.stacked, cout.mask, ctx.weights)


@register_aggregate("mix", needs=("mixing",), contract=StageContract(
    summary="one M-H mixing step: per-learner output, not a single model",
    out="fleet"))
def aggregate_mix_stage(ctx: StageCtx, cout: CohortOut):
    """One Metropolis–Hastings mixing step over the neighborhood — a
    per-leaf tensordot on the tree layout, ONE ``W @ X`` matmul on the
    plane."""
    if ctx.flat is not None:
        return cout.aux["W"].astype(ctx.flat.dtype) @ ctx.flat
    return aggregate_mix(ctx.stacked, cout.aux["W"])


# ---- commits --------------------------------------------------------------

@register_commit("average", needs=("full-cohort",), contract=StageContract(
    summary="cohort adopts the mean; ref moves when anyone averaged"))
def commit_average(ctx: StageCtx, cout: CohortOut, mean, hot, nhot) -> SyncOut:
    """sigma_b's commit: every cohort member adopts the aggregate; the
    reference moves whenever anybody was actually averaged."""
    m = ctx.m
    if cout.ideal:
        newcfg = _broadcast_commit(ctx, mean, m)
        rec = CommRecord(
            model_up=jnp.int32(m), model_down=jnp.int32(m),
            messages=jnp.int32(0), syncs=jnp.int32(1),
            full_syncs=jnp.int32(1))
        return SyncOut(newcfg, mean, carried_v(ctx, cout), cout.rng,
                       ctx.state.extra, rec, jnp.full((m,), 2, jnp.int32),
                       zeros_i32(m))
    mask = cout.mask
    nsync = jnp.sum(mask).astype(jnp.int32)
    newcfg = _select_commit(ctx, mask, mean)
    # the reference only moves when somebody was actually averaged
    new_ref = _ref_if_commit(ctx, nsync > 0, mean)
    rec = CommRecord(
        model_up=nsync, model_down=nsync, messages=jnp.int32(0),
        syncs=(nsync > 0).astype(jnp.int32),
        # sigma_b always averages every reachable learner
        full_syncs=(nsync > 0).astype(jnp.int32))
    return SyncOut(newcfg, new_ref, carried_v(ctx, cout), cout.rng,
                   ctx.state.extra, rec, xfers_cohort(mask), zeros_i32(m))


@register_commit("subset", needs=("subset",), contract=StageContract(
    summary="subset adopts the mean; full when it covered every "
            "reachable learner"))
def commit_subset(ctx: StageCtx, cout: CohortOut, mean, hot, nhot) -> SyncOut:
    """FedAvg's commit: the subset adopts the aggregate; a sync is "full"
    when the subset covered every reachable learner."""
    m = ctx.m
    mask = cout.mask
    newcfg = _select_commit(ctx, mask, mean)
    if ctx.active is None:
        k = cout.aux["k"]
        rec = CommRecord(
            model_up=jnp.int32(k), model_down=jnp.int32(k),
            messages=jnp.int32(0), syncs=jnp.int32(1),
            full_syncs=jnp.int32(1 if k == m else 0))
        return SyncOut(newcfg, mean, carried_v(ctx, cout), cout.rng,
                       ctx.state.extra, rec, xfers_cohort(mask),
                       zeros_i32(m))
    nsel = jnp.sum(mask).astype(jnp.int32)
    new_ref = _ref_if_commit(ctx, nsel > 0, mean)
    rec = CommRecord(
        model_up=nsel, model_down=nsel, messages=jnp.int32(0),
        syncs=(nsel > 0).astype(jnp.int32),
        # full = the subset covered every reachable learner
        full_syncs=((nsel > 0) & (nsel == jnp.sum(ctx.active)))
        .astype(jnp.int32))
    return SyncOut(newcfg, new_ref, carried_v(ctx, cout), cout.rng,
                   ctx.state.extra, rec, xfers_cohort(mask), zeros_i32(m))


@register_commit("balancing", needs=("balance",), needs_condition=True,
                 contract=StageContract(
                     summary="balanced cohort adopts the partial average; "
                             "per-link chatter on the sending links"))
def commit_balancing(ctx: StageCtx, cout: CohortOut, mean, hot,
                     nhot) -> SyncOut:
    """sigma_Delta's commit: the balanced cohort adopts the partial
    average, the reference moves only on a full sync (Algorithm 1), and
    the per-link chatter is attributed to the links that sent it."""
    mask, full = cout.mask, cout.full
    newcfg = _select_commit(ctx, mask, mean)
    # reference model updates only on full sync (Algorithm 1)
    new_ref = _ref_if_commit(ctx, full, mean)
    nsync = jnp.sum(mask).astype(jnp.int32)
    # every member of the final B that did not itself violate was polled
    # by the coordinator — counting nsync - nhot covers the balancing loop
    # AND the forced-full path (where the balanced cohort starts from an
    # all-true mask). Per link that is one violation notice on each true
    # violator's link and one poll request on each polled member's link,
    # so the ledger sees the same chatter the scalar record counts.
    polls = nsync - nhot
    link_msgs = (hot.astype(jnp.int32) + (mask & ~hot).astype(jnp.int32))
    rec = CommRecord(
        model_up=nsync,          # violators push + coordinator polls
        model_down=nsync,        # partial average pushed back to B
        messages=nhot + polls,   # violation notices + poll requests
        syncs=jnp.int32(1),
        full_syncs=full.astype(jnp.int32))
    return SyncOut(newcfg, new_ref, carried_v(ctx, cout), cout.rng,
                   ctx.state.extra, rec, xfers_cohort(mask), link_msgs)


@register_commit("mix", needs=("mixing",), contract=StageContract(
    summary="every learner adopts its mixing row; transfers occupy both "
            "endpoints' links; the reference never moves"))
def commit_mix(ctx: StageCtx, cout: CohortOut, mixed, hot, nhot) -> SyncOut:
    """Gossip's commit: every learner adopts its mixing-row combination;
    transfers occupy BOTH endpoints' links; the reference never moves
    (there is no coordinator to hold one)."""
    A = cout.aux["A"]
    edges = jnp.sum(A).astype(jnp.int32)           # directed count = 2E
    up = edges // 2
    na = jnp.sum(cout.mask).astype(jnp.int32)
    rec = CommRecord(
        model_up=up, model_down=edges - up,         # == up by symmetry
        messages=jnp.int32(0),
        syncs=(edges > 0).astype(jnp.int32),
        # "all reachable averaged": the active subgraph is complete, so
        # one mixing step couples every reachable learner
        full_syncs=((edges > 0) & (edges == na * (na - 1)))
        .astype(jnp.int32))
    return SyncOut(mixed, _ref_view(ctx), carried_v(ctx, cout), cout.rng,
                   ctx.state.extra, rec, xfers_neighborhood(A),
                   zeros_i32(ctx.m))
