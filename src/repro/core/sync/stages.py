"""Stage library: the four phases every synchronization operator shares.

A round of any operator factors into

* **trigger**   — should the sync machinery run at all? (cadence ``t % b``,
                  and for sigma_Delta the divergence condition)
* **cohort**    — WHO participates: everyone reachable, a random
                  C-fraction, the balancing augmentation's growing set, or
                  a neighborhood mixing matrix — all availability-masked
* **aggregate** — WHAT they agree on: masked (weighted) mean, or one
                  Metropolis–Hastings mixing step
* **commit**    — APPLY and ACCOUNT: per-learner select, reference /
                  violation-counter updates, CommRecord math, per-link
                  transfer and control-message counts (the bytes ledger's
                  inputs)

The functions here are the single implementation of each concern; the
operator compositions in ``kernel.py`` wire them together. Arithmetic is
kept expression-for-expression identical to the pre-kernel monoliths so
compositions reproduce the PR-2 engine bitwise (pinned by
``tests/golden_pr2_engine.json``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig
from repro.core.divergence import (
    per_learner_sq_distance, tree_mean, tree_weighted_mean,
)


# ---------------------------------------------------------------------------
# shared pytree helpers
# ---------------------------------------------------------------------------

def num_learners(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def zeros_i32(m: int) -> jnp.ndarray:
    return jnp.zeros((m,), jnp.int32)


def tree_select(mask, new, old):
    """Per-learner select: leaf (m, ...) <- new where mask[i] else old."""
    def sel(n, o):
        m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def broadcast_model(model, m: int):
    """Replicate a single-model pytree along a fresh leading learner axis."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape),
                        model)


# ---------------------------------------------------------------------------
# trigger
# ---------------------------------------------------------------------------

def cadence_fire(cfg: ProtocolConfig, t) -> jnp.ndarray:
    """The schedule half of every trigger: sync machinery runs when
    ``t % b == 0``."""
    return (t % cfg.b) == 0


def divergence_trigger(cfg: ProtocolConfig, stacked, ref, reach):
    """sigma_Delta's condition half: which reachable learners violate
    ``||f_i - r||^2 > Delta``. Returns ``(dists, violated, nviol)`` — the
    distances double as the balancing cohort's augmentation priority."""
    dists = per_learner_sq_distance(stacked, ref)
    violated = (dists > cfg.delta) & reach
    return dists, violated, jnp.sum(violated).astype(jnp.int32)


# ---------------------------------------------------------------------------
# cohort
# ---------------------------------------------------------------------------

def cohort_all(m: int, active: Optional[jnp.ndarray]) -> jnp.ndarray:
    """sigma_b's cohort: every reachable learner."""
    return jnp.ones((m,), bool) if active is None else active


def cohort_fraction_ideal(sub, m: int, k: int) -> jnp.ndarray:
    """FedAvg's cohort on an ideal network: a uniform random k-subset."""
    perm = jax.random.permutation(sub, m)
    return jnp.zeros((m,), bool).at[perm[:k]].set(True)


def cohort_fraction_masked(sub, m: int, k: int, active) -> jnp.ndarray:
    """FedAvg's cohort under availability: rank the reachable learners by
    a fresh uniform draw and take the first min(k, |active|) — the same
    C-fraction target, restricted to whoever answered this round."""
    r = jax.random.uniform(sub, (m,))
    ranks = jnp.argsort(jnp.argsort(jnp.where(active, r, -jnp.inf)))
    return (ranks >= m - jnp.minimum(k, jnp.sum(active))) & active


def cohort_balanced(cfg: ProtocolConfig, stacked, ref, violated, rng,
                    weights=None, reach=None):
    """sigma_Delta's cohort: coordinator balancing. Augment the violator
    set B until the partial average re-enters the safe zone
    ``||mean_B - r||^2 <= Delta`` or B covers every REACHABLE learner
    (B = [m] on an ideal network).

    This is the one stage where cohort and aggregate iterate together —
    each augmentation step re-aggregates to test the safe zone — so it
    returns both ``(mask B, mean_B)``. The caller derives poll counts from
    the mask: it is the single source of truth for who the coordinator
    contacted.
    """
    m = num_learners(stacked)
    if reach is None:
        reach = jnp.ones((m,), bool)
    dists = per_learner_sq_distance(stacked, ref)     # (m,) — augment priority

    if cfg.augmentation == "random":
        prio = jax.random.uniform(rng, (m,))
    elif cfg.augmentation == "max_distance":
        prio = dists
    else:  # "all": jump straight to full sync on any violation
        prio = jnp.full((m,), jnp.inf)

    def mean_dist(mask):
        mean = aggregate_mean(stacked, mask, weights)
        d = sum(
            jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
            for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)))
        return mean, d

    if cfg.augmentation == "all":
        mean = aggregate_mean(stacked, reach, weights)
        return reach, mean

    _, d0 = mean_dist(violated)

    def cond(carry):
        mask, d = carry
        return jnp.logical_and(jnp.any(reach & ~mask), d > cfg.delta)

    def body(carry):
        mask, _ = carry
        cand = jnp.where(mask | ~reach, -jnp.inf, prio)
        nxt = jnp.argmax(cand)
        mask = mask.at[nxt].set(True)
        _, d = mean_dist(mask)
        return mask, d

    mask, _ = jax.lax.while_loop(cond, body, (violated, d0))
    mean = aggregate_mean(stacked, mask, weights)
    return mask, mean


def cohort_neighborhood(m: int, active: Optional[jnp.ndarray], adjacency):
    """Gossip's cohort: the availability-masked peer overlay plus its
    Metropolis–Hastings mixing matrix
        W_ij = 1 / (1 + max(deg_i, deg_j))   for active edges i~j
        W_ii = 1 - sum_j W_ij
    which is doubly stochastic for a symmetric adjacency, so the
    configuration mean is preserved. Unreachable (or isolated) learners
    have W row e_i and keep their model bitwise. Returns ``(A, W)``."""
    act = jnp.ones((m,), bool) if active is None else active
    A = (jnp.asarray(adjacency, bool) & act[None, :] & act[:, None]
         & ~jnp.eye(m, dtype=bool))
    deg = jnp.sum(A, axis=1).astype(jnp.float32)
    W = jnp.where(A, 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])),
                  0.0)
    W = W + jnp.diag(1.0 - jnp.sum(W, axis=1))
    return A, W


# ---------------------------------------------------------------------------
# aggregate
# ---------------------------------------------------------------------------

def aggregate_mean(stacked, mask, weights=None):
    """Mean of the masked subset of learners (optionally B^i-weighted).
    An empty mask yields the zero model (``tree_weighted_mean`` guards the
    0/0) — commits keep the previous configuration via their selects."""
    w = mask.astype(jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    return tree_weighted_mean(stacked, w)


def aggregate_mean_ideal(stacked, m: int, weights=None):
    """The ideal-network (no-mask) aggregate: ``tree_mean`` unweighted —
    the exact expression the pre-network engine used, preserved for the
    bitwise regression — or the all-ones weighted mean."""
    if weights is None:
        return tree_mean(stacked)
    return aggregate_mean(stacked, jnp.ones((m,), bool), weights)


def aggregate_mix(stacked, W):
    """One mixing step: every learner's model becomes its W-row combination
    of the neighborhood's models."""
    return jax.tree.map(
        lambda x: jnp.tensordot(W.astype(x.dtype), x, axes=1), stacked)


# ---------------------------------------------------------------------------
# commit
# ---------------------------------------------------------------------------

def commit_select(stacked, mask, mean):
    """Cohort members adopt the aggregate; everyone else keeps their model."""
    m = num_learners(stacked)
    return tree_select(mask, broadcast_model(mean, m), stacked)


def commit_ref_if(moved, mean, ref):
    """Reference update gated on a scalar condition (``periodic``/``fedavg``:
    anyone averaged; ``dynamic``: the sync covered every reachable
    learner)."""
    return jax.tree.map(lambda a, b: jnp.where(moved, a, b), mean, ref)


def xfers_cohort(mask) -> jnp.ndarray:
    """Coordinator-link transfer counts: each cohort member's uplink
    carries its model up and the aggregate back down (2 per member), so
    ``sum(xfers) == model_up + model_down``."""
    return mask.astype(jnp.int32) * 2


def xfers_neighborhood(A) -> jnp.ndarray:
    """Gossip transfer counts: every exchanged model occupies the links of
    BOTH endpoints, so ``sum(xfers) == 2 * (model_up + model_down)``."""
    return (2 * jnp.sum(A, axis=1)).astype(jnp.int32)
