"""Dynamic averaging via ``jax.shard_map`` — manual-collective form.

The GSPMD path (``repro.core.distributed``) expresses the protocol with a
learner-stacked pytree and lets the partitioner derive the collectives.
This module is the explicit dual: the learner axis is a *manual* mesh axis,
every rank holds ITS OWN model replica, and the paper's operations are
spelled as named collectives —

    local condition   ||theta_i - r||^2 > Delta        (rank-local scalar)
    violation vote    jax.lax.pmax(violated, "learner") (1 flag)
    synchronization   jax.lax.pmean(params, "learner")  (the weight average)

matching Algorithm 1's communication structure literally: zero bytes while
all local conditions hold, one all-reduce when any fires (the B = [m]
branch — partial balancing degenerates for pod-scale m, DESIGN.md §2).

Used for cross-validation against the GSPMD path (same numerics) and as
the reference for how the protocol maps onto explicit TPU collectives.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.config import ProtocolConfig, TrainConfig
from repro.optim import make_optimizer


class ShardMapState(NamedTuple):
    params: Any      # leaves (m, ...) — learner-sharded
    opt_state: Any
    ref: Any         # reference model r (replicated)
    step: jnp.ndarray
    syncs: jnp.ndarray


def _sq_dist(a, b):
    return sum(
        jnp.sum(jnp.square(x.astype(jnp.float32) - y.astype(jnp.float32)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def make_shardmap_dynamic_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    proto: ProtocolConfig,
    train: TrainConfig,
    mesh,
    axis: str = "learner",
):
    """Returns step(state, batch) -> (state, metrics).

    ``state.params`` leaves carry a leading m axis sharded over ``axis``;
    inside the shard_map body each rank sees its own (1, ...) slice.
    """
    opt = make_optimizer(train)

    def body(params, opt_state, ref, step, syncs, batch):
        # strip the per-rank leading axis of size 1
        p = jax.tree.map(lambda x: x[0], params)
        o = jax.tree.map(lambda x: x[0], opt_state)
        r = jax.tree.map(lambda x: x[0], ref)
        b = jax.tree.map(lambda x: x[0], batch)

        loss, grads = jax.value_and_grad(loss_fn)(p, b)
        p, o = opt.update(p, grads, o)
        t = step[0] + 1

        def check(args):
            p, r = args
            violated = _sq_dist(p, r) > proto.delta           # rank-local
            any_viol = jax.lax.pmax(
                violated.astype(jnp.int32), axis)             # 1-flag vote

            def sync(p):
                return jax.lax.pmean(p, axis)                 # weight average

            p_new = jax.lax.cond(any_viol > 0, sync, lambda p: p, p)
            r_new = jax.tree.map(
                lambda a, c: jnp.where(any_viol > 0, a, c), p_new, r)
            return p_new, r_new, any_viol

        def skip(args):
            p, r = args
            return p, r, jnp.int32(0)

        p, r, did = jax.lax.cond((t % proto.b) == 0, check, skip, (p, r))
        mean_loss = jax.lax.pmean(loss, axis)
        expand = lambda x: x[None]
        return (jax.tree.map(expand, p), jax.tree.map(expand, o),
                jax.tree.map(expand, r), t[None], (syncs[0] + did)[None],
                mean_loss[None])

    m_spec = P(axis)
    rep = P(axis)  # ref/scalars are carried learner-stacked for simplicity
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(m_spec, m_spec, m_spec, m_spec, m_spec, m_spec),
        out_specs=(m_spec, m_spec, m_spec, m_spec, m_spec, m_spec),
        check_vma=False)

    def step(state: ShardMapState, batch):
        params, opt_state, ref, t, syncs, loss = fn(
            state.params, state.opt_state, state.ref, state.step,
            state.syncs, batch)
        new = ShardMapState(params, opt_state, ref, t, syncs)
        return new, {"loss": jnp.mean(loss), "syncs": syncs}

    return step


def init_shardmap_state(init_fn, key, m: int, train: TrainConfig,
                        proto: ProtocolConfig) -> ShardMapState:
    base = init_fn(key)
    stack = lambda x: jnp.broadcast_to(x[None], (m,) + x.shape)
    params = jax.tree.map(stack, base)
    opt = make_optimizer(train)
    opt_state = jax.vmap(opt.init)(params)
    z = jnp.zeros((m,), jnp.int32)
    return ShardMapState(params, opt_state, jax.tree.map(stack, base), z, z)
