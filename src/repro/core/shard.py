"""The device-sharded fleet plane: ``layout="sharded"`` mesh machinery.

``layout="flat"`` (PR 5) already carries the fleet through every sync
stage as one contiguous ``(m, P)`` matrix — exactly the layout GSPMD
wants. This module supplies the three pieces that turn that plane into a
multi-device backend of the SAME ``ProtocolSpec`` compile:

* ``FleetSharding`` — a 1-D device mesh with a single ``"fleet"`` axis
  (built through ``repro.compat.make_mesh``, never raw jax) plus the
  fleet size it partitions. ``m % n_devices == 0`` is validated at
  construction: every device owns exactly ``m / n_devices`` learner rows.
* placement helpers — ``put_fleet``/``put_sync_state`` give the scan
  carry its ``NamedSharding`` home (learner-stacked leaves split over
  ``"fleet"``, the reference model and scalar counters replicated), and
  ``constrain_fleet`` re-asserts that placement on the jitted round's
  outputs so the carry sharding is a fixpoint (no reshard between
  chunks, no second trace).
* the **active-fleet context** — ``use_fleet``/``constrain_rows``. The
  compiled round function (``core/sync/spec.py``) is cached per spec and
  knows nothing about devices; under ``layout="sharded"`` it calls
  ``constrain_rows`` on the raveled plane, which reads the fleet the
  ENGINE activated around its jit call (trace-time lookup) and inserts a
  ``with_sharding_constraint`` splitting the m axis over ``"fleet"``.
  With no active fleet — ``jax.eval_shape`` in the static contract gate,
  the jaxpr audit, a plain ``apply_staged`` call — it is the identity,
  so the sharded round stays abstractly bit-identical to ``flat``.

The row gate ``X.shape[0] == fleet.m`` keeps the constraint out of the
hierarchy's per-cluster vmap (there the plane's leading dim is the
cluster size k = m/g, and pinning k rows to the fleet axis would be
wrong); per-cluster sync then runs with flat arithmetic while the fleet
carry around it stays device-sharded.

Everything device-visible goes through the fleet's mesh, so the sharded
layout executes per-shard: the per-learner update, ``sqdist_rows``, the
``(m, P)`` commits and the per-link ledger rows are local to each
device's row block, and only the trigger vote (an ``any()`` over (m,)
scalars) and the cohort means (one ``w @ X`` matvec) cross devices.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.compat import make_mesh

FLEET_AXIS = "fleet"


class FleetSharding(NamedTuple):
    """One fleet's device partition: a 1-D ``("fleet",)`` mesh and the
    learner count it splits. Hashable/static — safe to close over in
    jitted code."""
    mesh: jax.sharding.Mesh
    m: int

    @property
    def n_devices(self) -> int:
        return self.mesh.shape[FLEET_AXIS]

    @property
    def rows_per_device(self) -> int:
        return self.m // self.n_devices

    # ---- shardings ---------------------------------------------------
    def row_sharding(self, ndim: int, axis: int = 0) -> NamedSharding:
        """NamedSharding splitting dimension ``axis`` over the fleet."""
        spec = [None] * ndim
        spec[axis] = FLEET_AXIS
        return NamedSharding(self.mesh, PartitionSpec(*spec))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())


def fleet_sharding(m: int, devices: int = 0) -> FleetSharding:
    """Build the fleet's mesh over the first ``devices`` visible devices
    (``0`` = all of them). ``m`` must divide evenly: learner rows never
    straddle devices, so ``m % n_devices == 0`` is required — pad the
    fleet or pick a divisor device count."""
    avail = jax.devices()
    n = len(avail) if devices in (0, None) else int(devices)
    if n < 1 or n > len(avail):
        raise ValueError(
            f"shard_devices={devices} but {len(avail)} device(s) are "
            f"visible — pass 0 (all) or 1..{len(avail)}")
    if m % n != 0:
        raise ValueError(
            f"layout='sharded' needs m % n_devices == 0 so every device "
            f"owns the same number of learner rows; got m={m}, "
            f"n_devices={n} (remainder {m % n}). Pad the fleet or set "
            f"shard_devices to a divisor of m.")
    mesh = make_mesh((n,), (FLEET_AXIS,), devices=avail[:n])
    return FleetSharding(mesh=mesh, m=m)


# ---------------------------------------------------------------------------
# carry placement (host-side device_put; engine init + batch feeding)
# ---------------------------------------------------------------------------

def _fleet_leaf(fleet: FleetSharding, x, axis: int = 0) -> bool:
    """Is this leaf learner-stacked (dimension ``axis`` is the fleet)?"""
    shape = jnp.shape(x)
    return len(shape) > axis and shape[axis] == fleet.m


def put_fleet(fleet: FleetSharding, tree, axis: int = 0):
    """Place a learner-stacked pytree: leaves whose dim ``axis`` is the
    fleet size are split over ``"fleet"``; anything else (a scalar count
    an optimizer forgot to vmap, say) is replicated."""
    def put(x):
        sh = (fleet.row_sharding(jnp.ndim(x), axis)
              if _fleet_leaf(fleet, x, axis) else fleet.replicated())
        return jax.device_put(x, sh)
    return jax.tree.map(put, tree)


def put_replicated(fleet: FleetSharding, tree):
    """Replicate every leaf over the fleet's mesh (the reference model,
    the hierarchy's per-cluster state)."""
    return jax.tree.map(
        lambda x: jax.device_put(x, fleet.replicated()), tree)


def put_sync_state(fleet: FleetSharding, state):
    """Place a flat ``SyncState``: the reference model and the scalar
    counters/rng replicate; trigger-owned extra arrays with a leading
    (m,) axis (staleness ages) live with their learners."""
    return state._replace(
        ref=put_replicated(fleet, state.ref),
        v=jax.device_put(state.v, fleet.replicated()),
        rng=jax.device_put(state.rng, fleet.replicated()),
        step=jax.device_put(state.step, fleet.replicated()),
        extra=put_fleet(fleet, state.extra))


# ---------------------------------------------------------------------------
# in-trace constraints (inside the jitted round/chunk)
# ---------------------------------------------------------------------------

def constrain_fleet(fleet: FleetSharding, tree, axis: int = 0):
    """``with_sharding_constraint`` mirror of :func:`put_fleet`, for the
    jitted round's OUTPUTS: pins the committed carry to the same layout
    the inputs entered with, so chunk-to-chunk carry sharding is a
    fixpoint instead of whatever the partitioner last inferred."""
    def pin(x):
        if not _fleet_leaf(fleet, x, axis):
            return x
        return jax.lax.with_sharding_constraint(
            x, fleet.row_sharding(jnp.ndim(x), axis))
    return jax.tree.map(pin, tree)


# The compiled round (core/sync/spec.py) is cached per ProtocolSpec and
# mesh-agnostic; the engine activates its fleet around the jit call and
# the round picks it up at TRACE time. Thread-local so concurrent engines
# (or a test driving two meshes) cannot see each other's fleet.
_ACTIVE = threading.local()


def current_fleet() -> Optional[FleetSharding]:
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_fleet(fleet: FleetSharding):
    stack = getattr(_ACTIVE, "stack", None)
    if stack is None:
        stack = _ACTIVE.stack = []
    stack.append(fleet)
    try:
        yield fleet
    finally:
        stack.pop()


def constrain_rows(X: jnp.ndarray) -> jnp.ndarray:
    """Split a fleet-plane's rows over the active fleet's devices.

    Identity when no fleet is active (eval_shape in the contract gate,
    the jaxpr audit, plain ``apply_staged``) or when the leading dim is
    not the fleet size (the hierarchy's per-cluster (k, P) plane under
    vmap) — so ``layout="sharded"`` degrades to exactly ``layout="flat"``
    arithmetic everywhere a mesh placement would be meaningless."""
    fleet = current_fleet()
    if fleet is None or X.shape[0] != fleet.m:
        return X
    return jax.lax.with_sharding_constraint(
        X, fleet.row_sharding(X.ndim))
