"""The flat fleet-plane: one contiguous ``(m, P)`` matrix per fleet.

Every sync stage is linear algebra over the fleet's parameter rows —
per-learner distances, masked weighted means, mixing matmuls, per-learner
selects — but the pytree layout forces each of them to re-walk the model
leaf by leaf. A ``FleetAdapter`` derives the ravel/unravel maps ONCE from
the (static) leaf structure and carries the fleet configuration as a
single dense matrix:

    adapter = fleet_adapter(stacked)        # cached on (treedef, shapes)
    X = adapter.ravel(stacked)              # (m, P) plane
    r = adapter.ravel_model(ref)            # (P,) row
    ... dense stage arithmetic ...
    new = adapter.unravel(X_new)            # back to the (m, ...) pytree

The plane dtype is the promotion of the leaf dtypes (at least float32),
so float32/bfloat16/float16 leaves round-trip BITWISE through
``unravel(ravel(x))`` — narrowing back to the leaf dtype after a widening
cast is exact. Non-floating leaves are rejected at adapter construction:
the plane is a parameter space, not a carrier for integer state. The
tree-layout aggregates accumulate in the same promoted dtype
(``repro.core.divergence._acc_dtype``), and the static contract checker
(``repro.analysis.contracts``) pins the two layouts to identical
abstract outputs on a mixed f32+bf16 template.

Offsets and shapes are plain Python/numpy metadata, so ``ravel``/
``unravel`` trace to pure reshape+concatenate (no arithmetic) and work
under ``jit``, ``vmap`` (the hierarchy's per-cluster path) and
``lax.scan`` — an unchanged row survives a ravel/unravel round trip
bit-for-bit, which keeps non-participants bitwise across flat commits.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class FleetAdapter(NamedTuple):
    """Static ravel/unravel maps for one model structure.

    ``shapes`` are the per-leaf TRAILING shapes (the leading learner axis
    is whatever the raveled array carries); ``offsets`` are the column
    starts of each leaf's slab in the plane; ``P`` is the model size."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    P: int
    plane_dtype: Any

    # ---- fleet (m, ...) <-> (m, P) ----------------------------------
    def ravel(self, stacked) -> jnp.ndarray:
        """Stacked (m, ...) pytree -> one (m, P) plane."""
        leaves = self.treedef.flatten_up_to(stacked)
        return jnp.concatenate(
            [x.reshape(x.shape[0], -1).astype(self.plane_dtype)
             for x in leaves], axis=1)

    def unravel(self, X: jnp.ndarray):
        """(m, P) plane -> stacked (m, ...) pytree with the leaf dtypes."""
        m = X.shape[0]
        leaves = [
            X[:, o:o + s].reshape((m,) + shp).astype(dt)
            for o, s, shp, dt in zip(self.offsets, self.sizes,
                                     self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)

    # ---- single model (...) <-> (P,) --------------------------------
    def ravel_model(self, model) -> jnp.ndarray:
        leaves = self.treedef.flatten_up_to(model)
        return jnp.concatenate(
            [x.reshape(-1).astype(self.plane_dtype) for x in leaves])

    def unravel_model(self, x: jnp.ndarray):
        leaves = [
            x[o:o + s].reshape(shp).astype(dt)
            for o, s, shp, dt in zip(self.offsets, self.sizes,
                                     self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)


_ADAPTERS: dict = {}


def fleet_adapter(stacked) -> FleetAdapter:
    """The (cached) adapter for a stacked (m, ...) model configuration.

    The cache key is the static structure — treedef + per-leaf trailing
    shape/dtype — so every round of every protocol shares one adapter and
    the offset table is computed exactly once per model architecture."""
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        raise ValueError("cannot build a FleetAdapter for an empty pytree")
    shapes = tuple(tuple(x.shape[1:]) for x in leaves)
    dtypes = tuple(jnp.dtype(x.dtype) for x in leaves)
    key = (treedef, shapes, dtypes)
    hit = _ADAPTERS.get(key)
    if hit is not None:
        return hit
    for shp, dt in zip(shapes, dtypes):
        if not jnp.issubdtype(dt, jnp.floating):
            raise TypeError(
                f"the flat fleet-plane carries floating-point parameters "
                f"only; got a leaf with dtype {dt} (shape {shp})")
    plane = jnp.dtype(jnp.float32)
    for dt in dtypes:
        plane = jnp.promote_types(plane, dt)
    sizes = tuple(int(math.prod(shp)) for shp in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    adapter = FleetAdapter(
        treedef=treedef, shapes=shapes, dtypes=dtypes,
        offsets=tuple(offsets), sizes=sizes, P=off, plane_dtype=plane)
    _ADAPTERS[key] = adapter
    return adapter
