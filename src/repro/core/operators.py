"""Synchronization operators sigma — compatibility shim.

The monolithic operators moved into the staged sync kernel
(``repro.core.sync``): every operator is now a composition of
trigger → cohort → aggregate → commit stages (see
``repro.core.sync.stages`` for the stage library and
``repro.core.sync.kernel`` for the compositions). This module keeps the
historical import surface — ``from repro.core import operators as ops`` —
pointing at the kernel; numerics are bitwise-identical to the pre-kernel
monoliths (pinned by ``tests/golden_pr2_engine.json``).

Contracts (unchanged):
  * ``apply_operator`` returns ``(new_config, new_state, CommRecord,
    xfers)`` where ``xfers`` is the (m,) int32 count of models crossing
    each learner's link this round.
  * Coordinator operators: ``sum(xfers) == model_up + model_down``;
    gossip transfers occupy BOTH endpoints' links:
    ``sum(xfers) == 2 * (model_up + model_down)``.
  * ``active=None`` is the ideal always-on network and preserves the
    pre-network engine's numerics bitwise.
"""
from repro.core.sync.kernel import (  # noqa: F401
    OPERATORS, CommRecord, StageResult, SyncState, apply_operator,
    apply_staged, dynamic, fedavg, gossip, init_state, nosync, periodic,
)
