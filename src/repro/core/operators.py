"""Synchronization operators sigma (paper Sections 3-4), jit-compatible.

Every operator acts on a *model configuration*: a pytree whose leaves have a
leading learner axis ``m``. Operators return
    (new_config, new_state, CommRecord-pytree)
where the state carries the reference model ``r``, the violation counter
``v`` and an rng key, and the comm record counts *model transfers* and
*scalar messages* as exact integers (bytes = transfers * model_bytes +
messages * msg_bytes, done in reporting — keeps jit-friendly int32 math).

Implemented operators:
  * ``nosync``      — identity
  * ``periodic_b``  — sigma_b: full average every b rounds (b=1: continuous)
  * ``fedavg``      — sigma_b over a random C-fraction subset (McMahan et al.)
  * ``dynamic``     — sigma_Delta: local conditions + coordinator balancing
                      (Algorithm 1), optionally weighted (Algorithm 2)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig
from repro.core.divergence import (
    per_learner_sq_distance, tree_mean, tree_weighted_mean,
)


class SyncState(NamedTuple):
    ref: object          # reference model r (single-model pytree)
    v: jnp.ndarray       # violation counter (scalar int32)
    rng: jnp.ndarray     # PRNG key for subsampling / random augmentation
    step: jnp.ndarray    # round counter t (scalar int32)


class CommRecord(NamedTuple):
    model_up: jnp.ndarray     # models sent learner -> coordinator
    model_down: jnp.ndarray   # models sent coordinator -> learner
    messages: jnp.ndarray     # small control messages (violations, polls)
    syncs: jnp.ndarray        # 1 if any averaging happened this round
    full_syncs: jnp.ndarray   # 1 if ALL learners were averaged

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.int32)
        return CommRecord(z, z, z, z, z)


def init_state(ref_model, seed: int = 0) -> SyncState:
    return SyncState(
        ref=ref_model,
        v=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(seed),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _tree_select(mask, new, old):
    """Per-learner select: leaf (m, ...) <- new where mask[i] else old."""
    def sel(n, o):
        m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def _broadcast_model(model, m: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), model)


def _masked_mean(stacked, mask, weights=None):
    """Mean of the masked subset of learners (optionally B^i-weighted)."""
    w = mask.astype(jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    return tree_weighted_mean(stacked, w)


def _num_learners(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


# ---------------------------------------------------------------------------
# trivial operators
# ---------------------------------------------------------------------------

def nosync(cfg: ProtocolConfig, stacked, state: SyncState):
    return stacked, state._replace(step=state.step + 1), CommRecord.zero()


def periodic(cfg: ProtocolConfig, stacked, state: SyncState, weights=None):
    """sigma_b: replace every model by the global mean every b rounds."""
    m = _num_learners(stacked)
    t = state.step + 1

    def sync(_):
        mean = (_masked_mean(stacked, jnp.ones((m,), bool), weights)
                if weights is not None else tree_mean(stacked))
        newcfg = _broadcast_model(mean, m)
        rec = CommRecord(
            model_up=jnp.int32(m), model_down=jnp.int32(m),
            messages=jnp.int32(0), syncs=jnp.int32(1), full_syncs=jnp.int32(1))
        return newcfg, mean, rec

    def skip(_):
        return stacked, state.ref, CommRecord.zero()

    do = (t % cfg.b) == 0
    newcfg, ref, rec = jax.lax.cond(do, sync, skip, None)
    return newcfg, state._replace(ref=ref, step=t), rec


def fedavg(cfg: ProtocolConfig, stacked, state: SyncState, weights=None):
    """sigma_b on a random subset of ceil(C*m) learners (McMahan et al. '17)."""
    m = _num_learners(stacked)
    t = state.step + 1
    k = max(1, int(round(cfg.fedavg_c * m)))

    def sync(rng):
        rng, sub = jax.random.split(rng)
        perm = jax.random.permutation(sub, m)
        mask = jnp.zeros((m,), bool).at[perm[:k]].set(True)
        mean = _masked_mean(stacked, mask, weights)
        newcfg = _tree_select(mask, _broadcast_model(mean, m), stacked)
        rec = CommRecord(
            model_up=jnp.int32(k), model_down=jnp.int32(k),
            messages=jnp.int32(0), syncs=jnp.int32(1),
            full_syncs=jnp.int32(1 if k == m else 0))
        return newcfg, mean, rec, rng

    def skip(rng):
        return stacked, state.ref, CommRecord.zero(), rng

    do = (t % cfg.b) == 0
    newcfg, ref, rec, rng = jax.lax.cond(do, sync, skip, state.rng)
    return newcfg, state._replace(ref=ref, rng=rng, step=t), rec


# ---------------------------------------------------------------------------
# dynamic averaging (Algorithm 1 / Algorithm 2)
# ---------------------------------------------------------------------------

def _balance(cfg: ProtocolConfig, stacked, ref, violated, rng, weights=None):
    """Coordinator balancing: augment the violator set B until the partial
    average re-enters the safe zone ||mean_B - r||^2 <= Delta or B = [m].

    Returns (final mask B, mean_B). The caller derives poll counts from
    the mask (|B| minus the true violators) — the mask is the single
    source of truth for who the coordinator contacted.
    """
    m = _num_learners(stacked)
    dists = per_learner_sq_distance(stacked, ref)     # (m,) — augment priority

    if cfg.augmentation == "random":
        prio = jax.random.uniform(rng, (m,))
    elif cfg.augmentation == "max_distance":
        prio = dists
    else:  # "all": jump straight to full sync on any violation
        prio = jnp.full((m,), jnp.inf)

    def mean_dist(mask):
        mean = _masked_mean(stacked, mask, weights)
        d = sum(
            jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
            for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)))
        return mean, d

    if cfg.augmentation == "all":
        full = jnp.ones((m,), bool)
        mean, _ = mean_dist(full)
        return full, mean

    _, d0 = mean_dist(violated)

    def cond(carry):
        mask, d = carry
        return jnp.logical_and(~jnp.all(mask), d > cfg.delta)

    def body(carry):
        mask, _ = carry
        cand = jnp.where(mask, -jnp.inf, prio)
        nxt = jnp.argmax(cand)
        mask = mask.at[nxt].set(True)
        _, d = mean_dist(mask)
        return mask, d

    mask, _ = jax.lax.while_loop(cond, body, (violated, d0))
    mean = _masked_mean(stacked, mask, weights)
    return mask, mean


def dynamic(cfg: ProtocolConfig, stacked, state: SyncState, weights=None):
    """sigma_Delta with local conditions and balancing (Algorithm 1; with
    ``weights`` = B^i it is Algorithm 2 for unbalanced sampling rates)."""
    m = _num_learners(stacked)
    t = state.step + 1

    def check(args):
        stacked, state = args
        dists = per_learner_sq_distance(stacked, state.ref)
        violated = dists > cfg.delta
        nviol = jnp.sum(violated).astype(jnp.int32)

        def no_violation(rng):
            return (stacked, state.ref, state.v,
                    CommRecord(jnp.int32(0), jnp.int32(0), jnp.int32(0),
                               jnp.int32(0), jnp.int32(0)), rng)

        def violation(rng):
            rng, sub = jax.random.split(rng)
            v_new = state.v + nviol
            # if the counter reaches m, force a full sync and reset it
            force_full = v_new >= m
            base = jnp.where(force_full, jnp.ones((m,), bool), violated)
            v_reset = jnp.where(force_full, jnp.int32(0), v_new)
            mask, mean = _balance(cfg, stacked, state.ref, base, sub, weights)
            full = jnp.all(mask)
            v_final = jnp.where(full, jnp.int32(0), v_reset)
            newcfg = _tree_select(mask, _broadcast_model(mean, m), stacked)
            # reference model updates only on full sync (Algorithm 1)
            new_ref = jax.tree.map(
                lambda a, b: jnp.where(full, a, b), mean, state.ref)
            nsync = jnp.sum(mask).astype(jnp.int32)
            # every member of the final B that did not itself violate was
            # polled by the coordinator — counting nsync - nviol covers the
            # balancing loop AND the forced-full path (where _balance sees
            # an all-true mask and its internal poll counter stays 0)
            polls = nsync - nviol
            rec = CommRecord(
                model_up=nsync,          # violators push + coordinator polls
                model_down=nsync,        # partial average pushed back to B
                messages=nviol + polls,  # violation notices + poll requests
                syncs=jnp.int32(1),
                full_syncs=full.astype(jnp.int32))
            return (newcfg, new_ref, v_final, rec, rng)

        newcfg, ref, v, rec, rng = jax.lax.cond(
            nviol > 0, violation, no_violation, state.rng)
        return newcfg, state._replace(ref=ref, v=v, rng=rng, step=t), rec

    def skip(args):
        stacked, state = args
        return stacked, state._replace(step=t), CommRecord.zero()

    do = (t % cfg.b) == 0
    return jax.lax.cond(do, check, skip, (stacked, state))


OPERATORS = {
    "nosync": nosync,
    "periodic": periodic,
    "continuous": periodic,     # cfg.b == 1
    "fedavg": fedavg,
    "dynamic": dynamic,
}


def apply_operator(cfg: ProtocolConfig, stacked, state: SyncState, weights=None):
    op = OPERATORS[cfg.kind]
    if cfg.kind == "nosync":
        return op(cfg, stacked, state)
    if not cfg.weighted:
        weights = None
    return op(cfg, stacked, state, weights)
