"""Synchronization operators sigma (paper Sections 3-4), jit-compatible.

Every operator acts on a *model configuration*: a pytree whose leaves have a
leading learner axis ``m``. Operators return
    (new_config, new_state, CommRecord-pytree, xfers)
where the state carries the reference model ``r``, the violation counter
``v`` and an rng key, the comm record counts *model transfers* and
*scalar messages* as exact integers (bytes = transfers * model_bytes +
messages * msg_bytes, done in reporting — keeps jit-friendly int32 math),
and ``xfers`` is the (m,) int32 count of models crossing each learner's
link this round (the input of the per-link cost model,
``repro.network.cost``). For the coordinator operators
``sum(xfers) == model_up + model_down``; for gossip every transfer
occupies the links of BOTH endpoints, so ``sum(xfers) == 2 * (model_up +
model_down)``.

Implemented operators:
  * ``nosync``      — identity
  * ``periodic_b``  — sigma_b: full average every b rounds (b=1: continuous)
  * ``fedavg``      — sigma_b over a random C-fraction subset (McMahan et al.)
  * ``dynamic``     — sigma_Delta: local conditions + coordinator balancing
                      (Algorithm 1), optionally weighted (Algorithm 2)
  * ``gossip``      — coordinator-free neighborhood averaging over the
                      network topology (Metropolis–Hastings mixing)

Availability (``active``: optional (m,) bool mask from
``repro.network.availability``): unavailable learners keep training locally
but cannot communicate — they neither violate, nor get polled, nor receive
the average, and ``dynamic``'s balancing loop augments only over reachable
learners. ``active=None`` is the ideal always-on network and preserves the
pre-network engine's numerics bitwise.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ProtocolConfig
from repro.core.divergence import (
    per_learner_sq_distance, tree_mean, tree_weighted_mean,
)


class SyncState(NamedTuple):
    ref: object          # reference model r (single-model pytree)
    v: jnp.ndarray       # violation counter (scalar int32)
    rng: jnp.ndarray     # PRNG key for subsampling / random augmentation
    step: jnp.ndarray    # round counter t (scalar int32)


class CommRecord(NamedTuple):
    model_up: jnp.ndarray     # models sent learner -> coordinator
    model_down: jnp.ndarray   # models sent coordinator -> learner
    messages: jnp.ndarray     # small control messages (violations, polls)
    syncs: jnp.ndarray        # 1 if any averaging happened this round
    full_syncs: jnp.ndarray   # 1 if ALL (reachable) learners were averaged

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.int32)
        return CommRecord(z, z, z, z, z)


def init_state(ref_model, seed: int = 0) -> SyncState:
    return SyncState(
        ref=ref_model,
        v=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(seed),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _tree_select(mask, new, old):
    """Per-learner select: leaf (m, ...) <- new where mask[i] else old."""
    def sel(n, o):
        m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def _broadcast_model(model, m: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), model)


def _masked_mean(stacked, mask, weights=None):
    """Mean of the masked subset of learners (optionally B^i-weighted).
    An empty mask yields the zero model (``tree_weighted_mean`` guards the
    0/0) — callers keep the previous configuration via their selects."""
    w = mask.astype(jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    return tree_weighted_mean(stacked, w)


def _num_learners(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def _no_xfers(m: int) -> jnp.ndarray:
    return jnp.zeros((m,), jnp.int32)


# ---------------------------------------------------------------------------
# trivial operators
# ---------------------------------------------------------------------------

def nosync(cfg: ProtocolConfig, stacked, state: SyncState):
    m = _num_learners(stacked)
    return (stacked, state._replace(step=state.step + 1), CommRecord.zero(),
            _no_xfers(m))


def periodic(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
             active=None):
    """sigma_b: replace every reachable model by their mean every b rounds."""
    m = _num_learners(stacked)
    t = state.step + 1

    def sync(_):
        if active is None:
            mean = (_masked_mean(stacked, jnp.ones((m,), bool), weights)
                    if weights is not None else tree_mean(stacked))
            newcfg = _broadcast_model(mean, m)
            rec = CommRecord(
                model_up=jnp.int32(m), model_down=jnp.int32(m),
                messages=jnp.int32(0), syncs=jnp.int32(1),
                full_syncs=jnp.int32(1))
            return newcfg, mean, rec, jnp.full((m,), 2, jnp.int32)
        nsync = jnp.sum(active).astype(jnp.int32)
        mean = _masked_mean(stacked, active, weights)
        newcfg = _tree_select(active, _broadcast_model(mean, m), stacked)
        # the reference only moves when somebody was actually averaged
        new_ref = jax.tree.map(
            lambda a, b: jnp.where(nsync > 0, a, b), mean, state.ref)
        rec = CommRecord(
            model_up=nsync, model_down=nsync, messages=jnp.int32(0),
            syncs=(nsync > 0).astype(jnp.int32),
            # sigma_b always averages every reachable learner
            full_syncs=(nsync > 0).astype(jnp.int32))
        return newcfg, new_ref, rec, active.astype(jnp.int32) * 2

    def skip(_):
        return stacked, state.ref, CommRecord.zero(), _no_xfers(m)

    do = (t % cfg.b) == 0
    newcfg, ref, rec, xfers = jax.lax.cond(do, sync, skip, None)
    return newcfg, state._replace(ref=ref, step=t), rec, xfers


def fedavg(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
           active=None):
    """sigma_b on a random subset of ceil(C*m) learners (McMahan et al. '17).
    Under availability masks the subset is drawn from the REACHABLE
    learners only (partial client participation)."""
    m = _num_learners(stacked)
    t = state.step + 1
    k = max(1, int(round(cfg.fedavg_c * m)))

    def sync(rng):
        rng, sub = jax.random.split(rng)
        if active is None:
            perm = jax.random.permutation(sub, m)
            mask = jnp.zeros((m,), bool).at[perm[:k]].set(True)
            mean = _masked_mean(stacked, mask, weights)
            newcfg = _tree_select(mask, _broadcast_model(mean, m), stacked)
            rec = CommRecord(
                model_up=jnp.int32(k), model_down=jnp.int32(k),
                messages=jnp.int32(0), syncs=jnp.int32(1),
                full_syncs=jnp.int32(1 if k == m else 0))
            return newcfg, mean, rec, rng, mask.astype(jnp.int32) * 2
        # rank the reachable learners by a fresh uniform draw and take the
        # first min(k, |active|) — the same C-fraction target, restricted
        # to whoever answered this round
        r = jax.random.uniform(sub, (m,))
        ranks = jnp.argsort(jnp.argsort(jnp.where(active, r, -jnp.inf)))
        mask = (ranks >= m - jnp.minimum(k, jnp.sum(active))) & active
        nsel = jnp.sum(mask).astype(jnp.int32)
        mean = _masked_mean(stacked, mask, weights)
        newcfg = _tree_select(mask, _broadcast_model(mean, m), stacked)
        new_ref = jax.tree.map(
            lambda a, b: jnp.where(nsel > 0, a, b), mean, state.ref)
        rec = CommRecord(
            model_up=nsel, model_down=nsel, messages=jnp.int32(0),
            syncs=(nsel > 0).astype(jnp.int32),
            # full = the subset covered every reachable learner
            full_syncs=((nsel > 0) & (nsel == jnp.sum(active)))
            .astype(jnp.int32))
        return newcfg, new_ref, rec, rng, mask.astype(jnp.int32) * 2

    def skip(rng):
        return stacked, state.ref, CommRecord.zero(), rng, _no_xfers(m)

    do = (t % cfg.b) == 0
    newcfg, ref, rec, rng, xfers = jax.lax.cond(do, sync, skip, state.rng)
    return newcfg, state._replace(ref=ref, rng=rng, step=t), rec, xfers


# ---------------------------------------------------------------------------
# dynamic averaging (Algorithm 1 / Algorithm 2)
# ---------------------------------------------------------------------------

def _balance(cfg: ProtocolConfig, stacked, ref, violated, rng, weights=None,
             reach=None):
    """Coordinator balancing: augment the violator set B until the partial
    average re-enters the safe zone ||mean_B - r||^2 <= Delta or B covers
    every REACHABLE learner (B = [m] on an ideal network).

    Returns (final mask B, mean_B). The caller derives poll counts from
    the mask (|B| minus the true violators) — the mask is the single
    source of truth for who the coordinator contacted.
    """
    m = _num_learners(stacked)
    if reach is None:
        reach = jnp.ones((m,), bool)
    dists = per_learner_sq_distance(stacked, ref)     # (m,) — augment priority

    if cfg.augmentation == "random":
        prio = jax.random.uniform(rng, (m,))
    elif cfg.augmentation == "max_distance":
        prio = dists
    else:  # "all": jump straight to full sync on any violation
        prio = jnp.full((m,), jnp.inf)

    def mean_dist(mask):
        mean = _masked_mean(stacked, mask, weights)
        d = sum(
            jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
            for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)))
        return mean, d

    if cfg.augmentation == "all":
        mean = _masked_mean(stacked, reach, weights)
        return reach, mean

    _, d0 = mean_dist(violated)

    def cond(carry):
        mask, d = carry
        return jnp.logical_and(jnp.any(reach & ~mask), d > cfg.delta)

    def body(carry):
        mask, _ = carry
        cand = jnp.where(mask | ~reach, -jnp.inf, prio)
        nxt = jnp.argmax(cand)
        mask = mask.at[nxt].set(True)
        _, d = mean_dist(mask)
        return mask, d

    mask, _ = jax.lax.while_loop(cond, body, (violated, d0))
    mean = _masked_mean(stacked, mask, weights)
    return mask, mean


def dynamic(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
            active=None):
    """sigma_Delta with local conditions and balancing (Algorithm 1; with
    ``weights`` = B^i it is Algorithm 2 for unbalanced sampling rates).
    With an ``active`` mask only reachable learners violate, get polled,
    or receive averages; a "full" sync (reference reset, counter reset)
    is one that covers every reachable learner."""
    m = _num_learners(stacked)
    t = state.step + 1
    reach = jnp.ones((m,), bool) if active is None else active

    def check(args):
        stacked, state = args
        dists = per_learner_sq_distance(stacked, state.ref)
        violated = (dists > cfg.delta) & reach
        nviol = jnp.sum(violated).astype(jnp.int32)

        def no_violation(rng):
            return (stacked, state.ref, state.v,
                    CommRecord(jnp.int32(0), jnp.int32(0), jnp.int32(0),
                               jnp.int32(0), jnp.int32(0)), rng,
                    _no_xfers(m))

        def violation(rng):
            rng, sub = jax.random.split(rng)
            v_new = state.v + nviol
            # if the counter reaches m, force a sync of every reachable
            # learner and reset it
            force_full = v_new >= m
            base = jnp.where(force_full, reach, violated)
            v_reset = jnp.where(force_full, jnp.int32(0), v_new)
            mask, mean = _balance(cfg, stacked, state.ref, base, sub,
                                  weights, reach)
            full = jnp.all(mask == reach)
            v_final = jnp.where(full, jnp.int32(0), v_reset)
            newcfg = _tree_select(mask, _broadcast_model(mean, m), stacked)
            # reference model updates only on full sync (Algorithm 1)
            new_ref = jax.tree.map(
                lambda a, b: jnp.where(full, a, b), mean, state.ref)
            nsync = jnp.sum(mask).astype(jnp.int32)
            # every member of the final B that did not itself violate was
            # polled by the coordinator — counting nsync - nviol covers the
            # balancing loop AND the forced-full path (where _balance sees
            # an all-true mask and its internal poll counter stays 0)
            polls = nsync - nviol
            rec = CommRecord(
                model_up=nsync,          # violators push + coordinator polls
                model_down=nsync,        # partial average pushed back to B
                messages=nviol + polls,  # violation notices + poll requests
                syncs=jnp.int32(1),
                full_syncs=full.astype(jnp.int32))
            return (newcfg, new_ref, v_final, rec, rng,
                    mask.astype(jnp.int32) * 2)

        newcfg, ref, v, rec, rng, xfers = jax.lax.cond(
            nviol > 0, violation, no_violation, state.rng)
        return (newcfg, state._replace(ref=ref, v=v, rng=rng, step=t), rec,
                xfers)

    def skip(args):
        stacked, state = args
        return stacked, state._replace(step=t), CommRecord.zero(), _no_xfers(m)

    do = (t % cfg.b) == 0
    return jax.lax.cond(do, check, skip, (stacked, state))


# ---------------------------------------------------------------------------
# gossip (coordinator-free baseline)
# ---------------------------------------------------------------------------

def gossip(cfg: ProtocolConfig, stacked, state: SyncState, weights=None,
           active=None, adjacency=None):
    """Neighborhood averaging over the network topology, no coordinator.

    Every b rounds each reachable learner exchanges models with its
    reachable neighbors and applies one Metropolis–Hastings mixing step
        W_ij = 1 / (1 + max(deg_i, deg_j))   for active edges i~j
        W_ii = 1 - sum_j W_ij
    which is doubly stochastic for a symmetric adjacency, so the
    configuration mean is preserved. Unreachable (or isolated) learners
    have W row e_i and keep their model bitwise. ``weights`` (Algorithm 2
    sample weights) are ignored — there is no coordinator to reweight the
    average; use a coordinator operator for unbalanced fleets.
    """
    m = _num_learners(stacked)
    t = state.step + 1
    if adjacency is None:
        raise ValueError(
            "gossip needs an adjacency matrix — configure a NetworkConfig "
            "topology (the engine passes it through)")
    act = jnp.ones((m,), bool) if active is None else active
    A = (jnp.asarray(adjacency, bool) & act[None, :] & act[:, None]
         & ~jnp.eye(m, dtype=bool))
    deg = jnp.sum(A, axis=1).astype(jnp.float32)
    W = jnp.where(A, 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])),
                  0.0)
    W = W + jnp.diag(1.0 - jnp.sum(W, axis=1))

    def sync(_):
        mixed = jax.tree.map(
            lambda x: jnp.tensordot(W.astype(x.dtype), x, axes=1), stacked)
        edges = jnp.sum(A).astype(jnp.int32)           # directed count = 2E
        up = edges // 2
        na = jnp.sum(act).astype(jnp.int32)
        rec = CommRecord(
            model_up=up, model_down=edges - up,         # == up by symmetry
            messages=jnp.int32(0),
            syncs=(edges > 0).astype(jnp.int32),
            # "all reachable averaged": the active subgraph is complete, so
            # one mixing step couples every reachable learner
            full_syncs=((edges > 0) & (edges == na * (na - 1)))
            .astype(jnp.int32))
        return mixed, rec, (2 * jnp.sum(A, axis=1)).astype(jnp.int32)

    def skip(_):
        return stacked, CommRecord.zero(), _no_xfers(m)

    do = (t % cfg.b) == 0
    newcfg, rec, xfers = jax.lax.cond(do, sync, skip, None)
    return newcfg, state._replace(step=t), rec, xfers


OPERATORS = {
    "nosync": nosync,
    "periodic": periodic,
    "continuous": periodic,     # cfg.b == 1
    "fedavg": fedavg,
    "dynamic": dynamic,
    "gossip": gossip,
}


def apply_operator(cfg: ProtocolConfig, stacked, state: SyncState,
                   weights=None, active=None, adjacency=None):
    """Dispatch to the configured operator.

    ``active``: optional (m,) bool reachability mask for this round;
    ``adjacency``: optional (m, m) bool peer overlay (required by gossip).
    Returns ``(new_config, new_state, CommRecord, xfers)``.
    """
    op = OPERATORS[cfg.kind]
    if cfg.kind == "nosync":
        return op(cfg, stacked, state)
    if not cfg.weighted:
        weights = None
    if cfg.kind == "gossip":
        return op(cfg, stacked, state, weights, active=active,
                  adjacency=adjacency)
    return op(cfg, stacked, state, weights, active=active)
