"""Synchronization operators sigma — compatibility shim.

The monolithic operators became declarative stage compositions
(``repro.core.sync``): the ``PROTOCOLS`` preset registry holds each kind
as a ``ProtocolSpec`` over the registered stage library (see
``repro.core.sync.registry`` for the registries, ``spec.py`` for the spec
API and ``kernel.py`` for the presets). This module keeps the historical
import surface — ``from repro.core import operators as ops`` — pointing
at the kernel; numerics are bitwise-identical to the pre-kernel
monoliths (pinned by ``tests/golden_pr2_engine.json``).

Contracts (unchanged):
  * ``apply_operator`` returns ``(new_config, new_state, CommRecord,
    xfers)`` where ``xfers`` is the (m,) int32 count of models crossing
    each learner's link this round.
  * Coordinator operators: ``sum(xfers) == model_up + model_down``;
    gossip transfers occupy BOTH endpoints' links:
    ``sum(xfers) == 2 * (model_up + model_down)``.
  * ``active=None`` is the ideal always-on network and preserves the
    pre-network engine's numerics bitwise.
"""
from repro.core.sync.kernel import (  # noqa: F401
    OPERATORS, PROTOCOLS, CommRecord, StageResult, SyncState,
    apply_operator, apply_staged, dynamic, fedavg, gossip, init_state,
    nosync, periodic, register_protocol,
)
from repro.core.sync.spec import ProtocolSpec, resolve_spec  # noqa: F401
