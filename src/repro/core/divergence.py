"""Model divergence and local conditions (paper Eq. 2 and Section 3).

All functions treat models as pytrees. A *model configuration* is a pytree
whose leaves carry a leading learner axis ``m`` (the vmap layout used by the
simulator): leaf shape ``(m, ...)``.

The divergence of a configuration is
    delta(f) = 1/m sum_i || f_i - mean(f) ||^2
and the local condition of learner i w.r.t. reference model r is
    || f_i - r ||^2 <= Delta.

``sq_distance`` optionally routes through the fused Pallas kernel
(`repro.kernels.ops.sqdist`) — the protocol's monitoring hot-spot.
``per_learner_sq_distance_flat`` is the batched dual over the flat
fleet-plane (``repro.core.flatten``): one ``(m, P) x (P,)`` pass, routed
through the row-tiled Pallas kernel on TPU backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flat_size(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def _acc_dtype(dt):
    """Accumulation dtype for reductions over a leaf: at least float32.

    Sub-f32 leaves (bfloat16/float16) accumulate in float32 and narrow
    back, matching the flat fleet-plane (whose dtype is the promotion of
    the leaf dtypes, at least f32) instead of summing m terms in an
    8-bit-mantissa format. For float32 leaves every cast below is a
    no-op, so the pre-fix expressions — and the PR-2 goldens — are
    reproduced bitwise."""
    return jnp.promote_types(dt, jnp.float32)


def tree_mean(stacked):
    """Mean over the leading learner axis of every leaf (accumulated in
    ``_acc_dtype``, returned in the leaf dtype)."""
    def mean1(x):
        return jnp.mean(x, axis=0, dtype=_acc_dtype(x.dtype)).astype(x.dtype)
    return jax.tree.map(mean1, stacked)


def tree_weighted_mean(stacked, weights):
    """Weighted mean over the learner axis (Algorithm 2). weights: (m,).

    An all-zero weight vector (an empty active set under availability
    masking) yields the zero model instead of 0/0 = NaN — the operators'
    selection masks then keep the previous configuration unchanged, so no
    NaN ever reaches the scan carry.

    Weighting happens in ``_acc_dtype`` (at least float32) and narrows
    back to the leaf dtype: the B^i weights are never downcast to a
    sub-f32 leaf dtype, and the sum over m learners never accumulates in
    bfloat16 — the dtype-promotion contract the static contract checker
    (``repro.analysis.contracts``) verifies against the flat layout.
    """
    wsum = jnp.sum(weights)
    denom = jnp.where(wsum > 0, wsum, jnp.ones_like(wsum))

    def wmean(x):
        acc = _acc_dtype(x.dtype)
        w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(acc)
        return (jnp.sum(x.astype(acc) * w, axis=0)
                / denom.astype(acc)).astype(x.dtype)

    return jax.tree.map(wmean, stacked)


def sq_distance(a, b, use_kernel: bool = False) -> jnp.ndarray:
    """|| a - b ||^2 summed over every leaf of two same-structure pytrees."""
    if use_kernel:
        from repro.kernels import ops as kops
        return sum(
            kops.sqdist(x.reshape(-1), y.reshape(-1))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        )
    return sum(
        jnp.sum(jnp.square(x.astype(jnp.float32) - y.astype(jnp.float32)))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def per_learner_sq_distance_flat(X, r,
                                 use_kernel: Optional[bool] = None
                                 ) -> jnp.ndarray:
    """(m,) squared distances over the FLAT fleet-plane: ``X`` is the
    (m, P) configuration matrix, ``r`` the (P,) reference row.

    This is the protocol's monitoring hot-spot in one batched pass. On a
    TPU backend it runs the row-tiled Pallas kernel
    (``repro.kernels.ops.sqdist_rows``); elsewhere the kernel would
    execute in interpret mode (Python, orders of magnitude slower than
    XLA), so the dense jnp row reduction is used instead.
    ``use_kernel`` forces the choice either way."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.sqdist_rows(X, r)
    d = X.astype(jnp.float32) - r.astype(jnp.float32)[None]
    return jnp.sum(d * d, axis=1)


def per_learner_sq_distance(stacked, ref) -> jnp.ndarray:
    """(m,) squared distances || f_i - r ||^2; leaves of ``stacked`` carry a
    leading m axis, ``ref`` is a single model."""
    def leaf(x, r):
        d = x.astype(jnp.float32) - r.astype(jnp.float32)[None]
        return jnp.sum(d.reshape(d.shape[0], -1) ** 2, axis=1)
    parts = jax.tree.leaves(jax.tree.map(leaf, stacked, ref))
    return sum(parts)


def divergence(stacked) -> jnp.ndarray:
    """delta(f) = 1/m sum_i || f_i - mean(f) ||^2  (paper Eq. 2)."""
    mean = tree_mean(stacked)
    d = per_learner_sq_distance(stacked, mean)
    return jnp.mean(d)


def local_condition_violated(stacked, ref, delta: float) -> jnp.ndarray:
    """(m,) bool — which learners violate || f_i - r ||^2 > Delta."""
    return per_learner_sq_distance(stacked, ref) > delta
