"""JAX version-portability layer (tested against jax 0.4.37; written for
0.4.x - 0.6.x).

Every API this repo uses whose import path or signature moved between jax
releases is resolved HERE, once, so call sites stay version-agnostic:

=====================  ==========================  =========================
API                    jax 0.4.x                   jax >= 0.5 / 0.6
=====================  ==========================  =========================
shard_map              jax.experimental.shard_map  jax.shard_map
  replication check      ``check_rep=``              ``check_vma=`` (0.6)
AbstractMesh           shape_tuple of              positional
                       (name, size) pairs          (axis_sizes, axis_names)
make_mesh              no ``axis_types``           ``axis_types=`` kwarg
AxisType               absent                      jax.sharding.AxisType
=====================  ==========================  =========================

Import from here, never from jax directly, for any of the above:

    from repro.compat import abstract_mesh, make_mesh, shard_map
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence, Tuple

import jax


def _parse_version(v: str) -> Tuple[int, ...]:
    parts = []
    for p in v.split(".")[:3]:
        digits = "".join(ch for ch in p if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: Tuple[int, ...] = _parse_version(jax.__version__)

# ---------------------------------------------------------------------------
# AxisType (jax >= 0.5): None on older releases. Callers must treat it as
# optional — ``default_axis_types`` below is the portable entry point.
# ---------------------------------------------------------------------------

AxisType = getattr(jax.sharding, "AxisType", None)
HAS_AXIS_TYPE = AxisType is not None


def default_axis_types(n_axes: int) -> Optional[tuple]:
    """(AxisType.Auto,) * n on jax >= 0.5; None (omit the kwarg) before."""
    if AxisType is None:
        return None
    return (AxisType.Auto,) * n_axes


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              axis_types: Any = "auto", devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with the ``axis_types`` kwarg applied only where the
    installed jax supports it (>= 0.5). ``axis_types="auto"`` requests
    Auto-typed axes when available and is silently dropped otherwise —
    exactly the behaviour every pre-AxisType release had implicitly.
    """
    shape, axes = tuple(shape), tuple(axes)
    if not hasattr(jax, "make_mesh"):           # jax < 0.4.35
        import numpy as np
        n = 1
        for s in shape:
            n *= s
        devs = np.asarray(devices if devices is not None
                          else jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devs, axes)
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        if axis_types == "auto":
            axis_types = default_axis_types(len(axes))
        if axis_types is not None:
            kw["axis_types"] = axis_types
    return jax.make_mesh(shape, axes, **kw)


# ---------------------------------------------------------------------------
# AbstractMesh
# ---------------------------------------------------------------------------

def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device-free mesh for sharding-rule evaluation, on every signature:
    jax >= 0.5 takes ``(axis_sizes, axis_names)`` positionally; 0.4.x takes a
    single ``shape_tuple`` of (name, size) pairs.
    """
    shape, axes = tuple(shape), tuple(axes)
    AbstractMesh = jax.sharding.AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


# ---------------------------------------------------------------------------
# cost_analysis
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax.

    jax 0.4.x returns a LIST with one per-program dict; jax >= 0.5 returns
    the dict directly; either may be None for trivial programs.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return ca or {}


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    if hasattr(jax, "shard_map"):               # jax >= 0.6
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as sm  # 0.4.x / 0.5.x
    return sm


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None,
              **kwargs):
    """Portable ``shard_map``.

    ``check_vma`` is the jax >= 0.6 name for what 0.4.x/0.5.x call
    ``check_rep`` (the replication/varying-manual-axes checker); pass the new
    name here and it is translated to whatever the installed jax accepts.
    """
    sm = _resolve_shard_map()
    params = inspect.signature(sm).parameters
    kw = dict(kwargs)
    if check_vma is not None:
        if "check_vma" in params:
            kw["check_vma"] = check_vma
        elif "check_rep" in params:
            kw["check_rep"] = check_vma
        # else: the checker kwarg vanished entirely — nothing to forward.
    if "mesh" in params and params["mesh"].kind is inspect.Parameter.KEYWORD_ONLY:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    return sm(f, mesh, in_specs=in_specs, out_specs=out_specs, **kw)
