"""Host-side tracing & profiling hooks (telemetry layer 2).

Wall-clock here always means ``time.perf_counter`` around a call that
BLOCKS on its (pytree) result — timing async dispatch instead of
execution is the classic JAX benchmarking bug (``jax.block_until_ready``
walks a pytree and ignores non-array leaves, so any result shape works).

``ChunkProfiler`` does the recompile accounting for the scanned engine:
``jit`` retraces per distinct chunk length, so the first observation of
a length is trace+compile+execute and every later one is execute-only —
the profiler keeps both populations per length and counts recompiles.

``profiler_trace``/``step_annotation`` are the optional ``jax.profiler``
integration: a CLI or benchmark wraps a run in ``profiler_trace(dir)``
and every chunk the engine executes shows up as a named step in the
trace viewer (the engine annotates when
``TelemetryConfig.jax_profiler`` is set; annotations are no-ops unless
a trace is active).
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.telemetry.sink import TelemetryLogger, get_logger

__all__ = ["timed", "span", "profiler_trace", "step_annotation",
           "ChunkProfiler"]


def timed(fn: Callable, *args, **kw):
    """``(result, seconds)`` of one call, blocking on the result so the
    wall-clock covers execution, not async dispatch."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


@contextlib.contextmanager
def span(name: str, logger: Optional[TelemetryLogger] = None, **fields):
    """Time a host-side region and emit it as a ``span`` event (silent
    unless the logger has handlers). The body is responsible for blocking
    on device work it wants included — wrap dispatches in ``timed`` or
    ``jax.block_until_ready``."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        (logger or get_logger()).event(
            "span", name=name,
            seconds=time.perf_counter() - t0, **fields)


@contextlib.contextmanager
def profiler_trace(log_dir: Optional[str]):
    """``jax.profiler`` trace over the with-body when ``log_dir`` is set;
    a no-op otherwise — callers thread an optional CLI flag straight
    through."""
    if not log_dir:
        yield
        return
    jax.profiler.start_trace(log_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def step_annotation(name: str, step: int):
    """A ``jax.profiler.StepTraceAnnotation`` context (no-op unless a
    trace is active)."""
    return jax.profiler.StepTraceAnnotation(name, step_num=step)


class ChunkProfiler:
    """Compile-vs-execute accounting per chunk length.

    ``begin(n)`` returns True when length ``n`` will trace+compile (first
    sighting — one recompile); ``observe(n, wall_s)`` files the
    measurement. ``summary()`` is JSON-ready: per-length counts, the
    first (compile-inclusive) wall-clock, and the best execute-only
    wall-clock."""

    def __init__(self):
        self.recompiles = 0
        self._stats: Dict[int, Dict[str, Any]] = {}

    def begin(self, n: int) -> bool:
        first = n not in self._stats
        if first:
            self.recompiles += 1
            self._stats[n] = {"calls": 0, "compile_s": None,
                              "best_exec_s": None, "total_s": 0.0}
        return first

    def observe(self, n: int, wall_s: float) -> None:
        if n not in self._stats:      # begin() not called — count it now
            self.begin(n)
        st = self._stats[n]
        st["calls"] += 1
        st["total_s"] += wall_s
        if st["compile_s"] is None:
            st["compile_s"] = wall_s
        else:
            best = st["best_exec_s"]
            st["best_exec_s"] = (wall_s if best is None
                                 else min(best, wall_s))

    def summary(self) -> Dict[str, Any]:
        return {
            "recompiles": self.recompiles,
            "chunk_lengths": {str(n): dict(st)
                              for n, st in sorted(self._stats.items())},
        }
