"""The telemetry plane's versioned record schema.

A telemetry stream is a sequence of JSON objects (one per JSONL line),
each tagged with a ``kind``:

* ``meta``  — once per stream (plus once per resume): the run's static
  facts — fleet size, model/payload sizes, per-link classes, the
  serialized ``ProtocolSpec`` and tier block. Everything the observatory
  CLI needs to analyze the stream *from the file alone*.
* ``round`` — one per executed round (``RoundRecord``): this round's
  loss / divergence / trigger accounting / cohort size / reachability /
  simulated network time / bytes, plus the exact cumulative counters
  after the round. Cumulative integer fields are exact (int64 host
  arithmetic over the device counters); cumulative floats use the same
  float64 running sums the engine's host counters accumulate, so the
  last record of a run equals ``DecentralizedLearner``'s counters
  bitwise.
* ``chunk`` — one per executed scan chunk: chunk-granularity facts that
  do not exist per round — the cumulative per-link bytes ledger, the
  staleness ages carried in ``SyncState.extra`` (a chunk-end snapshot;
  the scan carry is only fetched once per chunk), and, when profiling is
  enabled, the chunk's wall-clock and whether it compiled.
* ``event`` — free-form structured events from the
  ``repro.telemetry.sink.TelemetryLogger`` (launcher progress, spans).

``SCHEMA_VERSION`` is embedded in every record as ``v``;
``validate_record``/``RoundRecord.from_dict`` REJECT a mismatched
version (a stream written by a future schema must fail loudly, not parse
into garbage).
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Any, Dict, Optional, Tuple

SCHEMA_VERSION = 1

KIND_META = "meta"
KIND_ROUND = "round"
KIND_CHUNK = "chunk"
KIND_EVENT = "event"

KINDS = (KIND_META, KIND_ROUND, KIND_CHUNK, KIND_EVENT)


def _require_version(d: Dict[str, Any], where: str) -> None:
    v = d.get("v")
    if v != SCHEMA_VERSION:
        raise ValueError(
            f"telemetry schema version mismatch in {where} record: "
            f"got v={v!r}, this reader speaks v={SCHEMA_VERSION}")


def _as_int(d: Dict[str, Any], key: str) -> int:
    val = d[key]
    if isinstance(val, bool) or not isinstance(val, int):
        raise ValueError(
            f"round record field {key!r} must be an integer, got {val!r}")
    return val


def _as_float(d: Dict[str, Any], key: str) -> float:
    val = d[key]
    if isinstance(val, bool) or not isinstance(val, (int, float)):
        raise ValueError(
            f"round record field {key!r} must be a number, got {val!r}")
    return float(val)


@dataclass(frozen=True)
class RoundRecord:
    """One executed round of the protocol, host-side.

    Per-round fields are THIS round's values; ``cum_*`` fields are the
    exact cumulative counters after it. ``messages`` is the round's
    control-message count (violation notices + poll requests — the
    trigger-fire signal); ``cohort`` the models sent up (the synchronized
    cohort's size); ``round_bytes``/``cum_bytes`` use the engine's c(f)
    accounting (the per-link ledger sum under a hierarchy). ``link_bytes``
    is the optional per-link byte vector for this round
    (``TelemetryConfig.per_link``); ``uplink_bytes`` the aggregator-uplink
    share under a hierarchy. ``inflight``/``max_age`` are written only by
    state-carrying protocols (async timeline / bounded staleness): the
    number of learners with a message still in flight after the round and
    the oldest rounds-since-sync counter. ``num_faulty`` is written only
    under a ``FaultConfig`` (learners under any injected fault this
    round); ``num_quarantined``/``num_recovered`` only by robust
    protocols carrying health counters (learners currently quarantined /
    recovering this round)."""
    round: int              # 1-based global round index
    loss: float             # fleet loss this round (sum over learners)
    cum_loss: float
    divergence: float       # 0.0 unless the engine tracks divergence
    messages: int           # control messages this round (trigger fires)
    cohort: int             # models sent up this round (cohort size)
    sync: int               # 1 if any averaging happened
    full_sync: int          # 1 if the whole reachable fleet averaged
    cum_syncs: int
    num_active: int         # reachable learners this round
    net_time: float         # simulated network seconds this round
    cum_net_time: float
    round_bytes: int        # bytes moved this round (c(f) accounting)
    cum_bytes: int
    v: int = SCHEMA_VERSION
    link_bytes: Optional[Tuple[int, ...]] = None   # (L,) this round
    uplink_bytes: Optional[int] = None             # hierarchy uplink share
    inflight: Optional[int] = None                 # learners in flight
    max_age: Optional[int] = None                  # oldest sync-age counter
    num_faulty: Optional[int] = None               # learners under a fault
    num_quarantined: Optional[int] = None          # quarantined learners
    num_recovered: Optional[int] = None            # recoveries this round

    _INT_FIELDS = ("round", "messages", "cohort", "sync", "full_sync",
                   "cum_syncs", "num_active", "round_bytes", "cum_bytes")
    _FLOAT_FIELDS = ("loss", "cum_loss", "divergence", "net_time",
                     "cum_net_time")

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict (``kind`` tag included, None fields omitted)."""
        d: Dict[str, Any] = {"kind": KIND_ROUND, "v": self.v}
        for f in self._INT_FIELDS:
            d[f] = int(getattr(self, f))
        for f in self._FLOAT_FIELDS:
            d[f] = float(getattr(self, f))
        if self.link_bytes is not None:
            d["link_bytes"] = [int(x) for x in self.link_bytes]
        if self.uplink_bytes is not None:
            d["uplink_bytes"] = int(self.uplink_bytes)
        if self.inflight is not None:
            d["inflight"] = int(self.inflight)
        if self.max_age is not None:
            d["max_age"] = int(self.max_age)
        for f in ("num_faulty", "num_quarantined", "num_recovered"):
            val = getattr(self, f)
            if val is not None:
                d[f] = int(val)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RoundRecord":
        """Parse + validate one round record; raises ``ValueError`` on a
        schema-version mismatch, a wrong ``kind``, missing fields, or
        mistyped values."""
        if d.get("kind") != KIND_ROUND:
            raise ValueError(
                f"not a round record: kind={d.get('kind')!r}")
        _require_version(d, KIND_ROUND)
        missing = [f for f in cls._INT_FIELDS + cls._FLOAT_FIELDS
                   if f not in d]
        if missing:
            raise ValueError(f"round record missing fields: {missing}")
        kw: Dict[str, Any] = {f: _as_int(d, f) for f in cls._INT_FIELDS}
        kw.update({f: _as_float(d, f) for f in cls._FLOAT_FIELDS})
        if d.get("link_bytes") is not None:
            kw["link_bytes"] = tuple(int(x) for x in d["link_bytes"])
        if d.get("uplink_bytes") is not None:
            kw["uplink_bytes"] = int(d["uplink_bytes"])
        if d.get("inflight") is not None:
            kw["inflight"] = _as_int(d, "inflight")
        if d.get("max_age") is not None:
            kw["max_age"] = _as_int(d, "max_age")
        for f in ("num_faulty", "num_quarantined", "num_recovered"):
            if d.get(f) is not None:
                kw[f] = _as_int(d, f)
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known - {"kind"})
        if unknown:
            raise ValueError(f"round record has unknown fields: {unknown}")
        return cls(**kw)


def meta_record(*, m: int, model_size: int, model_bytes: int,
                msg_bytes: int, num_links: int,
                link_classes: Tuple[str, ...],
                spec: Optional[Dict[str, Any]] = None,
                tiers: Optional[Dict[str, Any]] = None,
                resumed_rounds: int = 0) -> Dict[str, Any]:
    """The stream's static facts — written once at recorder construction
    (and again on a checkpoint resume, with ``resumed_rounds`` set, so a
    resumed stream is self-describing about where it picks up)."""
    if len(link_classes) != num_links:
        raise ValueError(
            f"link_classes must name all {num_links} links, "
            f"got {len(link_classes)}")
    return {
        "kind": KIND_META, "v": SCHEMA_VERSION,
        "m": int(m), "model_size": int(model_size),
        "model_bytes": int(model_bytes), "msg_bytes": int(msg_bytes),
        "num_links": int(num_links), "link_classes": list(link_classes),
        "spec": spec, "tiers": tiers,
        "resumed_rounds": int(resumed_rounds),
    }


def chunk_record(*, chunk: int, rounds_end: int, n: int,
                 link_bytes_cum, stale_age=None,
                 wall_s: Optional[float] = None,
                 compiled: Optional[bool] = None,
                 recompiles: Optional[int] = None) -> Dict[str, Any]:
    """One executed scan chunk: the cumulative per-link ledger at chunk
    end, the chunk-end staleness-age snapshot (``SyncState.extra``), and
    the profiling span when enabled."""
    d: Dict[str, Any] = {
        "kind": KIND_CHUNK, "v": SCHEMA_VERSION,
        "chunk": int(chunk), "rounds_end": int(rounds_end), "n": int(n),
        "link_bytes_cum": [int(x) for x in link_bytes_cum],
    }
    if stale_age is not None:
        d["stale_age"] = stale_age
    if wall_s is not None:
        d["wall_s"] = float(wall_s)
    if compiled is not None:
        d["compiled"] = bool(compiled)
    if recompiles is not None:
        d["recompiles"] = int(recompiles)
    return d


def validate_record(d: Dict[str, Any], line: int = 0) -> Dict[str, Any]:
    """Validate one parsed JSONL object of any kind; round records come
    back as their dict form (round-tripped through ``RoundRecord`` so the
    field types are enforced). Raises ``ValueError`` with the line number
    on any schema violation."""
    where = f"line {line}" if line else "record"
    kind = d.get("kind")
    if kind not in KINDS:
        raise ValueError(f"{where}: unknown record kind {kind!r}; "
                         f"known: {KINDS}")
    if kind == KIND_ROUND:
        try:
            return RoundRecord.from_dict(d).to_dict()
        except ValueError as e:
            raise ValueError(f"{where}: {e}") from None
    _require_version(d, f"{where} ({kind})")
    if kind == KIND_CHUNK:
        for f in ("chunk", "rounds_end", "n", "link_bytes_cum"):
            if f not in d:
                raise ValueError(f"{where}: chunk record missing {f!r}")
    if kind == KIND_META:
        for f in ("m", "model_bytes", "msg_bytes", "num_links",
                  "link_classes"):
            if f not in d:
                raise ValueError(f"{where}: meta record missing {f!r}")
    return d
