"""Materializing round records from the engine's chunk fold.

The scanned engine already returns every per-round signal the telemetry
plane needs — stacked ``ProtocolMetrics`` out of ``lax.scan`` — and
already fetches ONE folded reduction per chunk. The recorder rides that
fetch: ``DecentralizedLearner`` extends its fold with a ``per_round``
branch (per-round device reductions, still one transfer) and hands the
host-side arrays here, together with a snapshot of the cumulative
counters taken BEFORE the chunk was folded in. ``observe`` then
reconstructs the per-round cumulative series as ``base + cumsum`` —
int64 for the byte/sync/message counters (exact) and float64 running
sums for loss / net-time (the engine switches its own accumulation to
the same sequential float64 sums while a recorder is attached, so the
stream's last ``cum_*`` equals the live counters bitwise).

Zero extra device work, zero extra transfers: everything below is numpy
on arrays that were already crossing the host boundary.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.telemetry.record import (
    RoundRecord, chunk_record, meta_record,
)
from repro.telemetry.sink import TelemetrySink

__all__ = ["RoundRecorder"]


def _ages(extra: Any) -> Any:
    """JSON-ready snapshot of trigger-carried state (e.g. staleness
    counters): arrays become lists, empty containers become None."""
    if extra is None:
        return None
    if isinstance(extra, dict):
        out = {k: _ages(v) for k, v in extra.items()}
        out = {k: v for k, v in out.items() if v is not None}
        return out or None
    arr = np.asarray(extra)
    if arr.size == 0:
        return None
    return arr.tolist()


class RoundRecorder:
    """Streams one ``meta`` record, then per chunk: n ``RoundRecord``s
    plus one ``chunk`` record, into a :class:`TelemetrySink`.

    ``link_payload_bytes`` / ``msg_bytes`` / ``tiers_m`` mirror the
    engine's pricing exactly: per-round link bytes are
    ``counts[..., 0] * payload + counts[..., 1] * msg_bytes`` in int64,
    and ``round_bytes`` uses the engine's c(f) accounting — the ledger
    row sum under a hierarchy, the scalar transfer formula flat."""

    def __init__(self, cfg, *, m: int, num_links: int, model_size: int,
                 model_bytes: int, msg_bytes: int,
                 link_payload_bytes: np.ndarray,
                 link_classes: Tuple[str, ...],
                 spec: Optional[Dict[str, Any]] = None,
                 tiers: Optional[Dict[str, Any]] = None,
                 resumed_rounds: int = 0):
        self.cfg = cfg
        self.m = m
        self.num_links = num_links
        self.model_bytes = int(model_bytes)
        self.msg_bytes = int(msg_bytes)
        self.link_payload_bytes = np.asarray(link_payload_bytes, np.int64)
        self.hierarchical = tiers is not None
        self._chunks = 0
        self.sink = TelemetrySink(cfg.path, ring=cfg.ring, append=cfg.append)
        self._meta_kw = dict(
            m=m, model_size=int(model_size), model_bytes=int(model_bytes),
            msg_bytes=int(msg_bytes), num_links=num_links,
            link_classes=tuple(link_classes), spec=spec, tiers=tiers)
        self.sink.write(meta_record(
            resumed_rounds=int(resumed_rounds), **self._meta_kw))
        self.sink.flush()

    # ------------------------------------------------------------------
    def resume(self, rounds: int) -> None:
        """Re-emit the meta record tagged with the restored round count —
        called when checkpointed counters are restored into the engine, so
        a resumed stream is self-describing about where it picks up."""
        self.sink.write(meta_record(resumed_rounds=int(rounds),
                                    **self._meta_kw))
        self.sink.flush()

    # ------------------------------------------------------------------
    def price(self, counts: np.ndarray) -> np.ndarray:
        """(..., L, 2) int64 [transfers, messages] -> (..., L) int64
        bytes — the engine's ledger pricing, verbatim."""
        c = counts.astype(np.int64)
        return (c[..., 0] * self.link_payload_bytes
                + c[..., 1] * self.msg_bytes)

    # ------------------------------------------------------------------
    def observe(self, per: Dict[str, Any], base: Dict[str, Any],
                extra: Any, n: int, wall_s: Optional[float] = None,
                compiled: Optional[bool] = None,
                recompiles: Optional[int] = None) -> None:
        """File one executed chunk.

        ``per``: the fold's per-round branch, host-side — ``loss`` (n,),
        ``divergence`` (n,), ``num_active`` (n,), ``net_time`` (n,),
        ``comm`` (dict of (n,)), ``link_counts`` (n, L, 2).
        ``base``: the cumulative counters BEFORE this chunk
        (``DecentralizedLearner.counters_snapshot()``). ``extra``: the
        chunk-end trigger-carried state snapshot (staleness ages)."""
        comm = per["comm"]
        messages = np.asarray(comm["messages"], np.int64)
        cohort = np.asarray(comm["model_up"], np.int64)
        syncs = np.asarray(comm["syncs"], np.int64)
        full_syncs = np.asarray(comm["full_syncs"], np.int64)
        model_down = np.asarray(comm["model_down"], np.int64)
        loss = np.asarray(per["loss"], np.float64)
        div = np.asarray(per["divergence"], np.float64)
        num_active = np.asarray(per["num_active"], np.int64)
        net_time = np.asarray(per["net_time"], np.float64)
        link_bytes = self.price(np.asarray(per["link_counts"]))   # (n, L)
        # state-carrying protocols only (async timeline / staleness):
        # per-round in-flight count and oldest sync-age counter
        inflight = (np.asarray(per["num_inflight"], np.int64)
                    if "num_inflight" in per else None)
        max_age = (np.asarray(per["max_age"], np.int64)
                   if "max_age" in per else None)
        # fault plane / robust protocols only: per-round fault and
        # quarantine counts (key membership mirrors the engine's static
        # gating, so fault-free streams carry no extra fields)
        faulty = (np.asarray(per["num_faulty"], np.int64)
                  if "num_faulty" in per else None)
        quar = (np.asarray(per["num_quarantined"], np.int64)
                if "num_quarantined" in per else None)
        rec = (np.asarray(per["num_recovered"], np.int64)
               if "num_recovered" in per else None)

        if self.hierarchical:
            round_bytes = link_bytes.sum(axis=1)
        else:
            round_bytes = ((cohort + model_down) * self.model_bytes
                           + messages * self.msg_bytes)

        # cumulative series: base + sequential running sums. float64
        # np.cumsum IS the sequential sum, so element [t] equals t+1
        # iterations of ``total += x`` — the arithmetic the engine's
        # counters use while a recorder is attached.
        cum_loss = float(base["cumulative_loss"]) + np.cumsum(loss)
        cum_net = float(base["network_time"]) + np.cumsum(net_time)
        cum_syncs = int(base["syncs"]) + np.cumsum(syncs)
        cum_bytes = int(base["cum_bytes"]) + np.cumsum(round_bytes)
        link_cum = (np.asarray(base["link_bytes_totals"], np.int64)
                    + np.cumsum(link_bytes, axis=0))
        base_round = int(base["rounds"])

        per_link = bool(getattr(self.cfg, "per_link", False))
        for t in range(n):
            lb = None
            uplink = None
            if per_link:
                lb = tuple(int(x) for x in link_bytes[t])
            if self.hierarchical:
                uplink = int(link_bytes[t, self.m:].sum())
            self.sink.write(RoundRecord(
                round=base_round + t + 1,
                loss=float(loss[t]), cum_loss=float(cum_loss[t]),
                divergence=float(div[t]),
                messages=int(messages[t]), cohort=int(cohort[t]),
                sync=int(syncs[t]), full_sync=int(full_syncs[t]),
                cum_syncs=int(cum_syncs[t]),
                num_active=int(num_active[t]),
                net_time=float(net_time[t]),
                cum_net_time=float(cum_net[t]),
                round_bytes=int(round_bytes[t]),
                cum_bytes=int(cum_bytes[t]),
                link_bytes=lb, uplink_bytes=uplink,
                inflight=None if inflight is None else int(inflight[t]),
                max_age=None if max_age is None else int(max_age[t]),
                num_faulty=None if faulty is None else int(faulty[t]),
                num_quarantined=None if quar is None else int(quar[t]),
                num_recovered=None if rec is None else int(rec[t]),
            ).to_dict())

        self._chunks += 1
        self.sink.write(chunk_record(
            chunk=self._chunks, rounds_end=base_round + n, n=n,
            link_bytes_cum=link_cum[-1], stale_age=_ages(extra),
            wall_s=wall_s, compiled=compiled, recompiles=recompiles))
        self.sink.flush()

    # ------------------------------------------------------------------
    def flush(self) -> None:
        self.sink.flush()

    def close(self) -> None:
        self.sink.close()

    def __enter__(self) -> "RoundRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
