"""repro.telemetry — the fleet telemetry plane.

Three layers (ROADMAP: the observability substrate every subsystem logs
into):

1. **Round records** (``record``/``sink``/``recorder``): a versioned,
   schema'd record per executed round, materialized host-side from the
   per-chunk fold ``DecentralizedLearner`` already fetches — zero extra
   device work — streamed to JSONL + a bounded in-memory ring. Attach
   via ``TelemetryConfig`` (``repro.config``) through
   ``DecentralizedLearner(telemetry=...)`` /
   ``run_protocol_training(telemetry=...)`` /
   ``benchmarks/run.py --telemetry``.
2. **Tracing & profiling** (``trace``/``costs``): blocked wall-clock
   spans, per-chunk-length recompile accounting, optional
   ``jax.profiler`` integration, and static per-stage cost attribution
   (jaxpr FLOPs × observed trigger fires).
3. **Observatory** (``observatory``, ``python -m repro.telemetry``):
   summarize/tail a recorded stream — comm-vs-loss frontier, sync
   efficiency, per-link-class bytes, Prometheus text exposition — from
   the file alone.
"""
from repro.telemetry.record import (  # noqa: F401
    SCHEMA_VERSION, RoundRecord, chunk_record, meta_record,
    validate_record,
)
from repro.telemetry.recorder import RoundRecorder  # noqa: F401
from repro.telemetry.sink import (  # noqa: F401
    TelemetryLogger, TelemetrySink, console_handler, get_logger,
    jsonl_handler,
)
from repro.telemetry.trace import (  # noqa: F401
    ChunkProfiler, profiler_trace, span, step_annotation, timed,
)

__all__ = [
    "SCHEMA_VERSION", "RoundRecord", "chunk_record", "meta_record",
    "validate_record", "RoundRecorder", "TelemetrySink", "TelemetryLogger",
    "get_logger", "console_handler", "jsonl_handler", "timed", "span",
    "profiler_trace", "step_annotation", "ChunkProfiler",
]
