"""Static per-stage cost attribution: jaxpr FLOPs × observed fires.

The compiled round is ``lax.cond(gate, sync, skip)`` — XLA folds both
branches into one module, so a compiled-executable cost analysis cannot
say what a FIRED round costs vs. a quiet one. The jaxpr still can:
``round_costs`` traces one round of a ``ProtocolSpec`` abstractly
(``jax.make_jaxpr`` over ``ShapeDtypeStruct`` templates — no arrays, no
compilation) and splits ``repro.analysis.roofline.jaxpr_flops`` three
ways:

* ``gate_flops`` — everything outside the sync cond: the local-update
  plumbing plus the trigger's divergence test, paid EVERY round;
* ``skip_flops`` — the cond's false branch (state carry on a quiet
  round);
* ``sync_flops`` — the true branch (cohort + aggregate + commit).

``attribute`` then joins these with a recorded run's observed trigger
fires (``cum_syncs`` from the telemetry stream): estimated total compute
= rounds·(gate+skip) + fires·(sync−skip). That is the protocol's compute
side of the paper's trade-off — how much arithmetic the dynamic trigger
spends to save its bytes — per spec, from the stream alone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax

from repro.analysis.roofline import jaxpr_flops

__all__ = ["RoundCosts", "round_costs", "attribute"]


@dataclass(frozen=True)
class RoundCosts:
    """Per-round FLOP estimate of one ``ProtocolSpec``, split by the
    sync cond's branches."""
    spec: str
    gate_flops: float     # paid every round (outside the sync cond)
    skip_flops: float     # the cond's false (quiet-round) branch
    sync_flops: float     # the cond's true (fired-round) branch

    def as_dict(self) -> Dict[str, Any]:
        return {"spec": self.spec, "gate_flops": self.gate_flops,
                "skip_flops": self.skip_flops,
                "sync_flops": self.sync_flops}


def _top_level_conds(jaxpr):
    return [e for e in jaxpr.eqns if e.primitive.name == "cond"]


def round_costs(spec, template=None, m: int = 8) -> RoundCosts:
    """Trace one abstract round of ``spec`` and split its jaxpr FLOPs by
    the sync cond. ``template``: a stacked ``ShapeDtypeStruct`` fleet
    (defaults to the contracts module's mixed template of ``m``
    learners — pass a real architecture's template for absolute
    numbers; the gate/skip/sync SHARES are what attribution uses)."""
    from repro.analysis.contracts import abstract_state, mixed_template
    from repro.core.sync.spec import resolve_spec
    spec = resolve_spec(spec)
    if template is None:
        template = mixed_template(m)
    mm = jax.tree.leaves(template)[0].shape[0]
    state = abstract_state(spec, template)
    adj = jax.ShapeDtypeStruct((mm, mm), jax.numpy.bool_)
    round_fn = spec.compile()
    closed = jax.make_jaxpr(
        lambda s, st, a: round_fn(s, st, None, adjacency=a))(
            template, state, adj)
    jx = closed.jaxpr
    total = jaxpr_flops(closed)
    conds = _top_level_conds(jx)
    if not conds:
        # unconditional spec (e.g. nosync): everything is gate
        return RoundCosts(spec.name, gate_flops=total,
                          skip_flops=0.0, sync_flops=0.0)
    # the sync gate is the top-level cond with the costliest branch
    # (an always-taken inner cond would sit inside its branches)
    def worst(e):
        return max((jaxpr_flops(b) for b in e.params["branches"]),
                   default=0.0)
    gate_cond = max(conds, key=worst)
    branches = gate_cond.params["branches"]
    skip = jaxpr_flops(branches[0])
    sync = jaxpr_flops(branches[-1])
    # jaxpr_flops counted every cond at its worst branch; carve the sync
    # cond back out to get the unconditional remainder
    gate = total - worst(gate_cond)
    return RoundCosts(spec.name, gate_flops=gate, skip_flops=skip,
                      sync_flops=sync)


def attribute(costs: RoundCosts, rounds: int, fires: int,
              wall_s: Optional[float] = None) -> Dict[str, Any]:
    """Join static per-round costs with a run's observed trigger fires:
    the estimated FLOP total and its gate/skip/sync split."""
    if rounds < 0 or fires < 0 or fires > rounds:
        raise ValueError(
            f"need 0 <= fires <= rounds, got fires={fires} "
            f"rounds={rounds}")
    gate = rounds * costs.gate_flops
    skip = (rounds - fires) * costs.skip_flops
    sync = fires * costs.sync_flops
    total = gate + skip + sync
    out = {
        "spec": costs.spec, "rounds": rounds, "fires": fires,
        "fire_rate": fires / rounds if rounds else 0.0,
        "gate_flops": gate, "skip_flops": skip, "sync_flops": sync,
        "est_total_flops": total,
        "sync_share": sync / total if total else 0.0,
        "per_round": costs.as_dict(),
    }
    if wall_s is not None:
        out["wall_s"] = wall_s
        if wall_s > 0:
            out["est_flops_per_s"] = total / wall_s
    return out
