"""The observatory: analyze a telemetry stream from the file alone.

Everything here consumes ONLY the JSONL a recorded run wrote — the meta
record carries the run's static facts (fleet size, payload sizes, link
classes, the serialized spec), the round records carry the exact
per-round cumulative series — so the paper's headline axes reconstruct
without touching the engine:

* ``frontier``  — the comm-vs-loss frontier (cumulative bytes vs.
  cumulative loss per round; the paper's Fig. 5 axis),
* ``summarize`` — the run card: totals, sync efficiency (bytes per unit
  of round-loss improvement), divergence-vs-Δ trajectory, per-link-class
  byte histogram, recompile/wall accounting,
* ``prom_text`` — Prometheus text exposition of the counters/gauges,
* ``tail_records`` — the newest k records (optionally following a live
  file, which works because the sink flushes per chunk).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

from repro.telemetry.record import (
    KIND_CHUNK, KIND_EVENT, KIND_META, KIND_ROUND, validate_record,
)

__all__ = ["Run", "load_run", "iter_records", "frontier", "age_histogram",
           "summarize", "prom_text", "tail_records"]


@dataclass
class Run:
    """One parsed + schema-validated telemetry stream."""
    meta: Dict[str, Any]
    rounds: List[Dict[str, Any]] = field(default_factory=list)
    chunks: List[Dict[str, Any]] = field(default_factory=list)
    events: List[Dict[str, Any]] = field(default_factory=list)
    metas: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def resumed(self) -> bool:
        return any(m.get("resumed_rounds", 0) > 0 for m in self.metas)


def iter_records(path: str) -> Iterator[Dict[str, Any]]:
    """Yield one validated record per JSONL line (line numbers in every
    error message)."""
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"line {i}: not valid JSON ({e})") from None
            yield validate_record(d, line=i)


def load_run(path: str) -> Run:
    """Parse + validate a whole stream. Raises ``ValueError`` on the
    first schema violation, a missing meta record, or out-of-order
    rounds."""
    run: Optional[Run] = None
    for rec in iter_records(path):
        kind = rec["kind"]
        if kind == KIND_META:
            if run is None:
                run = Run(meta=rec, metas=[rec])
            else:
                run.metas.append(rec)   # checkpoint resume
            continue
        if run is None:
            raise ValueError(
                f"stream {path!r} does not start with a meta record")
        if kind == KIND_ROUND:
            if run.rounds and rec["round"] != run.rounds[-1]["round"] + 1:
                raise ValueError(
                    f"round records out of order: {rec['round']} after "
                    f"{run.rounds[-1]['round']}")
            run.rounds.append(rec)
        elif kind == KIND_CHUNK:
            run.chunks.append(rec)
        elif kind == KIND_EVENT:
            run.events.append(rec)
    if run is None:
        raise ValueError(f"stream {path!r} holds no records")
    return run


# ---------------------------------------------------------------------------
# analyses
# ---------------------------------------------------------------------------

def frontier(run: Run) -> List[List[float]]:
    """The comm-vs-loss frontier: ``[round, cum_bytes, cum_loss]`` per
    recorded round — cumulative bytes bought cumulative loss progress."""
    return [[r["round"], r["cum_bytes"], r["cum_loss"]]
            for r in run.rounds]


def _downsample(rows: List[List[float]], k: int) -> List[List[float]]:
    if len(rows) <= k:
        return rows
    stride = max(1, len(rows) // k)
    out = rows[::stride]
    if out[-1] is not rows[-1]:
        out.append(rows[-1])
    return out


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


def sync_efficiency(run: Run) -> Optional[Dict[str, float]]:
    """Bytes per unit of round-loss improvement: the mean per-round loss
    of the first vs. last decile of rounds, against the bytes spent
    between them. None when the run is too short (< 20 rounds) or did
    not improve."""
    rounds = run.rounds
    if len(rounds) < 20:
        return None
    k = max(1, len(rounds) // 10)
    head = [r["loss"] for r in rounds[:k]]
    tail = [r["loss"] for r in rounds[-k:]]
    drop = _mean(head) - _mean(tail)
    spent = rounds[-1]["cum_bytes"] - rounds[k - 1]["cum_bytes"]
    if drop <= 0.0:
        return {"loss_drop": drop, "bytes_spent": spent,
                "bytes_per_unit_loss": float("inf")}
    return {"loss_drop": drop, "bytes_spent": spent,
            "bytes_per_unit_loss": spent / drop}


def link_class_bytes(run: Run) -> Dict[str, int]:
    """Cumulative bytes per link CLASS (wired/wifi/lte/edge/ideal): the
    last chunk record's per-link ledger joined with the meta record's
    link-class names."""
    if not run.chunks:
        return {}
    classes = run.meta["link_classes"]
    cum = run.chunks[-1]["link_bytes_cum"]
    out: Dict[str, int] = {}
    for name, b in zip(classes, cum):
        out[name] = out.get(name, 0) + int(b)
    return out


def _flat_int_lists(node: Any, prefix: str = "") -> Dict[str, List[int]]:
    """Walk a ``stale_age`` snapshot (nested dicts of lists), yielding the
    1-D integer vectors keyed by dotted path. Deeper nestings — e.g. the
    async timeline's (m, depth) delay ring — are bookkeeping, not
    per-learner counters, and are skipped."""
    out: Dict[str, List[int]] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            out.update(_flat_int_lists(v, key))
        return out
    if (isinstance(node, list) and node
            and all(isinstance(x, (int, float)) and not isinstance(x, bool)
                    for x in node)):
        out[prefix] = [int(x) for x in node]
    return out


def age_histogram(run: Run) -> Dict[str, Any]:
    """Per-counter value histogram of the chunk-end trigger-state
    snapshot (the last chunk record's ``stale_age``): for each carried
    per-learner vector — staleness ages, in-flight countdowns, local
    clocks — the value→count map plus min/max/mean. Empty dict when the
    run's protocol carries no trigger state."""
    if not run.chunks:
        return {}
    snap = run.chunks[-1].get("stale_age")
    if snap is None:
        return {}
    out: Dict[str, Any] = {}
    for key, vals in sorted(_flat_int_lists(snap).items()):
        hist: Dict[str, int] = {}
        for v in vals:
            hist[str(v)] = hist.get(str(v), 0) + 1
        out[key] = {
            "min": min(vals), "max": max(vals), "mean": _mean(vals),
            "hist": hist,
        }
    return out


def summarize(run: Run, points: int = 50) -> Dict[str, Any]:
    """The run card — JSON-ready, built from the stream alone."""
    meta, rounds = run.meta, run.rounds
    spec = meta.get("spec") or {}
    out: Dict[str, Any] = {
        "m": meta["m"],
        "spec": spec.get("name"),
        "delta": (spec.get("params") or {}).get("delta"),
        "model_bytes": meta["model_bytes"],
        "hierarchical": meta.get("tiers") is not None,
        "resumed": run.resumed,
        "rounds": rounds[-1]["round"] if rounds else 0,
        "chunks": len(run.chunks),
    }
    if not rounds:
        return out
    last = rounds[-1]
    out.update({
        "cum_loss": last["cum_loss"],
        "mean_round_loss": _mean([r["loss"] for r in rounds]),
        "cum_bytes": last["cum_bytes"],
        "cum_syncs": last["cum_syncs"],
        "sync_rate": last["cum_syncs"] / last["round"],
        "full_syncs": sum(r["full_sync"] for r in rounds),
        "messages": sum(r["messages"] for r in rounds),
        "mean_active": _mean([r["num_active"] for r in rounds]),
        "net_time_s": last["cum_net_time"],
        "bytes_per_round": last["cum_bytes"] / last["round"],
        "sync_efficiency": sync_efficiency(run),
        "frontier": _downsample(frontier(run), points),
        "divergence": _downsample(
            [[r["round"], r["divergence"]] for r in rounds], points),
        "link_class_bytes": link_class_bytes(run),
    })
    if meta.get("tiers") is not None:
        out["uplink_bytes"] = sum(
            r.get("uplink_bytes") or 0 for r in rounds)
    ages = age_histogram(run)
    if ages:
        out["state_ages"] = ages
    if any(r.get("inflight") is not None for r in rounds):
        out["inflight"] = _downsample(
            [[r["round"], r.get("inflight") or 0, r.get("max_age") or 0]
             for r in rounds], points)
        out["inflight_last"] = last.get("inflight") or 0
        out["max_age_last"] = last.get("max_age") or 0
    # fault card: present only when the stream was written under a
    # FaultConfig and/or a robust (health-carrying) protocol
    has_faults = any(r.get("num_faulty") is not None for r in rounds)
    has_health = any(r.get("num_quarantined") is not None for r in rounds)
    if has_faults or has_health:
        card: Dict[str, Any] = {}
        if has_faults:
            card["faulty_rounds"] = sum(
                1 for r in rounds if r.get("num_faulty"))
            card["max_faulty"] = max(
                r.get("num_faulty") or 0 for r in rounds)
            card["faulty"] = _downsample(
                [[r["round"], r.get("num_faulty") or 0] for r in rounds],
                points)
        if has_health:
            card["total_recovered"] = sum(
                r.get("num_recovered") or 0 for r in rounds)
            card["quarantined_last"] = last.get("num_quarantined") or 0
            card["quarantine"] = _downsample(
                [[r["round"], r.get("num_quarantined") or 0,
                  r.get("num_recovered") or 0] for r in rounds], points)
        out["faults"] = card
    walls = [c["wall_s"] for c in run.chunks if "wall_s" in c]
    if walls:
        out["profile"] = {
            "wall_s": sum(walls),
            "recompiles": max(
                (c.get("recompiles", 0) for c in run.chunks), default=0),
            "chunks_timed": len(walls),
        }
    return out


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _prom_line(lines, name, value, help_=None, typ=None, labels=None):
    if help_:
        lines.append(f"# HELP {name} {help_}")
    if typ:
        lines.append(f"# TYPE {name} {typ}")
    label_s = ""
    if labels:
        inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
        label_s = "{" + inner + "}"
    lines.append(f"{name}{label_s} {value}")


def prom_text(run: Run) -> str:
    """Prometheus text-format exposition of the stream's counters and
    last-round gauges (scrape-ready; also a stable machine interface for
    dashboards that don't speak the JSONL)."""
    lines: List[str] = []
    rounds = run.rounds
    last = rounds[-1] if rounds else None
    _prom_line(lines, "repro_rounds_total",
               last["round"] if last else 0,
               help_="Executed protocol rounds", typ="counter")
    if last is not None:
        _prom_line(lines, "repro_comm_bytes_total", last["cum_bytes"],
                   help_="Cumulative communication bytes (c(f) accounting)",
                   typ="counter")
        _prom_line(lines, "repro_syncs_total", last["cum_syncs"],
                   help_="Rounds in which averaging happened",
                   typ="counter")
        _prom_line(lines, "repro_messages_total",
                   sum(r["messages"] for r in rounds),
                   help_="Control messages (violations + polls)",
                   typ="counter")
        _prom_line(lines, "repro_net_time_seconds_total",
                   last["cum_net_time"],
                   help_="Simulated network seconds", typ="counter")
        first = True
        for cls, b in sorted(link_class_bytes(run).items()):
            _prom_line(
                lines, "repro_link_class_bytes_total", b,
                help_="Cumulative bytes per link class" if first else None,
                typ="counter" if first else None,
                labels={"link_class": cls})
            first = False
        _prom_line(lines, "repro_round_loss", last["loss"],
                   help_="Fleet loss of the last recorded round",
                   typ="gauge")
        _prom_line(lines, "repro_cumulative_loss", last["cum_loss"],
                   help_="Cumulative fleet loss", typ="gauge")
        _prom_line(lines, "repro_divergence", last["divergence"],
                   help_="Fleet divergence of the last recorded round",
                   typ="gauge")
        _prom_line(lines, "repro_num_active", last["num_active"],
                   help_="Reachable learners in the last recorded round",
                   typ="gauge")
    return "\n".join(lines) + "\n"


def tail_records(path: str, k: int = 10) -> List[Dict[str, Any]]:
    """The newest ``k`` records of a stream (validated)."""
    recs = list(iter_records(path))
    return recs[-k:]
