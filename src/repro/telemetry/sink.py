"""Telemetry sinks + the structured event logger.

``TelemetrySink`` is the record stream's output: every record lands in a
bounded in-memory ring (``collections.deque(maxlen=ring)``) and — when a
path is configured — is appended to a JSONL file, one JSON object per
line, flushed per chunk so ``python -m repro.telemetry tail --follow``
sees a live run.

``TelemetryLogger`` is the event side: LIBRARY code emits structured
events (``log.event("train_step", step=3, loss=0.12)``) and stays silent
unless a handler is attached; CLI entry points attach a
``console_handler`` (text formatting) or ``jsonl_handler`` (a sink).
This is the inversion the repo's lint rule enforces: no bare ``print``
in library code — events carry the data, handlers own the formatting.
"""
from __future__ import annotations

import collections
import json
import os
import sys
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "TelemetrySink", "TelemetryLogger", "get_logger", "console_handler",
    "jsonl_handler",
]

Handler = Callable[[Dict[str, Any]], None]


class TelemetrySink:
    """Bounded in-memory ring + optional JSONL file stream."""

    def __init__(self, path: Optional[str] = None, ring: int = 1024,
                 append: bool = False):
        if ring < 1:
            raise ValueError(f"ring must hold >= 1 record, got {ring!r}")
        self.path = path
        self._ring: collections.deque = collections.deque(maxlen=ring)
        self._file = None
        if path:
            parent = os.path.dirname(os.path.abspath(path))
            os.makedirs(parent, exist_ok=True)
            self._file = open(path, "a" if append else "w",
                              encoding="utf-8")

    def write(self, rec: Dict[str, Any]) -> None:
        self._ring.append(rec)
        if self._file is not None:
            self._file.write(json.dumps(rec, sort_keys=True) + "\n")

    def flush(self) -> None:
        if self._file is not None:
            self._file.flush()

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    @property
    def records(self) -> List[Dict[str, Any]]:
        """The ring's current contents, oldest first."""
        return list(self._ring)

    def tail(self, k: Optional[int] = None) -> List[Dict[str, Any]]:
        recs = list(self._ring)
        return recs if k is None else recs[-k:]

    def __enter__(self) -> "TelemetrySink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TelemetryLogger:
    """Structured events with pluggable handlers. With no handlers
    attached, ``event`` is a no-op — library code can emit
    unconditionally; only configured entry points produce output."""

    def __init__(self):
        self._handlers: List[Handler] = []

    def add_handler(self, handler: Handler) -> Handler:
        self._handlers.append(handler)
        return handler

    def remove_handler(self, handler: Handler) -> None:
        self._handlers = [h for h in self._handlers if h is not handler]

    @property
    def enabled(self) -> bool:
        return bool(self._handlers)

    def event(self, kind: str, **fields: Any) -> None:
        if not self._handlers:
            return
        rec = {"kind": kind, **fields}
        for h in list(self._handlers):
            h(rec)


_DEFAULT_LOGGER = TelemetryLogger()


def get_logger() -> TelemetryLogger:
    """The process-wide default event logger (handler-less — silent —
    until an entry point attaches a handler)."""
    return _DEFAULT_LOGGER


def _fmt_value(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4f}" if 1e-4 <= abs(v) < 1e6 or v == 0.0 else f"{v:.3e}"
    return str(v)


def console_handler(stream=None) -> Handler:
    """Text formatting for a CLI: one ``kind key=value ...`` line per
    event, flushed immediately (launcher progress must stream)."""
    out = stream if stream is not None else sys.stdout

    def handler(rec: Dict[str, Any]) -> None:
        kind = rec.get("kind", "event")
        body = " ".join(f"{k}={_fmt_value(v)}" for k, v in rec.items()
                        if k != "kind")
        out.write(f"{kind} {body}".rstrip() + "\n")
        if hasattr(out, "flush"):
            out.flush()

    return handler


def jsonl_handler(sink: TelemetrySink) -> Handler:
    """Route events into a record sink (they land as ``kind: event``-style
    objects alongside the round/chunk records)."""
    from repro.telemetry.record import KIND_EVENT, SCHEMA_VERSION

    def handler(rec: Dict[str, Any]) -> None:
        body = {k: v for k, v in rec.items() if k != "kind"}
        sink.write({"kind": KIND_EVENT, "v": SCHEMA_VERSION,
                    "event": rec.get("kind", "event"), **body})

    return handler
