"""The observatory CLI: ``python -m repro.telemetry <cmd>``.

    record     run the drift-MLP smoke task with telemetry attached and
               write the JSONL stream (a self-contained way to produce a
               stream to analyze; benchmarks attach telemetry to their
               own runs via ``benchmarks/run.py --telemetry``)
    summarize  the run card as JSON — totals, comm-vs-loss frontier,
               sync efficiency, per-link-class bytes
    frontier   just the [round, cum_bytes, cum_loss] frontier as JSON
    tail       the newest records, one JSON object per line
               (``--follow`` keeps watching the file)
    prom       Prometheus text exposition of counters/gauges
    costs      static per-stage FLOPs × this stream's observed fires
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _cmd_record(args) -> int:
    from repro.config import ProtocolConfig, TelemetryConfig, TrainConfig, get_arch
    from repro.data.synthetic import GraphicalModelStream
    from repro.models.cnn import cnn_loss, init_cnn_params
    from repro.train.loop import run_protocol_training

    cfg = get_arch("drift_mlp", smoke=True)
    proto = ProtocolConfig(kind=args.kind, b=args.b, delta=args.delta)
    telem = TelemetryConfig(path=args.out, per_link=args.per_link,
                            profile=args.profile)
    dl, _ = run_protocol_training(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k),
        GraphicalModelStream(seed=0, drift_prob=0.0),
        m=args.m, rounds=args.rounds, protocol=proto,
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, seed=args.seed, record_every=max(1, args.rounds // 10),
        chunk_size=args.chunk, telemetry=telem)
    dl.recorder.close()
    print(f"recorded {dl.rounds} rounds ({args.kind}, m={args.m}) "
          f"-> {args.out}")
    print(f"  cum_loss={dl.cumulative_loss:.4f} "
          f"syncs={dl.comm_totals['syncs']} bytes={dl.comm_bytes()}")
    return 0


def _cmd_summarize(args) -> int:
    from repro.telemetry.observatory import load_run, summarize
    print(json.dumps(summarize(load_run(args.path), points=args.points),
                     indent=1, sort_keys=True))
    return 0


def _cmd_frontier(args) -> int:
    from repro.telemetry.observatory import frontier, load_run
    print(json.dumps(frontier(load_run(args.path))))
    return 0


def _cmd_tail(args) -> int:
    from repro.telemetry.observatory import iter_records, tail_records
    for rec in tail_records(args.path, args.n):
        print(json.dumps(rec, sort_keys=True))
    if not args.follow:
        return 0
    seen = sum(1 for _ in iter_records(args.path))
    try:
        while True:
            time.sleep(args.interval)
            recs = list(iter_records(args.path))
            for rec in recs[seen:]:
                print(json.dumps(rec, sort_keys=True), flush=True)
            seen = len(recs)
    except KeyboardInterrupt:
        return 0


def _cmd_prom(args) -> int:
    from repro.telemetry.observatory import load_run, prom_text
    sys.stdout.write(prom_text(load_run(args.path)))
    return 0


def _cmd_costs(args) -> int:
    from repro.core.sync.spec import ProtocolSpec
    from repro.telemetry.costs import attribute, round_costs
    from repro.telemetry.observatory import load_run

    run = load_run(args.path)
    spec_dict = run.meta.get("spec")
    if spec_dict is None:
        print("error: stream's meta record carries no spec",
              file=sys.stderr)
        return 2
    spec = ProtocolSpec.from_dict(spec_dict)
    template = None
    if args.arch:
        import jax
        from repro.config import get_arch
        from repro.models.cnn import init_cnn_params
        cfg = get_arch(args.arch, smoke=True)
        params = jax.eval_shape(
            lambda k: init_cnn_params(cfg, k), jax.random.PRNGKey(0))
        template = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((run.meta["m"],) + s.shape,
                                           s.dtype), params)
    costs = round_costs(spec, template=template, m=run.meta["m"])
    last = run.rounds[-1] if run.rounds else None
    rounds = last["round"] if last else 0
    fires = last["cum_syncs"] if last else 0
    walls = [c["wall_s"] for c in run.chunks if "wall_s" in c]
    print(json.dumps(
        attribute(costs, rounds, fires,
                  wall_s=sum(walls) if walls else None),
        indent=1, sort_keys=True))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="fleet telemetry observatory")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rec = sub.add_parser("record", help="record a drift-MLP smoke run")
    rec.add_argument("--out", required=True, help="JSONL output path")
    rec.add_argument("--rounds", type=int, default=100)
    rec.add_argument("--m", type=int, default=8)
    rec.add_argument("--kind", default="dynamic")
    rec.add_argument("--b", type=int, default=2)
    rec.add_argument("--delta", type=float, default=0.5)
    rec.add_argument("--seed", type=int, default=0)
    rec.add_argument("--chunk", type=int, default=64)
    rec.add_argument("--per-link", action="store_true",
                     help="per-link bytes on every round record")
    rec.add_argument("--profile", action="store_true",
                     help="wall-clock + recompile spans per chunk")
    rec.set_defaults(fn=_cmd_record)

    summ = sub.add_parser("summarize", help="run card as JSON")
    summ.add_argument("path")
    summ.add_argument("--points", type=int, default=50,
                      help="downsampled curve length")
    summ.set_defaults(fn=_cmd_summarize)

    fro = sub.add_parser("frontier",
                         help="[round, cum_bytes, cum_loss] frontier")
    fro.add_argument("path")
    fro.set_defaults(fn=_cmd_frontier)

    tl = sub.add_parser("tail", help="newest records")
    tl.add_argument("path")
    tl.add_argument("-n", type=int, default=10)
    tl.add_argument("--follow", action="store_true",
                    help="keep watching the file")
    tl.add_argument("--interval", type=float, default=0.5)
    tl.set_defaults(fn=_cmd_tail)

    pr = sub.add_parser("prom", help="Prometheus text exposition")
    pr.add_argument("path")
    pr.set_defaults(fn=_cmd_prom)

    co = sub.add_parser("costs",
                        help="static stage FLOPs x observed fires")
    co.add_argument("path")
    co.add_argument("--arch", default=None,
                    help="architecture template for absolute FLOPs "
                         "(e.g. drift_mlp)")
    co.set_defaults(fn=_cmd_costs)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        # downstream pager/head closed the pipe mid-write — exit quietly
        # (devnull swap stops the interpreter-shutdown flush from raising)
        import os
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
