"""The fault-injection plane, pure in ``(fault_seed, t)``.

Every mask here is a pure function of the ``FaultConfig`` seed and the
round counter — derived by folding ``t`` (or the episode window
``t // window``) into a PRNG key, never by carried RNG state — so the
whole plane evaluates inside ``lax.scan`` (``t`` may be traced) and any
round's fault schedule is reconstructable in isolation, out of order,
on the host. Four orthogonal fault kinds (see ``FaultConfig``):

* **crash episodes** — ``crash_mask(cfg, m, t)``: within each
  ``crash_every``-round window a learner crashes with probability
  ``crash_prob`` at a sampled offset for a sampled duration. A crashed
  learner is stateless: the engine forces it out of the availability
  mask (``compose_active``) and freezes its local training.
  ``restart_mask`` marks the rejoin round — crashed last round, up this
  round — where the engine zeroes its params/optimizer/sync-state rows
  (``lose_state``): it comes back COLD.
* **payload corruption** — ``corrupt_mask`` + ``perturb_params``:
  a corrupted learner's parameter row goes NaN (odd rounds) or Inf
  (even rounds).
* **Byzantine adversaries** — ``byzantine_mask`` (a fixed subset drawn
  once from the seed) + ``perturb_params``: sign-flipped or scaled
  parameter rows, every round.
* **straggler bursts** — ``straggler_burst_mask``: whole windows where
  a random fraction of the fleet goes dark, AND-composed with the
  availability mask (no state loss).

The engine gates on ``faults is not None`` statically, so a fault-free
run traces none of this; a default ``FaultConfig()`` (all faults off)
traces it but every mask is constant-False and every ``where`` selects
the original value — bitwise identical results.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FaultConfig

# per-fault-kind key-derivation constants (xor'd into the seed so the
# streams never collide with each other or with availability's
# 0xAC71/0x57AA/0x0F0F and aircomp's 0xA17C0)
_KEY_CRASH = 0xC4A5
_KEY_CRASH_AT = 0xC4A7
_KEY_CRASH_LEN = 0xC4A9
_KEY_CORRUPT = 0xC0DE
_KEY_BYZ = 0xB42A
_KEY_BURST = 0x5B57
_KEY_BURST_WHO = 0x5B59


def _win_key(seed: int, const: int, window) -> jax.Array:
    return jax.random.fold_in(
        jax.random.PRNGKey(seed ^ const), jnp.asarray(window, jnp.int32))


# ---------------------------------------------------------------------------
# crash/restart episodes
# ---------------------------------------------------------------------------

def crash_mask(cfg: FaultConfig, m: int, t) -> jnp.ndarray:
    """(m,) bool — learners mid-outage (crashed, stateless) at round
    ``t``. Episode schedule per window ``w = t // crash_every``: learner
    i crashes iff its window draw < ``crash_prob``, starting at a
    uniform offset with a uniform ``outage_min..outage_max`` duration
    (truncated at the window edge, so episodes never straddle windows
    and the schedule stays a pure function of ``(fault_seed, t)``)."""
    if cfg.crash_prob <= 0.0:
        return jnp.zeros((m,), bool)
    t = jnp.asarray(t, jnp.int32)
    w = t // cfg.crash_every
    phase = t % cfg.crash_every
    crashing = jax.random.uniform(
        _win_key(cfg.fault_seed, _KEY_CRASH, w), (m,)) < cfg.crash_prob
    start = jax.random.randint(
        _win_key(cfg.fault_seed, _KEY_CRASH_AT, w), (m,),
        0, cfg.crash_every)
    dur = jax.random.randint(
        _win_key(cfg.fault_seed, _KEY_CRASH_LEN, w), (m,),
        cfg.outage_min, cfg.outage_max + 1)
    return crashing & (phase >= start) & (phase < start + dur)


def restart_mask(cfg: FaultConfig, m: int, t) -> jnp.ndarray:
    """(m,) bool — learners REJOINING at round ``t``: crashed during
    round ``t - 1``, up again this round. The engine zeroes their local
    state on this round (``lose_state``) before they rejoin."""
    if cfg.crash_prob <= 0.0:
        return jnp.zeros((m,), bool)
    t = jnp.asarray(t, jnp.int32)
    prev = crash_mask(cfg, m, jnp.maximum(t - 1, 0))
    return prev & ~crash_mask(cfg, m, t) & (t > 0)


# ---------------------------------------------------------------------------
# straggler bursts
# ---------------------------------------------------------------------------

def straggler_burst_mask(cfg: FaultConfig, m: int, t) -> jnp.ndarray:
    """(m,) bool — learners dark for this burst window. In window
    ``w = t // straggler_every`` a burst fires with probability
    ``straggler_prob``; during a burst each learner straggles with
    probability ``straggler_frac`` (drawn per window)."""
    if cfg.straggler_prob <= 0.0 or cfg.straggler_frac <= 0.0:
        return jnp.zeros((m,), bool)
    t = jnp.asarray(t, jnp.int32)
    w = t // cfg.straggler_every
    burst = jax.random.uniform(
        _win_key(cfg.fault_seed, _KEY_BURST, w), ()) < cfg.straggler_prob
    who = jax.random.uniform(
        _win_key(cfg.fault_seed, _KEY_BURST_WHO, w),
        (m,)) < cfg.straggler_frac
    return burst & who


def compose_active(cfg: FaultConfig, active, m: int, t) -> jnp.ndarray:
    """AND the fault plane into the availability mask: crashed and
    bursting learners are unreachable. The composition can only REMOVE
    learners, so a crashed (stateless) learner is never active. With
    crashes and bursts statically off the mask passes through UNTOUCHED
    (``None`` stays ``None``), so an inert config keeps the engine on
    the ideal-network expressions — bitwise vs ``faults=None``."""
    if cfg.crash_prob <= 0.0 and (
            cfg.straggler_prob <= 0.0 or cfg.straggler_frac <= 0.0):
        return active
    down = crash_mask(cfg, m, t) | straggler_burst_mask(cfg, m, t)
    if active is None:
        return ~down
    return active & ~down


# ---------------------------------------------------------------------------
# payload corruption + Byzantine adversaries
# ---------------------------------------------------------------------------

def corrupt_mask(cfg: FaultConfig, m: int, t) -> jnp.ndarray:
    """(m,) bool — learners whose parameters go non-finite this round."""
    if cfg.corrupt_prob <= 0.0:
        return jnp.zeros((m,), bool)
    key = jax.random.fold_in(
        jax.random.PRNGKey(cfg.fault_seed ^ _KEY_CORRUPT),
        jnp.asarray(t, jnp.int32))
    return jax.random.uniform(key, (m,)) < cfg.corrupt_prob


def byzantine_mask(cfg: FaultConfig, m: int) -> jnp.ndarray:
    """(m,) bool — the FIXED adversary subset, drawn once from the
    seed (round-independent: an adversary is an adversary all run)."""
    n_adv = int(round(cfg.byzantine_frac * m))
    if n_adv == 0:
        return jnp.zeros((m,), bool)
    perm = jax.random.permutation(
        jax.random.PRNGKey(cfg.fault_seed ^ _KEY_BYZ), m)
    return jnp.zeros((m,), bool).at[perm[:n_adv]].set(True)


def _row_select(rows: jnp.ndarray, bad, x: jnp.ndarray) -> jnp.ndarray:
    """``where`` over a learner-stacked leaf: row i <- bad_i if rows[i].
    Selection (not arithmetic), so untouched rows stay bitwise."""
    r = rows.reshape((rows.shape[0],) + (1,) * (x.ndim - 1))
    return jnp.where(r, bad, x)


def perturb_params(cfg: FaultConfig, params, m: int, t):
    """Apply corruption + Byzantine perturbation to the learner-stacked
    parameter pytree. Rows of honest, uncorrupted learners pass through
    a ``where`` select — bitwise untouched."""
    corrupt = corrupt_mask(cfg, m, t)
    byz = byzantine_mask(cfg, m)
    any_corrupt = cfg.corrupt_prob > 0.0
    any_byz = int(round(cfg.byzantine_frac * m)) > 0
    if not (any_corrupt or any_byz):
        return params
    t = jnp.asarray(t, jnp.int32)

    def leaf(x):
        if any_byz:
            if cfg.byzantine_mode == "sign_flip":
                x = _row_select(byz, -x, x)
            else:
                x = _row_select(byz, jnp.asarray(
                    cfg.byzantine_scale, x.dtype) * x, x)
        if any_corrupt:
            poison = jnp.where(t % 2 == 1,
                               jnp.asarray(jnp.nan, x.dtype),
                               jnp.asarray(jnp.inf, x.dtype))
            x = _row_select(corrupt, poison, x)
        return x

    return jax.tree.map(leaf, params)


def freeze_state(tree_new, tree_old, rows: jnp.ndarray, m: int):
    """Discard the update of the marked learner rows: leaves with a
    leading fleet axis keep their OLD row where ``rows[i]`` (a crashed
    learner does not train); other leaves take the new value."""
    def leaf(n, o):
        if jnp.ndim(n) >= 1 and n.shape[0] == m:
            return _row_select(rows, o, n)
        return n
    return jax.tree.map(leaf, tree_new, tree_old)


def lose_state(tree, rows: jnp.ndarray, m: int):
    """Zero the learner rows of every learner-stacked leaf (leading dim
    ``m``): the restart state loss. Leaves without a leading fleet axis
    (a replicated scalar an optimizer carries) pass through untouched."""
    def leaf(x):
        if jnp.ndim(x) >= 1 and x.shape[0] == m:
            return _row_select(rows, jnp.zeros_like(x), x)
        return x
    return jax.tree.map(leaf, tree)


def num_faulty(cfg: FaultConfig, m: int, t) -> jnp.ndarray:
    """Scalar int32 — learners under ANY fault this round (crashed,
    restarting, bursting, corrupted, or Byzantine)."""
    any_fault = (crash_mask(cfg, m, t) | restart_mask(cfg, m, t)
                 | straggler_burst_mask(cfg, m, t)
                 | corrupt_mask(cfg, m, t) | byzantine_mask(cfg, m))
    return jnp.sum(any_fault).astype(jnp.int32)
