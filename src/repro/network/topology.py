"""Communication topologies as (m, m) adjacency matrices (pure JAX).

Every builder returns a symmetric bool matrix with a zero diagonal. The
coordinator operators (periodic/fedavg/dynamic) communicate over
learner↔coordinator uplinks and read the environment only through the
availability mask; the adjacency matrix is the *peer overlay* consumed by
the coordinator-free ``gossip`` operator and by the mobility model.

``geometric`` supports mobility: with ``NetworkConfig.redraw_every = k``
the node positions are re-drawn every k rounds, so the adjacency used in
round ``t`` is a pure function of ``(seed, t)`` and evaluates inside
``lax.scan`` with no per-round host sync. Static topologies ignore ``t``
and the engine closes over one concrete matrix.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import (
    NetworkConfig, TOPO_ERDOS_RENYI, TOPO_GEOMETRIC, TOPO_RING, TOPO_STAR,
    TOPO_TORUS,
)


def _no_self(adj: jnp.ndarray) -> jnp.ndarray:
    return adj & ~jnp.eye(adj.shape[0], dtype=bool)


def star(m: int, hub: int = 0) -> jnp.ndarray:
    """Hub-and-spokes: every learner peers with ``hub`` only."""
    adj = jnp.zeros((m, m), bool)
    adj = adj.at[hub, :].set(True).at[:, hub].set(True)
    return _no_self(adj)


def ring(m: int) -> jnp.ndarray:
    """i ~ i±1 (mod m)."""
    i = jnp.arange(m)
    adj = jnp.zeros((m, m), bool)
    adj = adj.at[i, (i + 1) % m].set(True)
    adj = adj.at[i, (i - 1) % m].set(True)
    return _no_self(adj)


def complete(m: int) -> jnp.ndarray:
    return _no_self(jnp.ones((m, m), bool))


def _torus_sides(m: int) -> tuple:
    a = max(1, int(math.isqrt(m)))
    while m % a:
        a -= 1
    return a, m // a


def torus(m: int) -> jnp.ndarray:
    """2-d torus on the most-square a×b factorization of m (degenerates to
    a ring when m is prime)."""
    a, b = _torus_sides(m)
    idx = jnp.arange(m).reshape(a, b)
    adj = jnp.zeros((m, m), bool)
    for shift, axis in ((1, 0), (-1, 0), (1, 1), (-1, 1)):
        nbr = jnp.roll(idx, shift, axis)
        adj = adj.at[idx.reshape(-1), nbr.reshape(-1)].set(True)
    return _no_self(adj | adj.T)


def erdos_renyi(key: jax.Array, m: int, p: float) -> jnp.ndarray:
    """Each of the m(m-1)/2 undirected edges present i.i.d. w.p. ``p``."""
    u = jax.random.uniform(key, (m, m))
    upper = jnp.triu(u < p, k=1)
    return _no_self(upper | upper.T)


def random_geometric(key: jax.Array, m: int, radius: float) -> jnp.ndarray:
    """Nodes uniform in [0,1]^2, edge iff Euclidean distance < radius."""
    pos = jax.random.uniform(key, (m, 2))
    d2 = jnp.sum(jnp.square(pos[:, None] - pos[None]), axis=-1)
    return _no_self(d2 < radius * radius)


def adjacency(net: NetworkConfig, m: int, t=None) -> jnp.ndarray:
    """The (m, m) adjacency of ``net`` at round ``t``.

    Static topologies ignore ``t`` and return a concrete matrix when called
    outside jit. ``geometric`` with ``redraw_every > 0`` re-draws positions
    every ``redraw_every`` rounds — pass the traced round counter to get
    the mobile graph inside ``lax.scan``.
    """
    key = jax.random.PRNGKey(net.seed ^ 0x70B0)
    if net.topology == TOPO_STAR:
        return star(m)
    if net.topology == TOPO_RING:
        return ring(m)
    if net.topology == TOPO_TORUS:
        return torus(m)
    if net.topology == TOPO_ERDOS_RENYI:
        return erdos_renyi(key, m, net.er_p)
    if net.topology != TOPO_GEOMETRIC:
        raise KeyError(
            f"unknown topology {net.topology!r} — NetworkConfig validates "
            f"membership, so this overlay builder is out of sync with "
            f"repro.config.TOPOLOGIES")
    if net.redraw_every > 0 and t is not None:
        key = jax.random.fold_in(key, t // net.redraw_every)
    return random_geometric(key, m, net.geo_radius)


def is_mobile(net: NetworkConfig) -> bool:
    """True when the adjacency changes over rounds (must be rebuilt inside
    the scanned round body rather than closed over once)."""
    return net.topology == TOPO_GEOMETRIC and net.redraw_every > 0
