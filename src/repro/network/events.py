"""Discrete-event network timeline primitives for the scanned round loop.

The round-synchronous engine treats every sync as instantaneous: the
availability mask decides WHO communicates, never WHEN the payload lands.
This module supplies the arithmetic that turns each sync into a message
in flight: a per-learner flight time derived from the
``repro.network.cost`` link classes, quantized against a per-round time
budget into ``k = ceil(round_trip / budget) - 1`` extra rounds in the
air (an exchange that fits inside one round budget lands the same round,
which is exactly the synchronous engine), and a bounded-delay ring
buffer carried in ``SyncState.extra`` that schedules the arrival.

Everything here is a pure function of static parameters and the scan
carry — flight times are resolved at trace time from the protocol's
scalar params (the comma-joined link-class string mirrors the engine's
round-robin link profile), and the ring arithmetic is index math on the
carried ``(m, depth)`` buffer, so the timeline stays pure in
``(seed, t)`` and lives entirely inside ``lax.scan``. The registered
event-driven trigger stages that consume these primitives are in
``repro.core.sync.async_sync``.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.network.cost import LINK_CLASSES


# ---------------------------------------------------------------------------
# static flight-time resolution (trace time; python/numpy only)
# ---------------------------------------------------------------------------

def parse_link_classes(csv: str) -> Tuple[str, ...]:
    """Parse the comma-joined link-class protocol param (scalar-only spec
    params cannot carry tuples). ``""`` means an ideal network: every
    exchange lands inside the round it was launched."""
    if not csv:
        return ()
    names = tuple(s.strip() for s in csv.split(",") if s.strip())
    unknown = sorted(set(names) - set(LINK_CLASSES))
    if unknown:
        raise ValueError(
            f"unknown link class(es) {unknown} in {csv!r} — known: "
            f"{sorted(LINK_CLASSES)}")
    return names


def round_trip_time(name: str, payload_bytes: int) -> float:
    """Seconds for one sync exchange on a class link: the model up and
    the aggregate back down — ``2 * (latency + payload/bandwidth)``, the
    same per-transfer expression ``cost.round_network_time`` prices."""
    lc = LINK_CLASSES[name]
    return 2.0 * (lc.latency + float(payload_bytes) / lc.bandwidth)


def class_flight_rounds(csv: str, payload_bytes: int,
                        budget: float) -> Dict[str, int]:
    """Whole rounds each class's exchange spends IN FLIGHT, per class.
    An exchange that fits inside one round budget costs 0 extra rounds
    (it lands the round it was launched — the synchronous limit), so
    ``k = max(0, ceil(round_trip / budget) - 1)``."""
    return {
        name: max(0, math.ceil(round_trip_time(name, payload_bytes)
                               / budget) - 1)
        for name in parse_link_classes(csv)
    }


def max_flight_rounds(csv: str, payload_bytes: int, budget: float) -> int:
    """The largest per-class flight time — m-independent, so spec
    validation can bound the ring depth without knowing the fleet size."""
    return max(class_flight_rounds(csv, payload_bytes, budget).values(),
               default=0)


def flight_rounds(csv: str, m: int, payload_bytes: int,
                  budget: float) -> jnp.ndarray:
    """(m,) int32 per-learner flight rounds, round-robin over the named
    classes — the same learner->class assignment as
    ``cost.link_profile`` and the ledger's rows."""
    names = parse_link_classes(csv)
    if not names:
        return jnp.zeros((m,), jnp.int32)
    per_class = class_flight_rounds(csv, payload_bytes, budget)
    return jnp.asarray(
        np.asarray([per_class[names[i % len(names)]] for i in range(m)],
                   np.int32))


# ---------------------------------------------------------------------------
# bounded-delay ring buffer (traced; carried in SyncState.extra)
# ---------------------------------------------------------------------------

def empty_ring(m: int, depth: int) -> jnp.ndarray:
    """(m, depth) int32 arrival buffer: slot ``t % depth`` of row i holds
    1 iff learner i's in-flight exchange lands at round t."""
    return jnp.zeros((m, depth), jnp.int32)


def due_mask(ring: jnp.ndarray, t) -> jnp.ndarray:
    """(m,) bool — whose exchange lands this round."""
    depth = ring.shape[1]
    return jnp.take(ring, t % depth, axis=1) > 0


def ring_step(ring: jnp.ndarray, t, launch: jnp.ndarray,
              k: jnp.ndarray) -> jnp.ndarray:
    """One timeline transition: consume round-t arrivals (clear the
    current slot) and schedule this round's launches ``k`` rounds out.
    A learner launches only while idle (its row is empty), and spec
    validation pins ``k < depth``, so a scheduled slot can never collide
    with a pending one — the buffer is exact, not approximate."""
    m, depth = ring.shape
    cleared = ring.at[:, t % depth].set(0)
    return cleared.at[jnp.arange(m), (t + k) % depth].add(
        launch.astype(jnp.int32))
