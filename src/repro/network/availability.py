"""Per-round learner availability masks, pure in ``(seed, t)``.

``sample(net, m, t)`` returns the (m,) bool active mask for round ``t``,
derived by folding the round counter into a PRNG key — no carried RNG
state, so it runs inside ``lax.scan`` (``t`` may be traced) and the mask
for a given round is reproducible in isolation.

Three stacking failure modes (all off by default):

* i.i.d. dropout   — each learner answers w.p. ``act_prob``
  (FedAvg's partial client participation, McMahan et al. '17)
* stragglers       — a fixed ``straggler_frac`` subset (chosen once from
  ``seed``) answers with its own lower ``straggler_act_prob``
* scheduled outage — every ``outage_every`` rounds a fresh random
  ``outage_frac`` of the fleet goes dark for ``outage_length`` rounds
  (cell tower handoff, depot Wi-Fi, nightly charging)

Availability means *reachability*: an unavailable learner keeps taking
local SGD steps but cannot communicate — it neither violates, nor is
polled, nor receives the average that round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import NetworkConfig


def straggler_mask(net: NetworkConfig, m: int) -> jnp.ndarray:
    """(m,) bool — the fixed subset of learners that straggle."""
    n_strag = int(round(net.straggler_frac * m))
    if n_strag == 0:
        return jnp.zeros((m,), bool)
    perm = jax.random.permutation(jax.random.PRNGKey(net.seed ^ 0x57AA), m)
    return jnp.zeros((m,), bool).at[perm[:n_strag]].set(True)


def sample(net: NetworkConfig, m: int, t) -> jnp.ndarray:
    """(m,) bool active mask for round ``t`` (``t`` may be traced)."""
    t = jnp.asarray(t, jnp.int32)
    key = jax.random.fold_in(jax.random.PRNGKey(net.seed ^ 0xAC71), t)
    p = jnp.where(straggler_mask(net, m),
                  net.straggler_act_prob, net.act_prob)
    active = jax.random.uniform(key, (m,)) < p
    if net.outage_every > 0:
        window = t // net.outage_every
        in_outage = (t % net.outage_every) < net.outage_length
        okey = jax.random.fold_in(
            jax.random.PRNGKey(net.seed ^ 0x0F0F), window)
        n_down = int(round(net.outage_frac * m))
        down = jnp.zeros((m,), bool).at[
            jax.random.permutation(okey, m)[:n_down]].set(True)
        active = active & ~(in_outage & down)
    return active
