"""Link-cost model: bandwidth/latency classes → simulated wall-clock.

Units: bandwidth in **bytes/second**, latency in **seconds**; all returned
times are seconds. Each learner owns one link (to the coordinator for
periodic/fedavg/dynamic, to its peers for gossip), assigned a class from
``NetworkConfig.link_classes`` round-robin over the learner index.

The timing model is *parallel links*: within a round every participating
link transfers concurrently, so the round's network time is the slowest
link's ``transfers_i * (latency_i + model_bytes / bandwidth_i)``, plus one
control-plane round-trip over the slowest link that actually SENT a
message (violation notices / poll requests). Per-link
*bytes* are exact — ``transfers_i * model_bytes`` — and extend the paper's
``comm_bytes`` accounting from a fleet total to a per-link breakdown.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.config import LINK_CLASS_NAMES, NetworkConfig


class LinkClass(NamedTuple):
    bandwidth: float     # bytes / second
    latency: float       # seconds (one-way)


# Nominal classes, deliberately coarse: the object of study is the regime
# (orders of magnitude between tiers), not any one carrier's datasheet.
LINK_CLASSES = {
    "wired": LinkClass(bandwidth=125e6, latency=0.001),   # 1 Gb/s LAN
    "wifi":  LinkClass(bandwidth=25e6,  latency=0.005),
    "lte":   LinkClass(bandwidth=5e6,   latency=0.05),
    "edge":  LinkClass(bandwidth=125e3, latency=0.2),     # 2G fallback
}

# configs validate names against repro.config.LINK_CLASS_NAMES; keep the
# two registries in lockstep so config-time validation covers exactly the
# classes this cost model can price
if set(LINK_CLASSES) != set(LINK_CLASS_NAMES):
    raise RuntimeError(
        f"link-class registries out of lockstep: cost model prices "
        f"{sorted(LINK_CLASSES)}, configs validate against "
        f"{sorted(LINK_CLASS_NAMES)}")


def link_profile(net: NetworkConfig, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-learner ``(bandwidth, latency)`` float32 arrays, classes from
    ``net.link_classes`` assigned round-robin over the learner index."""
    unknown = [c for c in net.link_classes if c not in LINK_CLASSES]
    if unknown:
        raise KeyError(
            f"unknown link class(es) {unknown}; known: {sorted(LINK_CLASSES)}")
    classes = [LINK_CLASSES[net.link_classes[i % len(net.link_classes)]]
               for i in range(m)]
    bw = jnp.asarray([c.bandwidth for c in classes], jnp.float32)
    lat = jnp.asarray([c.latency for c in classes], jnp.float32)
    return bw, lat


def uniform_profile(link_class: str, n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(bandwidth, latency) arrays for ``n`` links of one class — the
    aggregator↔coordinator uplink tier of a hierarchy
    (``HierarchyConfig.link_class``)."""
    if link_class not in LINK_CLASSES:
        raise KeyError(
            f"unknown link class {link_class!r}; known: {sorted(LINK_CLASSES)}")
    c = LINK_CLASSES[link_class]
    return (jnp.full((n,), c.bandwidth, jnp.float32),
            jnp.full((n,), c.latency, jnp.float32))


def round_network_time(xfers, link_msgs, model_bytes: int,
                       bw, lat) -> jnp.ndarray:
    """Simulated seconds one round of the protocol spends on the network.

    ``xfers``: (m,) int32 models crossing each learner's link this round;
    ``link_msgs``: (m,) int32 control messages each link SENT (the
    ledger's message column); ``bw``/``lat``: ``link_profile`` arrays.

    The control-plane term prices one round-trip over the slowest link
    that actually sent a message — not the slowest merely-reachable
    link, which used to bill a silent slow link for chatter that never
    crossed it. A round with no messages contributes exactly 0.
    """
    per_link = xfers.astype(jnp.float32) * (
        lat + jnp.float32(model_bytes) / bw)
    t_models = jnp.max(per_link, initial=0.0)
    slowest_msg = jnp.max(jnp.where(link_msgs > 0, lat, 0.0), initial=0.0)
    return t_models + 2.0 * slowest_msg
