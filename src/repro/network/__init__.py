# The simulated network environment: peer topologies, per-round learner
# availability, and link-cost accounting. Everything here is pure JAX so it
# composes with the scanned protocol engine (one compiled program per chunk).
from repro.network import availability, cost, events, topology  # noqa: F401
from repro.network.availability import sample as sample_availability  # noqa: F401
from repro.network.cost import link_profile, round_network_time  # noqa: F401
from repro.network.topology import adjacency  # noqa: F401
