import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) program.

The two lines above run before ANY other import (jax locks the device count
on first init): the dry-run — and only the dry-run — sees 512 placeholder
host devices so ``jax.make_mesh`` can build the production meshes.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out-dir experiments/dryrun
    python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k \
        --mesh multi --mode train_dynamic

Per program it prints/records ``compiled.memory_analysis()`` (proves the
per-device footprint), ``compiled.cost_analysis()`` (FLOPs/bytes for the
roofline) and the parsed collective schedule.
"""
import argparse
import gc
import json
import time
import traceback

import jax

from repro.analysis import roofline as rl
from repro.config import INPUT_SHAPES, get_arch
from repro.configs import ASSIGNED_ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_program
from repro.telemetry import console_handler, get_logger

# long_500k needs sub-quadratic decode; pure full-attention archs skip it
# (DESIGN.md §Arch-applicability). llama3-8b-swa is the sliding-window
# VARIANT of a dense arch that makes the 524k shape tractable (the
# assignment's dense-arch carve-out).
LONG_CONTEXT_ARCHS = (
    "mamba2-2.7b", "hymba-1.5b", "mixtral-8x22b", "llama3-8b-swa")


def pairs_for(arch: str):
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
            continue
        yield shape


def _compile(cfg, shape, mesh, mode):
    prog = build_program(cfg, shape, mesh, mode=mode)
    with mesh:
        lowered = jax.jit(
            prog.fn, in_shardings=prog.in_shardings,
            out_shardings=prog.out_shardings).lower(*prog.args)
        compiled = lowered.compile()
    return prog, compiled


def _costs(compiled, mesh) -> tuple:
    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    stats = __import__("repro.analysis.hlo", fromlist=["hlo"]).parse_collectives(
        compiled.as_text(), mesh.size)
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(stats.total_wire_bytes))


def run_one(arch: str, shape_name: str, mesh_kind: str, mode: str = "auto",
            verbose: bool = True, calibrate: bool = True) -> dict:
    """Lower+compile one (arch, shape, mesh) program and derive its roofline.

    XLA's cost analysis counts a ``while``-loop body once regardless of trip
    count, so the scan-over-layers model under-reports per-step cost. We
    therefore compile the REAL program (scan, full depth) for the artifact +
    memory analysis, plus two small UNROLLED variants (1 and 2 layers) whose
    cost difference calibrates the true per-layer flops/bytes/collectives:
        total(L) = cost(L=1) + (L - 1) * (cost(L=2) - cost(L=1)).
    """
    import dataclasses
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.perf_counter()
    prog, compiled = _compile(cfg, shape, mesh, mode)
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    report = rl.analyze(
        f"{prog.name}@{mesh_kind}", compiled, mesh.size,
        model_flops=rl.model_flops_for(cfg, shape, prog.meta["mode"]))

    if calibrate:
        c1cfg = dataclasses.replace(cfg, num_layers=1, scan_layers=False)
        c2cfg = dataclasses.replace(cfg, num_layers=2, scan_layers=False)
        _, comp1 = _compile(c1cfg, shape, mesh, mode)
        _, comp2 = _compile(c2cfg, shape, mesh, mode)
        f1, b1, w1 = _costs(comp1, mesh)
        f2, b2, w2 = _costs(comp2, mesh)
        L = cfg.num_layers
        report.flops_per_chip = f1 + (L - 1) * max(f2 - f1, 0.0)
        report.bytes_per_chip = b1 + (L - 1) * max(b2 - b1, 0.0)
        report.wire_bytes_per_chip = w1 + (L - 1) * max(w2 - w1, 0.0)
        report.compute_s = report.flops_per_chip / rl.PEAK_FLOPS_BF16
        report.memory_s = report.bytes_per_chip / rl.HBM_BW
        report.collective_s = report.wire_bytes_per_chip / rl.ICI_BW_PER_LINK
        terms = {"compute": report.compute_s, "memory": report.memory_s,
                 "collective": report.collective_s}
        report.bottleneck = max(terms, key=terms.get)
        if report.model_flops:
            report.useful_fraction = report.model_flops / (
                report.flops_per_chip * mesh.size)
        del comp1, comp2
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mode": prog.meta["mode"], "num_devices": mesh.size,
        "compile_s": round(t_compile, 2),
        "ok": True,
        "calibrated": calibrate,
        "roofline": report.as_dict(),
    }
    if verbose:
        # structured events, not prints: this is library code — the CLI
        # entry points attach the text formatter (repro.telemetry)
        log = get_logger()
        log.event("dryrun_program", program=prog.name, mesh=mesh_kind,
                  chips=mesh.size, compile_s=t_compile)
        log.event("dryrun_memory", program=prog.name,
                  memory_analysis=str(mem))
        log.event("dryrun_roofline", program=prog.name,
                  flops_per_chip=report.flops_per_chip,
                  bytes_per_chip=report.bytes_per_chip,
                  wire_bytes_per_chip=report.wire_bytes_per_chip,
                  compute_s=report.compute_s, memory_s=report.memory_s,
                  collective_s=report.collective_s,
                  bottleneck=report.bottleneck,
                  collectives=str(report.collectives["by_kind"]))
    del compiled
    gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--mode", default="auto")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    # the CLI is where events become text: attach the console formatter
    get_logger().add_handler(console_handler())
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        if args.mode.startswith("train"):
            jobs = [(a, "train_4k") for a in ASSIGNED_ARCHS]
        else:
            jobs = [(a, s) for a in ASSIGNED_ARCHS for s in pairs_for(a)]
    else:
        if not (args.arch and args.shape):
            raise SystemExit("--arch/--shape or --all")
        jobs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in jobs:
        for mk in meshes:
            tag = f"{arch}_{shape}_{mk}_{args.mode}".replace("/", "-")
            path = os.path.join(args.out_dir, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"-- skip {tag} (exists)")
                continue
            try:
                rec = run_one(arch, shape, mk, args.mode)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "mode": args.mode, "ok": False, "error": repr(e)}
                failures.append(tag)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete:", len(jobs), "pairs x", len(meshes), "meshes")


if __name__ == "__main__":
    main()
