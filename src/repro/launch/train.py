"""Production launcher: train any assigned architecture on the mesh.

On real hardware this runs the same ``build_program`` programs the dry-run
compiles, executing them with on-device data. On CPU it runs reduced
variants end-to-end (--smoke) — the same code path, small shapes:

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --mode train_dynamic --steps 20

``--mode train`` is the sigma_1-consistent data-parallel baseline;
``--mode train_dynamic`` is the paper's protocol with one learner per pod
(or an unsharded learner axis on CPU/smoke runs).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import (
    INPUT_SHAPES, ProtocolConfig, ShapeConfig, TrainConfig, get_arch,
)
from repro.telemetry import console_handler, get_logger
from repro.core.distributed import (
    init_dynamic_state, make_dynamic_train_step, make_periodic_train_step,
)
from repro.data.synthetic import TokenStream
from repro.models.model import init_lm_params, lm_loss
from repro.train.step import make_train_step


def smoke_shape(cfg) -> ShapeConfig:
    return ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")


def make_batch(cfg, key, batch: int, seq: int, stream: TokenStream):
    if cfg.modality == "audio":
        toks = jax.random.randint(key, (batch, seq, 4), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    b = stream.sample(key, batch, seq)
    if cfg.modality == "vision":
        b["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 3), (batch, 8, cfg.d_model))
    return b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="train",
                    choices=("train", "train_dynamic", "train_periodic"))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shapes (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--learners", type=int, default=2)
    ap.add_argument("--delta", type=float, default=10.0)
    ap.add_argument("--b", type=int, default=5)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    shape = smoke_shape(cfg) if args.smoke else INPUT_SHAPES["train_4k"]
    train = TrainConfig(optimizer="adam", learning_rate=args.lr)
    loss_fn = lambda p, b: lm_loss(cfg, p, b)
    stream = TokenStream(seed=0, vocab=cfg.vocab_size)
    key = jax.random.PRNGKey(0)

    if args.mode == "train":
        init_state, step = make_train_step(loss_fn, train)
        state = init_state(init_lm_params(cfg, key))
        jstep = jax.jit(step)

        def next_batch(k):
            return make_batch(cfg, k, shape.global_batch, shape.seq_len,
                              stream)
    else:
        m = args.learners
        proto = ProtocolConfig(kind="dynamic", b=args.b, delta=args.delta)
        mk = (make_dynamic_train_step if args.mode == "train_dynamic"
              else make_periodic_train_step)
        jstep = jax.jit(mk(loss_fn, proto, train, m))
        state = init_dynamic_state(
            lambda k: init_lm_params(cfg, k), key, m, train)

        def next_batch(k):
            per = max(shape.global_batch // m, 1)
            bs = [make_batch(cfg, jax.random.fold_in(k, i), per,
                             shape.seq_len, stream) for i in range(m)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

    # progress goes through the telemetry event logger: the loop emits
    # structured events; THIS entry point attaches the text formatter
    log = get_logger()
    handler = log.add_handler(console_handler())
    t0 = time.perf_counter()
    try:
        for t in range(args.steps):
            key, sub = jax.random.split(key)
            state, metrics = jstep(state, next_batch(sub))
            fields = {"step": t + 1, "loss": float(metrics["loss"])}
            if "synced" in metrics:
                fields["synced"] = int(metrics["synced"])
            log.event("train_step", **fields)
        log.event("train_done", steps=args.steps,
                  seconds=time.perf_counter() - t0, mode=args.mode,
                  arch=cfg.name)
    finally:
        log.remove_handler(handler)


if __name__ == "__main__":
    main()
