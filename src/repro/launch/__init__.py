"""Launcher: production meshes, sharding rules, input specs, dry-run."""
