"""Production meshes (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run entry
point sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
any jax import; everything else sees the real (single) CPU device.

Mesh construction goes through ``repro.compat`` so the ``axis_types``
request degrades gracefully on jax releases without ``AxisType``.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~ per-device ring bw)
