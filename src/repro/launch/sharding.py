"""Sharding rules: param/state/batch PartitionSpecs for the production mesh.

Baseline layout (= continuous/periodic averaging data-parallel training,
consistent with the paper's Proposition 3):

* 2-D weights ``(d_in, d_out)``: FSDP over ``data`` on d_in, tensor-parallel
  over ``model`` on d_out (reversed for the row-parallel output projections
  ``w_o`` / ``w_down`` / ``w_out``).
* MoE expert tables ``(E, d, f)``: experts replicated in ID space, (d, f)
  sharded over (data, model) — the capacity-bucketed dispatch then induces
  the all-to-all-equivalent resharding under GSPMD.
* Embedding ``(V, d)``: vocab over ``model``, d over ``data``.
* Batch: ``("pod", "data")`` (or ``("data",)`` single-pod) on the leading
  batch dim.
* Dynamic-averaging state: a leading learner axis ``m`` sharded over
  ``pod`` — each pod is one of the paper's learners.

Every rule is guarded by divisibility: an axis is applied only when the dim
is divisible by the mesh axis size (e.g. hymba's 25 heads or mamba2's 50280
vocab simply stay unsharded on that dim).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey


# core trailing-dim specs by leaf name: logical axis names per trailing dim,
# counted from the RIGHT (leading L / learner axes are padded with None).
_COL = ("fsdp", "tp")        # (d_in, d_out) column-parallel
_ROW = ("tp", "fsdp")        # (d_in, d_out) row-parallel
_CORE_SPECS = {
    # attention / generic projections
    "w_q": _COL, "w_k": _COL, "w_v": _COL, "w_o": _ROW,
    "w_dq": _COL, "w_uq": _COL, "w_dkv": _COL, "w_krope": _COL,
    "w_uk": _COL, "w_uv": _COL,
    # ffn
    "w_gate": _COL, "w_up": _COL, "w_down": _ROW,
    # ssm
    "w_xz": _COL, "w_bc": _COL, "w_dt": _COL, "w_out": _ROW,
    "conv_w": (None, "tp"),
    # router
    "router": ("fsdp", None),
    # embeddings / head
    "embed": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    # cnn/mlp
    "w": _COL, "kernel": (None, None, "fsdp", "tp"),
    # 1-D / small leaves
    "scale": (None,), "bias": (None,), "b": (None,),
    "b_q": (None,), "b_k": (None,), "b_v": (None,),
    "dt_bias": (None,), "A_log": (None,), "D": (None,), "pos": (None,),
}
# MoE expert tables carry a leading E axis in front of the 2-D core.
# Dense layout (small E): experts replicated in ID space, (d, f) sharded.
_MOE_CORE = {
    "w_gate": (None,) + _COL, "w_up": (None,) + _COL, "w_down": (None,) + _ROW,
}
# Expert-parallel layout (E divisible by the tp axis): experts sharded in ID
# space over tp, FSDP on d; tokens all-to-all to their experts.
_MOE_CORE_EP = {
    "w_gate": ("tp", "fsdp", None), "w_up": ("tp", "fsdp", None),
    "w_down": ("tp", None, "fsdp"),
}

# KV / state caches, by leaf name (leading L axis padded automatically)
_CACHE_SPECS = {
    "k": ("batch", "seq", "tp", None),       # (B, S, Hkv, hd)
    "v": ("batch", "seq", "tp", None),
    "ckv": ("batch", "seq", None),           # MLA latent (B, S, r)
    "krope": ("batch", "seq", None),
    "ssm": ("batch", "tp", None, None),      # (B, H, P, N)
    "conv": ("batch", None, "tp"),           # (B, K-1, C)
    "pos": (None,),
}


def _key_name(k) -> Optional[str]:
    if isinstance(k, DictKey):
        return str(k.key)
    if isinstance(k, SequenceKey):
        return None
    return getattr(k, "name", None)


def _resolve(logical: Optional[str], axes_map: dict) -> Any:
    if logical is None:
        return None
    return axes_map.get(logical)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guarded_spec(dims: Tuple[int, ...], logical: Tuple, mesh,
                  axes_map: dict) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim and
    duplicate mesh axes (a spec may use each mesh axis at most once — the
    first dim that can legally use an axis keeps it)."""
    parts = []
    used: set = set()
    for size, name in zip(dims, logical):
        axis = _resolve(name, axes_map)
        members = (set(axis) if isinstance(axis, tuple)
                   else {axis} if axis is not None else set())
        if (axis is not None and size % _axis_size(mesh, axis) == 0
                and not (members & used)):
            parts.append(axis)
            used |= members
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def default_axes_map(multi_pod: bool = False) -> dict:
    """Logical -> mesh axes for the baseline layout."""
    return {
        "fsdp": "data",
        "tp": "model",
        "batch": ("pod", "data") if multi_pod else "data",
        "seq": "model",
        "learner": "pod",
    }


def param_spec_tree(params_shape, mesh, axes_map: dict,
                    learner_axis: bool = False):
    """PartitionSpec pytree for a (possibly learner-stacked) param tree.

    ``params_shape``: pytree of ShapeDtypeStruct (or arrays).
    ``learner_axis``: leaves carry a leading m axis -> sharded over
    ``axes_map['learner']``.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        names = [n for n in (_key_name(k) for k in path) if n]
        name = names[-1] if names else ""
        in_moe = "moe" in names and "shared" not in names
        if in_moe and name in _MOE_CORE:
            # expert-parallel layout when the E axis divides the tp axis
            e_dim = leaf.shape[-3]
            tp_size = _axis_size(mesh, _resolve("tp", axes_map))
            core = (_MOE_CORE_EP[name] if e_dim % tp_size == 0
                    else _MOE_CORE[name])
        else:
            core = _CORE_SPECS.get(name)
        if core is None:
            core = (None,) * leaf.ndim
        ndim = leaf.ndim
        ncore = min(len(core), ndim)
        lead = ndim - ncore
        logical = [None] * lead + list(core[-ncore:] if ncore else [])
        if learner_axis and lead >= 1:
            logical[0] = "learner"
        specs.append(_guarded_spec(leaf.shape, tuple(logical), mesh, axes_map))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_spec_tree(cache_shape, mesh, axes_map: dict):
    """PartitionSpec pytree for a stacked (L-leading) decode cache."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = []
    for path, leaf in flat:
        names = [n for n in (_key_name(k) for k in path) if n]
        name = names[-1] if names else ""
        core = _CACHE_SPECS.get(name, (None,) * leaf.ndim)
        ndim = leaf.ndim
        ncore = min(len(core), ndim)
        lead = ndim - ncore
        logical = [None] * lead + list(core[-ncore:] if ncore else [])
        specs.append(_guarded_spec(leaf.shape, tuple(logical), mesh, axes_map))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec_tree(batch_shape, mesh, axes_map: dict,
                    learner_axis: bool = False):
    """Batch pytree: leading dim(s) over (learner,) batch axes."""
    def spec(leaf):
        logical: list = ["batch"] + [None] * (leaf.ndim - 1)
        if learner_axis:
            logical = ["learner"] + logical[:leaf.ndim - 1]
        return _guarded_spec(leaf.shape, tuple(logical[:leaf.ndim]), mesh,
                             axes_map)
    return jax.tree.map(spec, batch_shape)


def replicated(tree, mesh):
    return jax.tree.map(lambda _: P(), tree)


def to_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def activation_rules(axes_map: dict) -> dict:
    """Rules consumed by ``repro.pjit_utils.mesh_context`` for the logical
    names used by ``constrain`` calls inside model code."""
    return {
        "batch": axes_map["batch"],
        "heads": axes_map["tp"],
        "kv_heads": axes_map["tp"],
        "ffn": axes_map["tp"],
        # expert parallelism: expert-ID axis over the tp axis when divisible
        # (the guard in logical_to_spec drops it otherwise)
        "expert": axes_map["tp"],
        "vocab": axes_map["tp"],
        "embed": axes_map["fsdp"],
        # JIT weight-gather target: keep the tensor-parallel dim sharded,
        # unshard the FSDP (contraction) dim right before each matmul.
        "tp": axes_map["tp"],
    }
