"""Programs + input specs for the dry-run and launchers.

``build_program(cfg, shape, mesh, mode)`` returns a ``Program``: the step
function, ShapeDtypeStruct stand-ins for every input (weak-type-correct, no
device allocation), and in/out shardings — ready for
``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*args)``.

Modes
-----
* ``train``          — baseline data-parallel train step (sigma_1-equivalent
                       per Prop. 3); lowered by ``train_4k``.
* ``train_dynamic``  — the paper's dynamic averaging protocol: m learners
                       (one per pod), conditional weight-averaging collective.
* ``train_periodic`` — sigma_b in the same learner layout (A/B reference).
* ``prefill``        — full forward; lowered by ``prefill_32k``.
* ``decode``         — one token against a seq_len-deep cache; lowered by
                       ``decode_32k`` / ``long_500k``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (
    ModelConfig, ProtocolConfig, ShapeConfig, TrainConfig,
    MODALITY_AUDIO, MODALITY_VISION,
)
from repro.core.distributed import (
    DynamicTrainState, make_dynamic_train_step, make_periodic_train_step,
)
from repro.launch import sharding as shd
from repro.models.model import (
    AUDIO_CODEBOOKS, init_lm_cache, init_lm_params, lm_loss,
)
from repro.pjit_utils import mesh_context
from repro.serve.engine import make_decode_step, make_prefill
from repro.train.step import TrainState, make_train_step

VISION_PREFIX_TOKENS = 256


@dataclass
class Program:
    name: str
    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: init_lm_params(cfg, k, _dtype(cfg)),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Training/prefill batch ShapeDtypeStructs for the arch's modality."""
    i32 = jnp.int32
    if cfg.modality == MODALITY_AUDIO:
        return {"tokens": _sds((batch, seq, AUDIO_CODEBOOKS), i32),
                "labels": _sds((batch, seq, AUDIO_CODEBOOKS), i32)}
    if cfg.modality == MODALITY_VISION:
        s_text = seq - VISION_PREFIX_TOKENS
        return {"tokens": _sds((batch, s_text), i32),
                "labels": _sds((batch, s_text), i32),
                "prefix_embeds": _sds(
                    (batch, VISION_PREFIX_TOKENS, cfg.d_model), _dtype(cfg))}
    return {"tokens": _sds((batch, seq), i32),
            "labels": _sds((batch, seq), i32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    if shape.kind == "decode":
        tok = (_sds((shape.global_batch, AUDIO_CODEBOOKS), jnp.int32)
               if cfg.modality == MODALITY_AUDIO
               else _sds((shape.global_batch,), jnp.int32))
        cache = jax.eval_shape(
            lambda: init_lm_cache(cfg, shape.global_batch, shape.seq_len,
                                  _dtype(cfg)))
        return {"token": tok, "cache": cache,
                "pos": _sds((), jnp.int32)}
    b = batch_struct(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        b.pop("labels")
    return b


def _with_mesh(fn, mesh, rules):
    @functools.wraps(fn)
    def wrapped(*a):
        with mesh_context(mesh, rules):
            return fn(*a)
    return wrapped


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# per-mode program builders
# ---------------------------------------------------------------------------

def build_program(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  mode: str = "auto",
                  train: Optional[TrainConfig] = None,
                  proto: Optional[ProtocolConfig] = None) -> Program:
    multi_pod = "pod" in mesh.axis_names
    axes = shd.default_axes_map(multi_pod)
    rules = shd.activation_rules(axes)
    train = train or TrainConfig(optimizer="sgd", remat=True)
    proto = proto or ProtocolConfig(kind="dynamic", b=10, delta=1.0)

    if mode == "auto":
        mode = {"train": "train", "prefill": "prefill",
                "decode": "decode"}[shape.kind]

    p_struct = params_struct(cfg)
    p_spec = shd.param_spec_tree(p_struct, mesh, axes)

    if mode == "train":
        loss_fn = lambda p, b: lm_loss(cfg, p, b, remat=train.remat)
        init_state, step = make_train_step(loss_fn, train)
        state = jax.eval_shape(init_state, p_struct)
        state_spec = TrainState(
            params=p_spec,
            opt_state=jax.tree.map(lambda _: P(), state.opt_state),
            step=P())
        b_struct = batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_spec = shd.batch_spec_tree(b_struct, mesh, axes)
        return Program(
            name=f"{cfg.name}:{shape.name}:train",
            fn=_with_mesh(step, mesh, rules),
            args=(state, b_struct),
            in_shardings=(_named(state_spec, mesh), _named(b_spec, mesh)),
            out_shardings=(_named(state_spec, mesh),
                           _named({"loss": P()}, mesh)),
            meta={"mode": "train", "multi_pod": multi_pod})

    if mode in ("train_dynamic", "train_periodic"):
        m = mesh.shape["pod"] if multi_pod else 2
        if multi_pod:
            # the pod axis is consumed by the learner dim; within a learner
            # the batch shards over data only.
            axes = dict(axes, batch="data")
        else:
            # single-pod: learners = halves of the data axis is not modeled;
            # the learner axis is simply unsharded (m small).
            axes = dict(axes, learner=None)
        loss_fn = lambda p, b: lm_loss(cfg, p, b, remat=train.remat)
        mk = (make_dynamic_train_step if mode == "train_dynamic"
              else make_periodic_train_step)
        # §Perf: propagate per-learner sharding constraints through the vmap
        # (spmd_axis_name) so the within-learner layout matches the baseline
        step = mk(loss_fn, proto, train, m,
                  spmd_axis_name="pod" if multi_pod else None)
        if multi_pod:
            step = _with_mesh(step, mesh, shd.activation_rules(axes))
        stacked = jax.tree.map(
            lambda l: _sds((m,) + l.shape, l.dtype), p_struct)
        from repro.optim import make_optimizer
        opt_state = jax.eval_shape(
            lambda p: jax.vmap(make_optimizer(train).init)(p), stacked)
        z = _sds((), jnp.int32)
        state = DynamicTrainState(stacked, opt_state, p_struct, z, z, z)
        sp_stacked = shd.param_spec_tree(stacked, mesh, axes,
                                         learner_axis=True)
        sp_opt = jax.tree.map(lambda _: P(), opt_state)
        state_spec = DynamicTrainState(
            sp_stacked, sp_opt, p_spec, P(), P(), P())
        per = shape.global_batch // m
        b_struct = jax.tree.map(
            lambda l: _sds((m, per) + l.shape[1:], l.dtype),
            batch_struct(cfg, shape.global_batch, shape.seq_len))
        b_spec = shd.batch_spec_tree(b_struct, mesh, axes, learner_axis=True)
        out_metrics = {"loss": P(), "synced": P()}
        if mode == "train_dynamic":
            out_metrics.update({"loss_per_learner": P(), "max_sq_dist": P()})
        return Program(
            name=f"{cfg.name}:{shape.name}:{mode}",
            fn=step,   # no mesh_context: constraints inside vmap are skipped
            args=(state, b_struct),
            in_shardings=(_named(state_spec, mesh), _named(b_spec, mesh)),
            out_shardings=(_named(state_spec, mesh),
                           _named(out_metrics, mesh)),
            meta={"mode": mode, "m": m, "multi_pod": multi_pod})

    if mode == "prefill":
        fn = make_prefill(cfg)
        b_struct = batch_struct(cfg, shape.global_batch, shape.seq_len)
        b_struct.pop("labels")
        tok = b_struct["tokens"]
        b_spec = shd.batch_spec_tree(b_struct, mesh, axes)
        if cfg.modality == MODALITY_VISION:
            from repro.models.model import lm_apply
            fn = lambda p, t, pe: lm_apply(cfg, p, t, prefix_embeds=pe)[0]
            args = (p_struct, tok, b_struct["prefix_embeds"])
            in_sh = (_named(p_spec, mesh), _named(b_spec["tokens"], mesh),
                     _named(b_spec["prefix_embeds"], mesh))
        else:
            args = (p_struct, tok)
            in_sh = (_named(p_spec, mesh), _named(b_spec["tokens"], mesh))
        out_sh = _named(P(axes["batch"], None, None), mesh)
        return Program(
            name=f"{cfg.name}:{shape.name}:prefill",
            fn=_with_mesh(fn, mesh, rules),
            args=args, in_shardings=in_sh, out_shardings=out_sh,
            meta={"mode": "prefill", "multi_pod": multi_pod})

    if mode == "decode":
        spec_in = input_specs(cfg, shape)
        step = make_decode_step(cfg)
        cache_spec = shd.cache_spec_tree(spec_in["cache"], mesh, axes)
        tok_spec = shd.batch_spec_tree(spec_in["token"], mesh, axes)
        fn = lambda p, c, t, pos: step(p, c, t, pos)
        # §Perf: decode moves a handful of tokens — leave weights sharded
        # (no JIT weight-gather) and let the tiny activations all-reduce
        rules = dict(rules, _gather_weights=False)
        return Program(
            name=f"{cfg.name}:{shape.name}:decode",
            fn=_with_mesh(fn, mesh, rules),
            args=(p_struct, spec_in["cache"], spec_in["token"],
                  spec_in["pos"]),
            in_shardings=(_named(p_spec, mesh), _named(cache_spec, mesh),
                          _named(tok_spec, mesh), NamedSharding(mesh, P())),
            out_shardings=None,
            meta={"mode": "decode", "multi_pod": multi_pod})

    raise ValueError(mode)
