"""The device-sharded fleet plane (ISSUE 8): ``layout="sharded"`` is the
flat ``(m, P)`` plane with the learner axis split over a device mesh —
same ``ProtocolSpec`` compile, third execution backend.

The equivalence contract under test is the acceptance criterion: for
every registered preset (the six kinds + ``"stale"``), under
availability masks and a two-tier hierarchy, ``layout="sharded"`` must
reproduce ``layout="flat"``'s communication EXACTLY — comm counters,
the per-link bytes ledger, simulated network time — and its parameters
to float-reassociation tolerance. A sharded and a flat run of the same
spec with ``TelemetryConfig`` attached must stream interchangeable
JSONL round records, and checkpoint-resume counter continuity must
survive the sharded carry.

On one device every constraint is a no-op placement, so sharded == flat
bitwise; the multi-device tests (skipped unless >1 device is visible —
CI forces 8 host devices via ``XLA_FLAGS``) additionally assert the
carry is REALLY split across the mesh and the same equalities hold
across real per-shard execution.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (
    HierarchyConfig, NetworkConfig, ProtocolConfig, TelemetryConfig,
    TrainConfig, get_arch,
)
from repro.core import shard
from repro.core.protocol import DecentralizedLearner
from repro.core.sync.spec import (
    LAYOUTS, PLANE_LAYOUTS, ProtocolSpec, resolve_spec,
)
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >1 device (CI forces 8 via "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# every registered preset, smallest parameters that make its trigger
# fire within the fixture's horizon (mirrors test_flatten.py)
PRESETS = {
    "nosync": dict(kind="nosync"),
    "periodic": dict(kind="periodic", b=3),
    "continuous": dict(kind="continuous", b=1),
    "fedavg": dict(kind="fedavg", b=2, fedavg_c=0.5),
    "dynamic": dict(kind="dynamic", b=2, delta=0.5),
    "gossip": dict(kind="gossip", b=2),
    "stale": dict(kind="stale"),
}


def _run_engine(proto, rounds=30, m=8, seed=0, telemetry=None):
    cfg = get_arch("drift_mlp", smoke=True)
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=10, seed=seed)
    dl = DecentralizedLearner(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k), m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        network=NetworkConfig(act_prob=0.6, topology="ring",
                              link_classes=("wifi", "lte")),
        telemetry=telemetry)
    dl.run_chunk(streams.next_chunk(rounds))
    return dl


def _assert_comm_equal(a, b):
    assert a.comm_totals == b.comm_totals
    np.testing.assert_array_equal(a.link_xfer_totals, b.link_xfer_totals)
    np.testing.assert_array_equal(a.link_bytes_totals, b.link_bytes_totals)
    assert a.network_time == b.network_time


def _assert_params_close(a, b, rtol=2e-4, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# registration: the third layout is a first-class spec citizen
# ---------------------------------------------------------------------------

def test_sharded_is_a_registered_layout():
    assert "sharded" in LAYOUTS
    assert "sharded" in PLANE_LAYOUTS and "tree" not in PLANE_LAYOUTS
    spec = resolve_spec(ProtocolConfig(kind="dynamic", layout="sharded",
                                       shard_devices=1))
    assert spec.param("layout") == "sharded"
    assert spec.param("shard_devices") == 1
    # serialization round-trips the layout like any other param
    back = ProtocolSpec.from_json(spec.to_json())
    assert back == spec


def test_spec_rejects_bad_shard_devices():
    with pytest.raises(ValueError, match="shard_devices"):
        ProtocolSpec(trigger="divergence", cohort="balanced",
                     aggregate="mean", commit="balancing",
                     params={"b": 2, "delta": 0.5, "shard_devices": -1})


def test_fleet_sharding_validates_divisibility():
    if N_DEV > 1:     # m % 1 == 0 always — nothing to reject on one device
        with pytest.raises(ValueError, match="m % n_devices"):
            shard.fleet_sharding(N_DEV + 1, N_DEV)
    with pytest.raises(ValueError, match="device"):
        shard.fleet_sharding(8, N_DEV + 1)   # more than visible
    fs = shard.fleet_sharding(4 * N_DEV, 0)
    assert fs.n_devices == N_DEV
    assert fs.rows_per_device == 4


def test_engine_rejects_indivisible_fleet():
    if N_DEV == 1:
        pytest.skip("m % 1 == 0 always — nothing to reject on one device")
    with pytest.raises(ValueError, match="m % n_devices"):
        _run_engine(ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                                   layout="sharded"), m=N_DEV + 1)


def test_constrain_rows_is_identity_without_a_fleet():
    x = jnp.ones((4, 3))
    assert shard.constrain_rows(x) is x
    fs = shard.fleet_sharding(4, 1)
    with shard.use_fleet(fs):
        assert shard.current_fleet() is fs
        y = shard.constrain_rows(x)
        assert y.shape == x.shape
        # a non-fleet leading dim (the hierarchy's per-cluster plane)
        # passes through untouched
        z = jnp.ones((2, 3))
        assert shard.constrain_rows(z) is z
    assert shard.current_fleet() is None


# ---------------------------------------------------------------------------
# the acceptance criterion: sharded == flat for every preset
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PRESETS))
def test_sharded_engine_matches_flat_engine(name):
    flat = _run_engine(ProtocolConfig(layout="flat", **PRESETS[name]))
    shd = _run_engine(ProtocolConfig(layout="sharded", **PRESETS[name]))
    _assert_comm_equal(flat, shd)
    _assert_params_close(flat, shd)


def test_sharded_hierarchy_matches_flat():
    tiers = HierarchyConfig(num_clusters=4,
                            inter=ProtocolConfig(kind="periodic", b=6))
    base = dict(kind="dynamic", b=2, delta=0.5, tiers=tiers)
    flat = _run_engine(ProtocolConfig(layout="flat", **base))
    shd = _run_engine(ProtocolConfig(layout="sharded", **base))
    _assert_comm_equal(flat, shd)
    _assert_params_close(flat, shd)


def test_sharded_device_subset_matches_full_mesh():
    """``shard_devices`` caps the mesh; any cap yields the same run."""
    base = dict(kind="dynamic", b=2, delta=0.5)
    full = _run_engine(ProtocolConfig(layout="sharded", **base))
    one = _run_engine(ProtocolConfig(layout="sharded", shard_devices=1,
                                     **base))
    _assert_comm_equal(full, one)
    _assert_params_close(full, one)


# ---------------------------------------------------------------------------
# telemetry: sharded and flat stream interchangeable round records
# ---------------------------------------------------------------------------

def _stream(tmp_path, layout, tag):
    path = str(tmp_path / f"{tag}.jsonl")
    dl = _run_engine(
        ProtocolConfig(kind="dynamic", b=2, delta=0.5, layout=layout),
        telemetry=TelemetryConfig(path=path, per_link=True))
    dl.recorder.close()
    with open(path) as f:
        recs = [json.loads(line) for line in f]
    return dl, recs


def test_telemetry_streams_identical_across_layouts(tmp_path):
    fdl, frecs = _stream(tmp_path, "flat", "flat")
    sdl, srecs = _stream(tmp_path, "sharded", "sharded")
    _assert_comm_equal(fdl, sdl)
    fr = [r for r in frecs if r["kind"] == "round"]
    sr = [r for r in srecs if r["kind"] == "round"]
    assert len(fr) == len(sr) == 30
    for a, b in zip(fr, sr):
        # integer accounting bitwise; float series to float32 resolution
        # (cross-device reductions may reassociate)
        for k, va in a.items():
            vb = b[k]
            if isinstance(va, float):
                np.testing.assert_allclose(va, vb, rtol=1e-5, atol=1e-7)
            else:
                assert va == vb, (k, va, vb)
    # the meta records differ only in the spec's layout param
    fmeta = [r for r in frecs if r["kind"] == "meta"][0]
    smeta = [r for r in srecs if r["kind"] == "meta"][0]
    assert fmeta["spec"]["params"]["layout"] == "flat"
    assert smeta["spec"]["params"]["layout"] == "sharded"


def test_counter_continuity_across_resume_under_sharded_carry(tmp_path):
    """checkpoint counters -> restore into a FRESH sharded engine -> the
    stream continues as one contiguous record (the sharded carry changes
    nothing about host-side counter arithmetic)."""
    path = str(tmp_path / "resume.jsonl")
    proto = ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                           layout="sharded")
    dl = _run_engine(proto, rounds=15,
                     telemetry=TelemetryConfig(path=path, per_link=True))
    dl.recorder.close()
    saved = dl.counters_state()
    assert saved["rounds"] == 15

    dl2 = DecentralizedLearner(
        dl.loss_fn, lambda k: init_cnn_params(
            get_arch("drift_mlp", smoke=True), k), dl.m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        network=NetworkConfig(act_prob=0.6, topology="ring",
                              link_classes=("wifi", "lte")),
        telemetry=TelemetryConfig(path=path, per_link=True, append=True))
    dl2.params, dl2.sync_state = dl.params, dl.sync_state
    dl2.restore_counters(saved)
    assert dl2.comm_totals == dl.comm_totals
    streams = LearnerStreams(GraphicalModelStream(seed=0, drift_prob=0.0),
                             dl.m, batch=10, seed=0)
    streams.next_chunk(15)                       # replay consumed data
    dl2.run_chunk(streams.next_chunk(15))
    dl2.recorder.close()

    with open(path) as f:
        recs = [json.loads(line) for line in f]
    rounds = [r["round"] for r in recs if r["kind"] == "round"]
    assert rounds == list(range(1, 31))          # contiguous across resume
    metas = [r for r in recs if r["kind"] == "meta"]
    assert metas[-1]["resumed_rounds"] == 15
    last = [r for r in recs if r["kind"] == "round"][-1]
    assert last["cum_syncs"] == dl2.comm_totals["syncs"]
    assert last["cum_bytes"] == dl2.comm_bytes()


# ---------------------------------------------------------------------------
# multi-device: the carry is REALLY split (CI: 8 forced host devices)
# ---------------------------------------------------------------------------

@multi_device
def test_sharded_carry_lives_on_all_devices():
    dl = _run_engine(ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                                    layout="sharded"))
    for leaf in jax.tree.leaves(dl.params):
        assert len(leaf.sharding.device_set) == N_DEV, leaf.sharding
        assert leaf.sharding.spec[0] == shard.FLEET_AXIS
    # the reference model replicates; the scalar counters too
    for leaf in jax.tree.leaves(dl.sync_state.ref):
        assert leaf.sharding.is_fully_replicated


@multi_device
@pytest.mark.parametrize("name", ["dynamic", "gossip", "stale"])
def test_sharded_multi_device_matches_flat(name):
    """Same comm accounting across real per-shard execution; parameters
    to reassociation tolerance (cross-device means may re-associate)."""
    flat = _run_engine(ProtocolConfig(layout="flat", **PRESETS[name]))
    shd = _run_engine(ProtocolConfig(layout="sharded", **PRESETS[name]))
    _assert_comm_equal(flat, shd)
    _assert_params_close(flat, shd)


@multi_device
def test_sharded_two_device_subset():
    """shard_devices=2 places the fleet on exactly two devices and still
    reproduces the flat run."""
    flat = _run_engine(ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                                      layout="flat"))
    shd = _run_engine(ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                                     layout="sharded", shard_devices=2))
    leaf = jax.tree.leaves(shd.params)[0]
    assert len(leaf.sharding.device_set) == 2
    _assert_comm_equal(flat, shd)
    _assert_params_close(flat, shd)
