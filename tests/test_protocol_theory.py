"""Theory tests: Proposition 3 (exact), consistency/adaptiveness sanity.

Prop. 3: continuous averaging of m mini-batch-SGD learners (batch B, lr eta)
equals ONE serial mini-batch SGD step with batch mB and lr eta/m — an exact
algebraic identity we verify to float tolerance on a real CNN.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core import operators as ops
from repro.core.divergence import tree_mean
from repro.core.protocol import DecentralizedLearner, SerialLearner
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_loss, init_cnn_params

from conftest import tree_allclose


def _cnn_setup():
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    return cfg, loss_fn, init_fn


def test_proposition3_exact():
    m, B, eta = 4, 8, 0.05
    cfg, loss_fn, init_fn = _cnn_setup()
    src = SyntheticMNIST(seed=0, image_size=14)
    key = jax.random.PRNGKey(1)
    batches = [src.sample(jax.random.fold_in(key, i), B) for i in range(m)]

    params0 = init_fn(jax.random.PRNGKey(2))

    # m learners: one local SGD step each, then average (sigma_1)
    def local_step(p, b):
        g = jax.grad(loss_fn)(p, b)
        # phi^mSGD as in the paper: f - eta * SUM of per-sample gradients
        # (mean-loss grad * B = sum grad)
        return jax.tree.map(lambda x, gg: x - eta * B * gg, p, g)

    locals_ = [local_step(params0, b) for b in batches]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *locals_)
    averaged = tree_mean(stacked)

    # serial: ONE step with batch mB and lr eta/m
    big = jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)
    g = jax.grad(loss_fn)(params0, big)
    serial = jax.tree.map(
        lambda x, gg: x - (eta / m) * (m * B) * gg, params0, g)

    assert tree_allclose(averaged, serial, rtol=1e-4, atol=1e-6)


def test_nosync_divergence_grows_sync_resets():
    """Sanity for Fig 1.1(a): without sync local models diverge; a sync
    brings divergence to ~0."""
    cfg, loss_fn, init_fn = _cnn_setup()
    src = SyntheticMNIST(seed=0, image_size=14)
    m = 4
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, ProtocolConfig(kind="nosync"),
        TrainConfig(optimizer="sgd", learning_rate=0.1),
        track_divergence=True)
    from repro.data.pipeline import LearnerStreams
    streams = LearnerStreams(src, m, batch=8, seed=3)
    divs = [float(dl.step(streams.next()).divergence) for _ in range(10)]
    assert divs[-1] > divs[0]

    dl2 = DecentralizedLearner(
        loss_fn, init_fn, m, ProtocolConfig(kind="continuous", b=1),
        TrainConfig(optimizer="sgd", learning_rate=0.1),
        track_divergence=True)
    streams2 = LearnerStreams(src, m, batch=8, seed=3)
    d = None
    for _ in range(3):
        d = float(dl2.step(streams2.next()).divergence)
    assert d < 1e-8   # post-sync divergence is zero every round


def test_dynamic_comm_bounded_by_periodic_same_b():
    """Adaptiveness sanity: on the same stream, sigma_Delta communicates no
    more than sigma_b (worst case equals it)."""
    cfg, loss_fn, init_fn = _cnn_setup()
    src = SyntheticMNIST(seed=0, image_size=14)
    m, rounds = 6, 40

    def run(proto):
        from repro.data.pipeline import LearnerStreams
        dl = DecentralizedLearner(
            loss_fn, init_fn, m, proto,
            TrainConfig(optimizer="sgd", learning_rate=0.1), seed=0)
        streams = LearnerStreams(src, m, batch=8, seed=5)
        for _ in range(rounds):
            dl.step(streams.next())
        return dl

    periodic = run(ProtocolConfig(kind="periodic", b=5))
    dynamic = run(ProtocolConfig(kind="dynamic", b=5, delta=0.5))
    assert dynamic.comm_bytes() <= periodic.comm_bytes()
    # and with a loose threshold the saving is real
    assert dynamic.comm_bytes() < 0.9 * periodic.comm_bytes()


def test_serial_learner_learns():
    # lr calibrated against measured curves (SGD verified exact vs a NumPy
    # reference; conv init is true Glorot). On this 14x14 task, mean loss
    # over steps 50-60 / steps 0-10 after 60 steps:
    #   lr=0.1 -> 0.61   (plateaus near the 0.5 bar; the seed's flake)
    #   lr=0.2 -> 0.32   (chosen: passes with ~35% margin)
    #   lr=0.3 -> 0.22   (faster but nearer the divergence edge)
    cfg, loss_fn, init_fn = _cnn_setup()
    src = SyntheticMNIST(seed=0, image_size=14)
    sl = SerialLearner(loss_fn, init_fn,
                       TrainConfig(optimizer="sgd", learning_rate=0.2))
    key = jax.random.PRNGKey(0)
    losses = []
    for t in range(60):
        losses.append(float(sl.step(src.sample(jax.random.fold_in(key, t), 32))))
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10])
