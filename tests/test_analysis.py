"""Tests for the HLO collective parser, roofline math, and sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import parse_collectives, count_op
from repro.launch import sharding as shd

HLO_SAMPLE = """
HloModule jit_f

ENTRY %main {
  %param = f32[16,256]{1,0} parameter(0)
  %param.1 = f32[32,256]{1,0} parameter(1)
  %all-gather = f32[256,128]{1,0} all-gather(%param), channel_id=1, replica_groups=[4,2]<=[8], dimensions={0}
  %all-reduce = f32[16,256]{1,0} all-reduce(%param), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %reduce-scatter = f32[4,256]{1,0} reduce-scatter(%param.1), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
  %all-to-all = f32[32,256]{1,0} all-to-all(%param.1), channel_id=4, replica_groups=[2,4]<=[8]
  %collective-permute = f32[16,256]{1,0} collective-permute(%param), channel_id=5, source_target_pairs={{0,1}}
  ROOT %t = (f32[256,128]{1,0}) tuple(%all-gather)
}
"""


def test_parse_collectives_kinds_and_groups():
    stats = parse_collectives(HLO_SAMPLE, num_devices=8)
    kinds = {o.kind: o for o in stats.ops}
    assert set(kinds) == {"all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute"}
    assert kinds["all-gather"].group_size == 2         # [4,2]<=[8]
    assert kinds["all-reduce"].group_size == 4         # explicit {{0..3}}
    assert kinds["reduce-scatter"].group_size == 8


def test_wire_byte_formulas():
    stats = parse_collectives(HLO_SAMPLE, num_devices=8)
    by = {o.kind: o for o in stats.ops}
    b16 = 16 * 256 * 4
    b32 = 32 * 256 * 4
    bag = 256 * 128 * 4
    assert np.isclose(by["all-reduce"].wire_bytes, 2 * b16 * 3 / 4)
    assert np.isclose(by["all-gather"].wire_bytes, bag * 1 / 2)
    assert np.isclose(by["reduce-scatter"].wire_bytes, b32 * 7 / 8)
    assert np.isclose(by["all-to-all"].wire_bytes, b32 * 3 / 4)
    assert np.isclose(by["collective-permute"].wire_bytes, b16)


def test_async_start_counted_once():
    txt = """
  %ag-start = (f32[16,8]{1,0}, f32[64,8]{1,0}) all-gather-start(%p), replica_groups=[1,4]<=[4], dimensions={0}
  %ag-done = f32[64,8]{1,0} all-gather-done(%ag-start)
  %p = f32[16,8]{1,0} parameter(0)
"""
    stats = parse_collectives(txt, num_devices=4)
    assert len(stats.ops) == 1
    # start result tuple minus operand -> gathered bytes
    assert stats.ops[0].result_bytes == 64 * 8 * 4


def test_count_op():
    assert count_op(HLO_SAMPLE, "parameter") == 2
    assert count_op(HLO_SAMPLE, "all-gather") == 1


# ---------------------------------------------------------------------------
# sharding rules (AbstractMesh: no devices needed)
# ---------------------------------------------------------------------------

def _mesh(multi=False):
    from repro.compat import abstract_mesh
    shape = (2, 16, 16) if multi else (16, 16)
    axes = ("pod", "data", "model") if multi else ("data", "model")
    return abstract_mesh(shape, axes)


def test_param_specs_basic():
    mesh = _mesh()
    axes = shd.default_axes_map(False)
    params = {
        "embed": jax.ShapeDtypeStruct((128256, 4096), jnp.bfloat16),
        "lm_head": jax.ShapeDtypeStruct((4096, 128256), jnp.bfloat16),
        "blocks": {
            "attn": {"w_q": jax.ShapeDtypeStruct((32, 4096, 4096),
                                                 jnp.bfloat16)},
            "norm_mix": {"scale": jax.ShapeDtypeStruct((32, 4096),
                                                       jnp.bfloat16)},
            "moe": {"w_gate": jax.ShapeDtypeStruct((32, 8, 4096, 1024),
                                                   jnp.bfloat16)},
        },
    }
    specs = shd.param_spec_tree(params, mesh, axes)
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")
    assert specs["blocks"]["attn"]["w_q"] == P(None, "data", "model")
    assert specs["blocks"]["norm_mix"]["scale"] == P()
    assert specs["blocks"]["moe"]["w_gate"] == P(None, None, "data", "model")


def test_divisibility_guard_drops_axis():
    mesh = _mesh()
    axes = shd.default_axes_map(False)
    params = {"embed": jax.ShapeDtypeStruct((50280, 2560), jnp.float32)}
    specs = shd.param_spec_tree(params, mesh, axes)
    # 50280 % 16 != 0 -> vocab axis dropped; 2560 % 16 == 0 -> kept
    assert specs["embed"] == P(None, "data")


def test_learner_axis_sharding():
    mesh = _mesh(multi=True)
    axes = shd.default_axes_map(True)
    params = {"blocks": {"ffn": {
        "w_gate": jax.ShapeDtypeStruct((2, 32, 4096, 14336), jnp.bfloat16)}}}
    specs = shd.param_spec_tree(params, mesh, axes, learner_axis=True)
    assert specs["blocks"]["ffn"]["w_gate"] == P("pod", None, "data", "model")


def test_batch_specs():
    mesh = _mesh(multi=True)
    axes = shd.default_axes_map(True)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = shd.batch_spec_tree(batch, mesh, axes)
    assert specs["tokens"] == P(("pod", "data"))
    # batch=1 (long_500k): axis dropped
    b1 = {"tokens": jax.ShapeDtypeStruct((1,), jnp.int32)}
    assert shd.batch_spec_tree(b1, mesh, axes)["tokens"] == P()


def test_cache_specs():
    mesh = _mesh()
    axes = shd.default_axes_map(False)
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128),
                                       jnp.bfloat16),
             "ssm": jax.ShapeDtypeStruct((64, 128, 80, 64, 128),
                                         jnp.float32)}
    specs = shd.cache_spec_tree(cache, mesh, axes)
    assert specs["k"] == P(None, "data", "model")          # B, S sharded
    assert specs["ssm"] == P(None, "data", "model")        # B, H sharded


def test_roofline_model_flops():
    from repro.analysis.roofline import model_flops_for
    from repro.config import INPUT_SHAPES, get_arch
    cfg = get_arch("llama3-8b")
    f = model_flops_for(cfg, INPUT_SHAPES["train_4k"], "train")
    tokens = 256 * 4096
    assert np.isclose(f, 6.0 * cfg.active_param_count() * tokens)
    # MoE uses active params only
    moe = get_arch("mixtral-8x22b")
    fm = model_flops_for(moe, INPUT_SHAPES["train_4k"], "train")
    assert fm < 6.0 * moe.param_count() * tokens
