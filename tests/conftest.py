"""Shared fixtures. NOTE: no XLA device-count forcing here — smoke tests and
benches must see the single real CPU device; only the dry-run (and the
subprocess-based dry-run tests) force placeholder devices."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tree_allclose(a, b, rtol=1e-5, atol=1e-6):
    import numpy as np
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))


def make_stacked(key, m, shapes=((4, 3), (7,))):
    """Random m-learner model configuration (list-of-arrays pytree)."""
    ks = jax.random.split(key, len(shapes))
    return {f"w{i}": jax.random.normal(k, (m,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}
