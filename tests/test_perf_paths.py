"""Equivalence tests for the §Perf optimization paths: every optimized
implementation must be numerically interchangeable with the baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import apply_rope


def test_banded_swa_matches_dense_masked():
    """Block-banded sliding-window attention == dense masked attention."""
    cfg = dataclasses.replace(get_arch("mixtral-8x22b", smoke=True),
                              sliding_window=16)
    p = attn.attn_init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64                       # S = 4 * window -> banded path
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.1
    pos = jnp.arange(S, dtype=jnp.int32)
    y_banded = attn.gqa_forward(cfg, p, x, pos)

    q, k, v = attn._project_qkv(cfg, p, x)
    posb = jnp.broadcast_to(pos[None], (B, S))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    mask = attn.causal_mask(posb, posb, cfg.sliding_window)
    hd = cfg.resolved_head_dim
    G = cfg.num_heads // cfg.num_kv_heads
    out = attn._sdpa(q.reshape(B, S, cfg.num_kv_heads, G, hd), k, v, mask,
                     1.0 / np.sqrt(hd))
    y_dense = jnp.einsum("bse,ed->bsd", out.reshape(B, S, -1), p["w_o"])
    np.testing.assert_allclose(np.asarray(y_banded), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("S", [32, 48, 100])
def test_banded_swa_various_lengths(S):
    cfg = dataclasses.replace(get_arch("mixtral-8x22b", smoke=True),
                              sliding_window=16)
    p = attn.attn_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, S, cfg.d_model)) * 0.1
    pos = jnp.arange(S, dtype=jnp.int32)
    # banded path triggers only for S % w == 0 — both paths must agree with
    # a decode replay regardless
    y = attn.gqa_forward(cfg, p, x, pos)
    assert y.shape == (1, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(y)))


def test_moe_gather_dispatch_equals_einsum():
    cfg = get_arch("deepseek-v2-236b", smoke=True)
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.3
    old = moe_mod.GATHER_DISPATCH_MIN_E
    try:
        moe_mod.GATHER_DISPATCH_MIN_E = 1
        y_g, aux_g = moe_mod.moe_apply(cfg, p, x)
        moe_mod.GATHER_DISPATCH_MIN_E = 10 ** 9
        y_e, aux_e = moe_mod.moe_apply(cfg, p, x)
    finally:
        moe_mod.GATHER_DISPATCH_MIN_E = old
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_e),
                               rtol=1e-4, atol=1e-5)
    assert np.isclose(float(aux_g), float(aux_e))


def test_expert_parallel_param_specs():
    """E divisible by tp -> expert-parallel layout; otherwise dense."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import abstract_mesh
    from repro.launch import sharding as shd
    mesh = abstract_mesh((16, 16), ("data", "model"))
    axes = shd.default_axes_map(False)
    params = {"blocks": {"moe": {
        "w_gate": jax.ShapeDtypeStruct((60, 160, 5120, 1536), jnp.bfloat16),
        "w_down": jax.ShapeDtypeStruct((60, 160, 1536, 5120), jnp.bfloat16),
    }}}
    specs = shd.param_spec_tree(params, mesh, axes)
    assert specs["blocks"]["moe"]["w_gate"] == P(None, "model", "data")
    assert specs["blocks"]["moe"]["w_down"] == P(None, "model", None, "data")
    # E = 8: not divisible -> dense (d, f) layout
    params8 = {"blocks": {"moe": {
        "w_gate": jax.ShapeDtypeStruct((56, 8, 6144, 16384), jnp.bfloat16)}}}
    specs8 = shd.param_spec_tree(params8, mesh, axes)
    assert specs8["blocks"]["moe"]["w_gate"] == P(None, None, "data", "model")


def test_spmd_axis_name_dynamic_step_numerics():
    """spmd_axis_name must not change the dynamic step's numerics (CPU,
    no mesh: plain vmap semantics)."""
    from repro.config import ProtocolConfig, TrainConfig
    from repro.core.distributed import (
        init_dynamic_state, make_dynamic_train_step)
    from repro.models.cnn import cnn_loss, init_cnn_params
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    train = TrainConfig(optimizer="sgd", learning_rate=0.1)
    proto = ProtocolConfig(kind="dynamic", b=1, delta=1e9)
    m = 3
    state = init_dynamic_state(
        lambda k: init_cnn_params(cfg, k), jax.random.PRNGKey(0), m, train)
    from repro.data.synthetic import SyntheticMNIST
    src = SyntheticMNIST(seed=0, image_size=14)
    batch = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[src.sample(jax.random.PRNGKey(i), 8) for i in range(m)])
    s1, m1 = make_dynamic_train_step(loss_fn, proto, train, m)(state, batch)
    s2, m2 = make_dynamic_train_step(loss_fn, proto, train, m,
                                     spmd_axis_name=None)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]))


def test_distributed_shim_delegates_to_staged_engine():
    """core.distributed is a shim over the staged ProtocolSpec compile:
    syncs fire exactly on divergence violations, the synced fleet
    collapses onto one model, and the counters stay consistent."""
    from repro.config import ProtocolConfig, TrainConfig
    from repro.core.distributed import (
        init_dynamic_state, make_dynamic_train_step,
        make_periodic_train_step)
    from repro.data.synthetic import SyntheticMNIST
    from repro.models.cnn import cnn_loss, init_cnn_params
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    train = TrainConfig(optimizer="sgd", learning_rate=0.3)
    m = 3
    src = SyntheticMNIST(seed=0, image_size=14)

    def batches(t):
        return jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[src.sample(jax.random.PRNGKey(100 * t + i), 8)
              for i in range(m)])

    def drive(step_fn):
        state = init_dynamic_state(
            lambda k: init_cnn_params(cfg, k), jax.random.PRNGKey(0), m,
            train)
        jstep = jax.jit(step_fn)
        synced = []
        for t in range(4):
            state, metrics = jstep(state, batches(t))
            synced.append(int(metrics["synced"]))
        return state, synced

    # a tiny Delta: the first check (t=2) must violate and average
    proto = ProtocolConfig(kind="dynamic", b=2, delta=1e-6)
    state, synced = drive(make_dynamic_train_step(loss_fn, proto, train, m))
    assert synced == [0, 1, 0, 1]
    assert int(state.syncs) == 2 and int(state.checks) == 2
    # after a sync round every learner carries the same model, and the
    # reference moved to it
    for leaf, ref in zip(jax.tree.leaves(state.params),
                         jax.tree.leaves(state.ref)):
        assert np.allclose(np.asarray(leaf), np.asarray(leaf)[0][None])
        np.testing.assert_array_equal(np.asarray(leaf)[0], np.asarray(ref))

    # a huge Delta: checks run, syncs never fire, the fleet stays diverged
    proto = ProtocolConfig(kind="dynamic", b=2, delta=1e9)
    state, synced = drive(make_dynamic_train_step(loss_fn, proto, train, m))
    assert synced == [0, 0, 0, 0]
    assert int(state.syncs) == 0 and int(state.checks) == 2

    # the periodic baseline averages unconditionally every b rounds and
    # uses the same "synced" metrics key
    proto = ProtocolConfig(kind="periodic", b=2)
    state, synced = drive(make_periodic_train_step(loss_fn, proto, train, m))
    assert synced == [0, 1, 0, 1]
    assert int(state.syncs) == 2


def test_microbatch_accumulation_matches_full_batch():
    """micro_batch gradient accumulation == one full-batch step exactly."""
    from repro.config import TrainConfig
    from repro.models.cnn import cnn_loss, init_cnn_params
    from repro.train.step import make_train_step
    from repro.data.synthetic import SyntheticMNIST
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    params = init_cnn_params(cfg, jax.random.PRNGKey(0))
    src = SyntheticMNIST(seed=0, image_size=14)
    batch = src.sample(jax.random.PRNGKey(1), 16)

    def one_step(micro):
        init_state, step = make_train_step(
            loss_fn, TrainConfig(optimizer="sgd", learning_rate=0.1,
                                 micro_batch=micro))
        state, metrics = jax.jit(step)(init_state(params), batch)
        return state.params, float(metrics["loss"])

    p_full, l_full = one_step(0)
    p_micro, l_micro = one_step(4)
    assert np.isclose(l_full, l_micro, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_micro)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
