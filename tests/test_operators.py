"""Unit + property tests for the synchronization operators (paper §3, Def. 2).

Invariants (DESIGN.md §5):
  1. mean invariance of every operator
  2. divergence <= Delta after sigma_Delta fires
  3. local-condition soundness (Kamp'14 Thm. 6)
  5. worst case: dynamic comm <= periodic comm on the same schedule
  6. Algorithm 2 reduces to Algorithm 1 for equal weights
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import ProtocolConfig
from repro.core import operators as ops
from repro.core.divergence import (
    divergence, local_condition_violated, per_learner_sq_distance, tree_mean,
)

from conftest import make_stacked, tree_allclose


def _mk(m=6, seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    t = make_stacked(k, m)
    return jax.tree.map(lambda x: x * scale, t)


def _state(stacked, seed=0):
    ref = tree_mean(stacked)
    return ops.init_state(ref, seed)


# ---------------------------------------------------------------------------
# 1. mean invariance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", [
    ("periodic", dict(b=1)),
    ("fedavg", dict(b=1, fedavg_c=0.5)),
    ("dynamic", dict(b=1, delta=1e-6)),            # forced sync
    ("dynamic", dict(b=1, delta=1e6)),             # no sync
    ("dynamic", dict(b=1, delta=0.5, augmentation="max_distance")),
    ("dynamic", dict(b=1, delta=0.5, augmentation="random")),
    ("dynamic", dict(b=1, delta=0.5, augmentation="all")),
])
def test_mean_invariance(kind, kw):
    stacked = _mk(m=8, scale=2.0)
    cfg = ProtocolConfig(kind=kind, **kw)
    before = tree_mean(stacked)
    new, _, _, _ = ops.apply_operator(cfg, stacked, _state(stacked))
    after = tree_mean(new)
    assert tree_allclose(before, after, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 2. divergence contract: delta(f) <= Delta after sigma_Delta
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta", [1e-6, 0.1, 1.0, 10.0])
def test_divergence_bounded_after_dynamic(delta):
    stacked = _mk(m=10, scale=3.0)
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=delta)
    state = _state(stacked)
    new, new_state, rec, _ = ops.apply_operator(cfg, stacked, state)
    # after the operator either all local conditions hold w.r.t. the (new)
    # reference, or a full sync happened (divergence 0)
    d = float(divergence(new))
    viol = local_condition_violated(new, new_state.ref, delta)
    if not bool(jnp.any(viol)):
        assert d <= delta + 1e-5
    else:
        # remaining violations are allowed only if no sync was needed
        assert int(rec.syncs) == 1


# ---------------------------------------------------------------------------
# 3. local-condition soundness (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(m=st.integers(2, 12), seed=st.integers(0, 10_000),
       delta=st.floats(0.01, 50.0))
def test_local_condition_soundness(m, seed, delta):
    """If no local condition is violated w.r.t. ANY common reference r,
    then delta(f) <= Delta (Kamp'14 Thm. 6)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    stacked = make_stacked(k1, m)
    ref = jax.tree.map(lambda x: x[0] + 0.1, make_stacked(k2, 1))
    ref = jax.tree.map(lambda x: x, ref)
    dists = per_learner_sq_distance(stacked, ref)
    if bool(jnp.all(dists <= delta)):
        assert float(divergence(stacked)) <= delta + 1e-4


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_divergence_matches_naive(m, seed):
    stacked = make_stacked(jax.random.PRNGKey(seed), m)
    mean = tree_mean(stacked)
    naive = 0.0
    for i in range(m):
        fi = jax.tree.map(lambda x: x[i], stacked)
        naive += sum(
            float(jnp.sum((a - b) ** 2))
            for a, b in zip(jax.tree.leaves(fi), jax.tree.leaves(mean)))
    naive /= m
    assert np.isclose(float(divergence(stacked)), naive, rtol=1e-4)


# ---------------------------------------------------------------------------
# operator mechanics
# ---------------------------------------------------------------------------

def test_periodic_schedule():
    stacked = _mk(m=4)
    cfg = ProtocolConfig(kind="periodic", b=3)
    state = _state(stacked)
    syncs = []
    for t in range(9):
        stacked_new, state, rec, _ = ops.apply_operator(cfg, stacked, state)
        syncs.append(int(rec.syncs))
    assert syncs == [0, 0, 1, 0, 0, 1, 0, 0, 1]


def test_continuous_is_periodic_b1():
    stacked = _mk(m=4, scale=2.0)
    cfg = ProtocolConfig(kind="continuous", b=1)
    new, _, rec, _ = ops.apply_operator(cfg, stacked, _state(stacked))
    mean = tree_mean(stacked)
    for i in range(4):
        fi = jax.tree.map(lambda x: x[i], new)
        assert tree_allclose(fi, mean, rtol=1e-5, atol=1e-6)
    assert int(rec.full_syncs) == 1


def test_fedavg_subset_size():
    m = 10
    stacked = _mk(m=m, scale=2.0)
    cfg = ProtocolConfig(kind="fedavg", b=1, fedavg_c=0.3)
    new, _, rec, _ = ops.apply_operator(cfg, stacked, _state(stacked))
    # exactly ceil(C*m)=3 learners pulled+pushed
    assert int(rec.model_up) == 3 and int(rec.model_down) == 3
    # the other 7 are untouched
    changed = 0
    for i in range(m):
        a = jax.tree.map(lambda x: x[i], new)
        b = jax.tree.map(lambda x: x[i], stacked)
        if not tree_allclose(a, b, rtol=1e-7, atol=1e-8):
            changed += 1
    assert changed == 3


def test_dynamic_no_violation_no_comm():
    stacked = _mk(m=6, scale=1.0)
    ref = tree_mean(stacked)
    # delta larger than any ||f_i - r||^2 -> zero communication
    dmax = float(jnp.max(per_learner_sq_distance(stacked, ref)))
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=dmax * 1.01)
    new, _, rec, _ = ops.apply_operator(cfg, stacked, ops.init_state(ref))
    assert int(rec.model_up) == 0 and int(rec.model_down) == 0
    assert tree_allclose(new, stacked)


def test_dynamic_partial_balancing_cheaper_than_full():
    """With one outlier learner, balancing should average a subset, not all."""
    m = 8
    stacked = _mk(m=m, scale=0.01)
    ref = tree_mean(stacked)
    # push learner 0 out of the safe zone — by an amount a small subset can
    # balance: ||mean_B - r||^2 ~ off^2 * n_params / |B|^2 <= Delta for
    # |B| ~ 3 (n_params = 19, off = 0.15)
    stacked = jax.tree.map(
        lambda x: x.at[0].set(x[0] + 0.15), stacked)
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=0.05,
                         augmentation="max_distance")
    new, state, rec, _ = ops.apply_operator(cfg, stacked, ops.init_state(ref))
    assert int(rec.syncs) == 1
    assert int(rec.model_up) < m            # partial, not full
    assert int(rec.full_syncs) == 0
    # the balanced subset satisfies the safe-zone condition afterwards
    d = per_learner_sq_distance(new, state.ref)
    assert float(jnp.max(d)) <= 0.2         # outlier got pulled in


def test_dynamic_worst_case_full_sync_bounded_by_periodic():
    """Invariant 5: per round, dynamic transfers <= periodic's 2m."""
    m = 6
    stacked = _mk(m=m, scale=10.0)
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=1e-8)
    _, _, rec, _ = ops.apply_operator(cfg, stacked, _state(stacked))
    assert int(rec.model_up) + int(rec.model_down) <= 2 * m


def test_violation_counter_forces_full_sync():
    """Algorithm 1: when the violation counter reaches m, B <- [m]."""
    m = 4
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=0.05,
                         augmentation="max_distance")
    stacked = _mk(m=m, scale=0.01)
    state = ops.init_state(tree_mean(stacked))
    full_syncs = 0
    for t in range(30):
        # keep perturbing one learner so violations accumulate
        stacked = jax.tree.map(
            lambda x: x.at[t % m].add(0.4), stacked)
        stacked, state, rec, _ = ops.apply_operator(cfg, stacked, state)
        full_syncs += int(rec.full_syncs)
    assert full_syncs >= 1


# ---------------------------------------------------------------------------
# 6. weighted averaging (Algorithm 2)
# ---------------------------------------------------------------------------

def test_weighted_reduces_to_unweighted():
    stacked = _mk(m=5, scale=2.0)
    cfg_w = ProtocolConfig(kind="dynamic", b=1, delta=1e-6, weighted=True)
    cfg_u = ProtocolConfig(kind="dynamic", b=1, delta=1e-6)
    w = jnp.full((5,), 7.0)
    new_w, _, _, _ = ops.apply_operator(cfg_w, stacked, _state(stacked), w)
    new_u, _, _, _ = ops.apply_operator(cfg_u, stacked, _state(stacked))
    assert tree_allclose(new_w, new_u, rtol=1e-5, atol=1e-6)


def test_weighted_mean_is_sample_weighted():
    m = 3
    stacked = _mk(m=m, scale=1.0)
    w = jnp.asarray([1.0, 2.0, 3.0])
    cfg = ProtocolConfig(kind="periodic", b=1, weighted=True)
    new, _, _, _ = ops.apply_operator(cfg, stacked, _state(stacked), w)
    expect = jax.tree.map(
        lambda x: jnp.einsum("m...,m->...", x, w) / jnp.sum(w), stacked)
    got = jax.tree.map(lambda x: x[0], new)
    assert tree_allclose(got, expect, rtol=1e-5, atol=1e-6)
