"""Dry-run mechanics on a tiny mesh (subprocess: the forced device count
must be set before jax initializes, so these tests shell out)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)


@pytest.mark.slow
def test_tiny_mesh_train_and_dynamic_lower():
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax
        from repro.compat import make_mesh
        from repro.config import ShapeConfig, get_arch
        from repro.launch.specs import build_program
        from repro.analysis.hlo import parse_collectives

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_arch("llama3-8b", smoke=True)
        shape = ShapeConfig("tiny", seq_len=64, global_batch=8, kind="train")
        out = {}
        for mode in ("train", "train_dynamic", "train_periodic"):
            prog = build_program(cfg, shape, mesh, mode=mode)
            with mesh:
                c = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                            out_shardings=prog.out_shardings
                            ).lower(*prog.args).compile()
            stats = parse_collectives(c.as_text(), mesh.size)
            out[mode] = {k: v["count"]
                         for k, v in stats.summary()["by_kind"].items()}
        print("RESULT:" + json.dumps(out))
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    res = json.loads(r.stdout.split("RESULT:")[1])
    # every mode lowered; the dynamic mode's sync path emits collectives
    assert set(res) == {"train", "train_dynamic", "train_periodic"}
    assert sum(res["train_dynamic"].values()) > 0


@pytest.mark.slow
def test_tiny_mesh_decode_and_prefill_lower():
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.compat import make_mesh
        from repro.config import ShapeConfig, get_arch
        from repro.launch.specs import build_program

        mesh = make_mesh((4, 2), ("data", "model"))
        for arch in ("llama3-8b", "mamba2-2.7b", "deepseek-v2-236b"):
            cfg = get_arch(arch, smoke=True)
            for kind, shape in [
                ("prefill", ShapeConfig("p", 64, 8, "prefill")),
                ("decode", ShapeConfig("d", 64, 8, "decode")),
            ]:
                prog = build_program(cfg, shape, mesh)
                with mesh:
                    c = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                                out_shardings=prog.out_shardings
                                ).lower(*prog.args).compile()
                assert c.cost_analysis() is not None
        print("RESULT:ok")
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RESULT:ok" in r.stdout


@pytest.mark.slow
def test_dynamic_step_executes_and_syncs_on_tiny_mesh():
    """Numerically execute the SPMD dynamic-averaging step: no sync while
    divergence < Delta, full averaging once it crosses (worst case the HLO
    always contains the collective; execution takes the gated branch)."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.config import ProtocolConfig, TrainConfig, get_arch
        from repro.core.distributed import (
            init_dynamic_state, make_dynamic_train_step)
        from repro.models.model import init_lm_params, lm_loss

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_arch("llama3-8b", smoke=True)
        m = 2
        loss_fn = lambda p, b: lm_loss(cfg, p, b)
        proto = ProtocolConfig(kind="dynamic", b=2, delta=1e-4)
        step = make_dynamic_train_step(
            loss_fn, proto, TrainConfig(optimizer="sgd", learning_rate=0.5), m)
        state = init_dynamic_state(
            lambda k: init_lm_params(cfg, k), jax.random.PRNGKey(0), m,
            TrainConfig(optimizer="sgd", learning_rate=0.5))
        kb = jax.random.PRNGKey(1)
        toks = jax.random.randint(kb, (m, 4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        with mesh:
            jstep = jax.jit(step)
            syncs = []
            for t in range(4):
                state, metrics = jstep(state, batch)
                syncs.append(int(metrics["synced"]))
        # checks happen at t=2 and t=4; lr is large so divergence crosses
        assert sum(syncs) >= 1, syncs
        assert int(state.syncs) == sum(syncs)
        print("RESULT:ok", syncs)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RESULT:ok" in r.stdout


@pytest.mark.slow
def test_sharded_engine_matches_flat_on_forced_devices():
    """The device-sharded fleet plane (layout="sharded") reproduces the
    single-device flat plane on a real 8-device mesh: the committed carry
    is actually split over all devices, comm counters and the per-link
    ledger match bitwise, and parameters match to reassociation
    tolerance. (The manual-collective shard_map prototype this test used
    to cover is retired — the staged engine is the one implementation.)"""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import (
            NetworkConfig, ProtocolConfig, TrainConfig, get_arch)
        from repro.core.protocol import DecentralizedLearner
        from repro.data.pipeline import LearnerStreams
        from repro.data.synthetic import GraphicalModelStream

        from repro.models.cnn import cnn_loss, init_cnn_params
        assert len(jax.devices()) == 8
        cfg = get_arch("drift_mlp", smoke=True)

        def run(layout):
            src = GraphicalModelStream(seed=0, drift_prob=0.0)
            m = 8
            streams = LearnerStreams(src, m, batch=8, seed=0)
            dl = DecentralizedLearner(
                lambda p, b: cnn_loss(cfg, p, b),
                lambda k: init_cnn_params(cfg, k), m,
                ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                               layout=layout),
                TrainConfig(optimizer="sgd", learning_rate=0.05),
                network=NetworkConfig(act_prob=0.6, topology="ring",
                                      link_classes=("wifi", "lte")))
            dl.run_chunk(streams.next_chunk(20))
            return dl

        flat, shd = run("flat"), run("sharded")
        leaf = jax.tree.leaves(shd.params)[0]
        assert len(leaf.sharding.device_set) == 8, leaf.sharding
        assert flat.comm_totals == shd.comm_totals
        assert np.array_equal(flat.link_bytes_totals,
                              shd.link_bytes_totals)
        for a, b in zip(jax.tree.leaves(flat.params),
                        jax.tree.leaves(shd.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-6)
        print("RESULT:ok")
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RESULT:ok" in r.stdout
