"""Network environment subsystem (ISSUE 2): topologies, availability,
link costs, availability-aware operators, and the engine regression.

The load-bearing test is the scan_driver-style regression: with full
availability and a star topology the engine must reproduce the
pre-network engine's comm counters BITWISE and its losses exactly —
the network subsystem is strictly additive on an ideal network.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import NetworkConfig, ProtocolConfig, TrainConfig, get_arch
from repro.core import operators as ops
from repro.core.divergence import tree_mean, tree_weighted_mean
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.network import availability, cost, topology
from repro.train.loop import run_protocol_training

from conftest import make_stacked, tree_allclose


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo,kw", [
    ("star", {}),
    ("ring", {}),
    ("torus", {}),
    ("erdos_renyi", dict(er_p=0.5)),
    ("geometric", dict(geo_radius=0.5)),
])
def test_adjacency_well_formed(topo, kw):
    m = 12
    net = NetworkConfig(topology=topo, **kw)
    adj = np.asarray(topology.adjacency(net, m))
    assert adj.shape == (m, m) and adj.dtype == bool
    assert (adj == adj.T).all(), "must be symmetric"
    assert not adj.diagonal().any(), "no self loops"


def test_star_and_ring_degrees():
    star = np.asarray(topology.star(8))
    assert star[0].sum() == 7 and (star[1:, 1:].sum() == 0)
    ring = np.asarray(topology.ring(8))
    assert (ring.sum(1) == 2).all()


def test_torus_degrees():
    adj = np.asarray(topology.torus(12))        # 3x4 grid
    assert (adj.sum(1) == 4).all()
    # prime m degenerates to a ring
    assert (np.asarray(topology.torus(7)).sum(1) == 2).all()


def test_mobility_redraws_every_k_rounds():
    m, k = 12, 5
    net = NetworkConfig(topology="geometric", geo_radius=0.5, redraw_every=k)
    a0 = np.asarray(topology.adjacency(net, m, t=0))
    assert (a0 == np.asarray(topology.adjacency(net, m, t=k - 1))).all()
    assert not (a0 == np.asarray(topology.adjacency(net, m, t=k))).all()
    # pure in t: same window, same graph
    assert (np.asarray(topology.adjacency(net, m, t=k))
            == np.asarray(topology.adjacency(net, m, t=2 * k - 1))).all()


def test_static_topology_ignores_round():
    net = NetworkConfig(topology="erdos_renyi", er_p=0.4)
    a = np.asarray(topology.adjacency(net, 10, t=0))
    b = np.asarray(topology.adjacency(net, 10, t=999))
    assert (a == b).all()


# ---------------------------------------------------------------------------
# availability
# ---------------------------------------------------------------------------

def test_full_availability_mask_is_all_ones():
    net = NetworkConfig()            # act_prob=1.0, no stragglers/outages
    assert net.full_availability
    for t in range(5):
        assert bool(jnp.all(availability.sample(net, 8, t)))


def test_dropout_rate_and_determinism():
    net = NetworkConfig(act_prob=0.6)
    masks = np.stack([np.asarray(availability.sample(net, 16, t))
                      for t in range(200)])
    assert 0.5 < masks.mean() < 0.7
    again = np.asarray(availability.sample(net, 16, 17))
    assert (masks[17] == again).all(), "pure in (seed, t)"


def test_stragglers_are_less_available():
    net = NetworkConfig(act_prob=0.95, straggler_frac=0.25,
                        straggler_act_prob=0.2)
    strag = np.asarray(availability.straggler_mask(net, 16))
    assert strag.sum() == 4
    masks = np.stack([np.asarray(availability.sample(net, 16, t))
                      for t in range(300)])
    assert masks[:, ~strag].mean() > 0.9
    assert masks[:, strag].mean() < 0.35


def test_scheduled_outage_window():
    net = NetworkConfig(outage_every=10, outage_length=3, outage_frac=0.5)
    m = 8
    down_per_round = [m - int(availability.sample(net, m, t).sum())
                      for t in range(20)]
    # inside each window exactly outage_frac*m learners are dark
    for t in (0, 1, 2, 10, 11, 12):
        assert down_per_round[t] == 4, (t, down_per_round)
    for t in (3, 7, 9, 15, 19):
        assert down_per_round[t] == 0, (t, down_per_round)


def test_availability_samples_inside_scan():
    net = NetworkConfig(act_prob=0.5)

    def body(carry, t):
        return carry, availability.sample(net, 8, t)

    _, masks = jax.jit(
        lambda: jax.lax.scan(body, 0, jnp.arange(32)))()
    eager = np.stack([np.asarray(availability.sample(net, 8, t))
                      for t in range(32)])
    assert (np.asarray(masks) == eager).all()


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_link_profile_round_robin():
    net = NetworkConfig(link_classes=("wifi", "lte"))
    bw, lat = cost.link_profile(net, 4)
    assert float(bw[0]) == np.float32(cost.LINK_CLASSES["wifi"].bandwidth)
    assert float(bw[1]) == np.float32(cost.LINK_CLASSES["lte"].bandwidth)
    assert float(lat[2]) == np.float32(cost.LINK_CLASSES["wifi"].latency)
    with pytest.raises(KeyError):
        cost.link_profile(NetworkConfig(link_classes=("warp-drive",)), 4)


def test_round_network_time_slowest_link():
    bw = jnp.asarray([1e6, 1e3], jnp.float32)       # bytes/s
    lat = jnp.asarray([0.0, 0.0], jnp.float32)
    xfers = jnp.asarray([2, 2], jnp.int32)
    no_msgs = jnp.zeros((2,), jnp.int32)
    t = cost.round_network_time(xfers, no_msgs, 1000, bw, lat)
    # slowest link: 2 transfers * 1000B / 1e3 B/s = 2s (parallel links)
    assert np.isclose(float(t), 2.0)
    t0 = cost.round_network_time(jnp.zeros(2, jnp.int32), no_msgs,
                                 1000, bw, lat)
    assert float(t0) == 0.0
    # control messages add a round-trip on the slowest link that SENT one
    lat2 = jnp.asarray([0.1, 0.4], jnp.float32)
    tm = cost.round_network_time(jnp.zeros(2, jnp.int32),
                                 jnp.asarray([3, 0], jnp.int32),
                                 1000, bw, lat2)
    assert np.isclose(float(tm), 0.2)
    tm_slow = cost.round_network_time(jnp.zeros(2, jnp.int32),
                                      jnp.asarray([0, 1], jnp.int32),
                                      1000, bw, lat2)
    assert np.isclose(float(tm_slow), 0.8)


def test_round_network_time_message_term_bitwise():
    # Regression for the 2*RTT term: a round with no messages must price
    # the model term EXACTLY (no phantom round-trip over a silent link),
    # and a round where every link messages adds exactly 2 * max(lat).
    bw = jnp.asarray([1e6, 1e3, 25e6], jnp.float32)
    lat = jnp.asarray([0.05, 0.4, 0.005], jnp.float32)
    xfers = jnp.asarray([1, 2, 0], jnp.int32)
    per_link = xfers.astype(jnp.float32) * (lat + jnp.float32(1000) / bw)
    t_models = jnp.max(per_link, initial=0.0)

    silent = cost.round_network_time(xfers, jnp.zeros(3, jnp.int32),
                                     1000, bw, lat)
    assert float(silent) == float(t_models)          # bitwise: no 2*RTT term

    chatty = cost.round_network_time(xfers, jnp.ones(3, jnp.int32),
                                     1000, bw, lat)
    assert float(chatty) == float(t_models + 2.0 * jnp.max(lat))


# ---------------------------------------------------------------------------
# availability-aware operators
# ---------------------------------------------------------------------------

def _mk(m=6, seed=0, scale=1.0):
    t = make_stacked(jax.random.PRNGKey(seed), m)
    return jax.tree.map(lambda x: x * scale, t)


def _state(stacked, seed=0):
    return ops.init_state(tree_mean(stacked), seed)


@pytest.mark.parametrize("kind,kw", [
    ("periodic", dict(b=1)),
    ("fedavg", dict(b=1, fedavg_c=0.5)),
    ("dynamic", dict(b=1, delta=1e-6)),
])
def test_inactive_learners_untouched(kind, kw):
    m = 8
    stacked = _mk(m=m, scale=2.0)
    cfg = ProtocolConfig(kind=kind, **kw)
    active = jnp.asarray([True, False, True, True, False, True, True, False])
    new, _, rec, xfers = ops.apply_operator(
        cfg, stacked, _state(stacked), active=active)
    for i in np.flatnonzero(~np.asarray(active)):
        a = jax.tree.map(lambda x: x[i], new)
        b = jax.tree.map(lambda x: x[i], stacked)
        assert all(np.array_equal(x, y) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        assert int(xfers[i]) == 0
    assert int(rec.model_up) <= int(jnp.sum(active))


@pytest.mark.parametrize("kind,kw", [
    ("periodic", dict(b=1)),
    ("dynamic", dict(b=1, delta=1e-6)),
    ("dynamic", dict(b=1, delta=0.5)),
])
def test_all_ones_mask_matches_unmasked(kind, kw):
    """The masked code path with a full mask = the unmasked operator (comm
    exactly, params to float tolerance — fedavg is excluded: its masked
    path draws the subset differently)."""
    stacked = _mk(m=6, scale=2.0)
    cfg = ProtocolConfig(kind=kind, **kw)
    new_u, st_u, rec_u, xf_u = ops.apply_operator(cfg, stacked, _state(stacked))
    new_m, st_m, rec_m, xf_m = ops.apply_operator(
        cfg, stacked, _state(stacked), active=jnp.ones((6,), bool))
    for f in ops.CommRecord._fields:
        assert int(getattr(rec_u, f)) == int(getattr(rec_m, f)), f
    assert (np.asarray(xf_u) == np.asarray(xf_m)).all()
    assert tree_allclose(new_u, new_m, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("kind,kw", [
    ("periodic", dict(b=1)),
    ("fedavg", dict(b=1, fedavg_c=0.5)),
    ("dynamic", dict(b=1, delta=1e-6)),
])
def test_empty_active_set_is_a_noop(kind, kw):
    """Nobody reachable: no comm, no NaNs, configuration unchanged."""
    stacked = _mk(m=5, scale=3.0)
    cfg = ProtocolConfig(kind=kind, **kw)
    new, state, rec, xfers = ops.apply_operator(
        cfg, stacked, _state(stacked), active=jnp.zeros((5,), bool))
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(new), jax.tree.leaves(stacked)))
    assert int(rec.syncs) == 0 and int(rec.model_up) == 0
    assert int(jnp.sum(xfers)) == 0
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(new))
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(state.ref))


def test_dynamic_balancing_respects_reachability():
    """The balancing loop may only augment over reachable learners."""
    m = 8
    stacked = _mk(m=m, scale=0.01)
    ref = tree_mean(stacked)
    # one big violator, tiny delta -> balancing wants everyone; half the
    # fleet is unreachable, so the final B is exactly the reachable half
    stacked = jax.tree.map(lambda x: x.at[0].set(x[0] + 5.0), stacked)
    active = jnp.asarray([True, True, True, True, False, False, False, False])
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=1e-8)
    new, state, rec, xfers = ops.apply_operator(
        cfg, stacked, ops.init_state(ref), active=active)
    assert int(rec.model_up) == 4                 # the reachable half
    assert int(rec.full_syncs) == 1               # full among reachable
    assert (np.asarray(xfers)[4:] == 0).all()
    for i in range(4, 8):
        a = jax.tree.map(lambda x: x[i], new)
        b = jax.tree.map(lambda x: x[i], stacked)
        assert all(np.array_equal(x, y) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_gossip_preserves_mean_and_isolates_inactive():
    m = 8
    stacked = _mk(m=m, scale=2.0)
    cfg = ProtocolConfig(kind="gossip", b=1)
    adj = topology.ring(m)
    new, _, rec, xfers = ops.apply_operator(
        cfg, stacked, _state(stacked), adjacency=adj)
    # Metropolis weights are doubly stochastic -> mean invariance
    assert tree_allclose(tree_mean(stacked), tree_mean(new),
                         rtol=1e-5, atol=1e-6)
    assert int(rec.model_up) == int(rec.model_down) == 8   # ring: 8 edges
    assert (np.asarray(xfers) == 4).all()                  # 2 neighbors * 2
    # knock out one learner: it keeps its model bitwise
    active = jnp.ones((m,), bool).at[3].set(False)
    new2, _, _, xf2 = ops.apply_operator(
        cfg, stacked, _state(stacked), active=active, adjacency=adj)
    a = jax.tree.map(lambda x: x[3], new2)
    b = jax.tree.map(lambda x: x[3], stacked)
    assert all(np.array_equal(x, y) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    assert int(xf2[3]) == 0


def test_full_syncs_means_all_reachable_for_every_operator():
    """Consistent semantics under masks: full_syncs=1 iff the sync covered
    every REACHABLE learner (periodic always does; fedavg with C=1 does;
    gossip needs a complete active subgraph)."""
    m = 6
    stacked = _mk(m=m, scale=2.0)
    active = jnp.asarray([True, True, True, False, False, True])
    _, _, rec_p, _ = ops.apply_operator(
        ProtocolConfig(kind="periodic", b=1), stacked, _state(stacked),
        active=active)
    assert int(rec_p.full_syncs) == 1
    _, _, rec_f, _ = ops.apply_operator(
        ProtocolConfig(kind="fedavg", b=1, fedavg_c=1.0), stacked,
        _state(stacked), active=active)
    assert int(rec_f.full_syncs) == 1
    _, _, rec_h, _ = ops.apply_operator(
        ProtocolConfig(kind="fedavg", b=1, fedavg_c=0.5), stacked,
        _state(stacked), active=active)
    assert int(rec_h.full_syncs) == 0
    _, _, rec_g, _ = ops.apply_operator(
        ProtocolConfig(kind="gossip", b=1), stacked, _state(stacked),
        active=active, adjacency=topology.complete(m))
    assert int(rec_g.full_syncs) == 1
    _, _, rec_r, _ = ops.apply_operator(
        ProtocolConfig(kind="gossip", b=1), stacked, _state(stacked),
        active=active, adjacency=topology.ring(m))
    assert int(rec_r.full_syncs) == 0


def test_gossip_requires_adjacency():
    stacked = _mk(m=4)
    with pytest.raises(ValueError):
        ops.apply_operator(ProtocolConfig(kind="gossip", b=1), stacked,
                           _state(stacked))


def test_tree_weighted_mean_zero_weights_is_finite():
    stacked = _mk(m=4)
    mean = tree_weighted_mean(stacked, jnp.zeros((4,)))
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(mean))


# ---------------------------------------------------------------------------
# CommRecord invariants under random masks (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(["periodic", "fedavg", "dynamic", "gossip"]),
       m=st.integers(2, 8), seed=st.integers(0, 10_000),
       mask_bits=st.integers(0, 255))
def test_comm_record_invariants_under_random_masks(kind, m, seed, mask_bits):
    stacked = make_stacked(jax.random.PRNGKey(seed), m)
    active = jnp.asarray([(mask_bits >> i) & 1 == 1 for i in range(m)])
    kw = dict(b=1)
    if kind == "dynamic":
        kw["delta"] = 0.05
    cfg = ProtocolConfig(kind=kind, **kw)
    adj = topology.ring(m) if kind == "gossip" else None
    new, _, rec, xfers = ops.apply_operator(
        cfg, stacked, _state(stacked, seed), active=active, adjacency=adj)
    up, down = int(rec.model_up), int(rec.model_down)
    assert up == down
    assert int(rec.messages) >= 0
    assert 0 <= int(rec.syncs) <= 1 and 0 <= int(rec.full_syncs) <= 1
    assert (np.asarray(xfers) >= 0).all()
    total_xfers = int(jnp.sum(xfers))
    # coordinator links carry up+down; a gossip transfer occupies BOTH
    # endpoints' links
    assert total_xfers == (2 * (up + down) if kind == "gossip" else up + down)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(new))


# ---------------------------------------------------------------------------
# engine regression: ideal network == pre-network engine, bitwise
# ---------------------------------------------------------------------------

def _mlp_setup():
    cfg = get_arch("drift_mlp", smoke=True)
    return (lambda p, b: cnn_loss(cfg, p, b),
            lambda k: init_cnn_params(cfg, k))


def _run_engine(proto, network, rounds=40, m=6):
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=10, seed=0)
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05), network=network)
    dl.run_chunk(streams.next_chunk(rounds))
    return dl


@pytest.mark.parametrize("proto", [
    ProtocolConfig(kind="periodic", b=3),
    ProtocolConfig(kind="fedavg", b=2, fedavg_c=0.5),
    ProtocolConfig(kind="dynamic", b=2, delta=0.5),
])
def test_ideal_network_reproduces_engine_bitwise(proto):
    """ISSUE-2 acceptance: act_prob=1.0 + star topology == the pre-network
    engine — comm counters bitwise, losses exactly, params bitwise."""
    base = _run_engine(proto, None)
    net = _run_engine(proto, NetworkConfig())   # star, full availability
    assert base.comm_totals == net.comm_totals
    assert base.cumulative_loss == net.cumulative_loss
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(base.params), jax.tree.leaves(net.params)))


def test_dropout_chunk_runs_scanned_and_accounts():
    """Dropout + topology runs inside run_chunk (stacked per-round metrics
    come back from ONE compiled program) and the new accounting holds."""
    proto = ProtocolConfig(kind="dynamic", b=2, delta=0.5)
    net = NetworkConfig(act_prob=0.6, topology="ring",
                        link_classes=("wifi", "lte"))
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, 6, batch=10, seed=0)
    dl = DecentralizedLearner(
        loss_fn, init_fn, 6, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05), network=net)
    n = 32
    metrics = dl.run_chunk(streams.next_chunk(n))
    assert metrics.num_active.shape == (n,)
    assert metrics.net_time.shape == (n,)
    assert metrics.link_xfers.shape == (n, 6)
    assert np.isfinite(dl.cumulative_loss)
    assert 0.0 < dl.mean_active() < 1.0
    assert dl.network_time >= 0.0
    # per-link accounting consistent with the global counters
    assert (int(np.sum(dl.link_xfer_totals))
            == dl.comm_totals["model_up"] + dl.comm_totals["model_down"])
    # the bytes ledger: model payloads per link PLUS the control messages
    # each link sent (dynamic's chatter no longer hides in the global
    # total) — its sum IS the paper's c(f)
    msg_link_bytes = dl.per_link_bytes() - dl.link_xfer_totals * dl.model_bytes
    assert (msg_link_bytes >= 0).all()
    assert (int(np.sum(msg_link_bytes))
            == dl.comm_totals["messages"] * net.msg_bytes)
    assert int(np.sum(dl.per_link_bytes())) == dl.comm_bytes()


def test_gossip_mobile_geometric_end_to_end():
    proto = ProtocolConfig(kind="gossip", b=2)
    net = NetworkConfig(topology="geometric", geo_radius=0.6,
                        redraw_every=5, act_prob=0.8)
    dl = _run_engine(proto, net, rounds=30)
    assert np.isfinite(dl.cumulative_loss)
    assert dl.comm_totals["model_up"] == dl.comm_totals["model_down"]
    assert dl.comm_totals["syncs"] >= 1


def test_loop_threads_network_and_records_sim_time():
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    net = NetworkConfig(act_prob=0.7, link_classes=("lte",))
    dl, traj = run_protocol_training(
        loss_fn, init_fn, src, m=5, rounds=40,
        protocol=ProtocolConfig(kind="periodic", b=5),
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, record_every=10, network=net)
    assert len(traj.network_time) == len(traj.rounds)
    assert traj.network_time == sorted(traj.network_time)   # cumulative
    assert np.isclose(traj.network_time[-1], dl.network_time)
    assert "network_time" in traj.as_dict()


# ---------------------------------------------------------------------------
# config validation (satellites)
# ---------------------------------------------------------------------------

def test_protocol_config_validation():
    """Construction-time validation raises ValueError (not assert, which
    vanishes under ``python -O``), matching HierarchyConfig's style."""
    with pytest.raises(ValueError):
        ProtocolConfig(kind="fedavg", fedavg_c=0.0)
    with pytest.raises(ValueError):
        ProtocolConfig(kind="fedavg", fedavg_c=1.5)
    with pytest.raises(ValueError):
        ProtocolConfig(kind="dynamic", delta=0.0)
    with pytest.raises(ValueError):
        ProtocolConfig(kind="periodic", b=0)
    with pytest.raises(ValueError):
        ProtocolConfig(kind="dynamic", augmentation="telepathy")
    with pytest.raises(KeyError):     # unknown kind names the known ones
        ProtocolConfig(kind="psychic")
    # delta is dynamic-only: a periodic/nosync config must not be rejected
    # over a field it never reads
    ProtocolConfig(kind="periodic", delta=0.0)
    ProtocolConfig(kind="nosync", delta=-1.0)


def test_network_config_validation():
    with pytest.raises(KeyError):
        NetworkConfig(topology="full-mesh-of-dreams")
    with pytest.raises(ValueError):
        NetworkConfig(act_prob=0.0)
    with pytest.raises(ValueError):
        NetworkConfig(outage_every=5, outage_length=0)
    with pytest.raises(ValueError):
        # an outage outlasting its period would be a permanent blackout
        NetworkConfig(outage_every=3, outage_length=5)
    with pytest.raises(ValueError):
        NetworkConfig(link_classes=())
    with pytest.raises(ValueError):
        # mobility only applies to the geometric graph
        NetworkConfig(topology="ring", redraw_every=10)
    assert NetworkConfig().full_availability
    assert not NetworkConfig(act_prob=0.9).full_availability
    assert not NetworkConfig(straggler_frac=0.5).full_availability
    assert not NetworkConfig(outage_every=10).full_availability
