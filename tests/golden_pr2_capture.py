"""Capture the PR-2 engine's numerics as golden values (maintenance tool).

Run ONCE against a known-good engine to (re)generate
``tests/golden_pr2_engine.json``, the fixture behind the staged-kernel
bitwise regression in ``tests/test_sync_kernel.py``:

    PYTHONPATH=src python tests/golden_pr2_capture.py

Every case runs 40 scanned rounds of the drift-MLP smoke task through
``DecentralizedLearner.run_chunk`` and records the comm-counter totals,
the exact cumulative loss, a SHA-256 over the final parameter bytes, and
the per-link transfer totals. The staged sync kernel (ISSUE 3) must
reproduce all of them bitwise with ``tiers=None``.
"""
import hashlib
import json
import os

import jax
import numpy as np

from repro.config import NetworkConfig, ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params

M, ROUNDS = 6, 40

CASES = {
    "periodic_ideal": (ProtocolConfig(kind="periodic", b=3), None),
    "periodic_net": (ProtocolConfig(kind="periodic", b=3),
                     NetworkConfig(act_prob=0.6, topology="ring",
                                   link_classes=("wifi", "lte"))),
    "fedavg_ideal": (ProtocolConfig(kind="fedavg", b=2, fedavg_c=0.5), None),
    "fedavg_net": (ProtocolConfig(kind="fedavg", b=2, fedavg_c=0.5),
                   NetworkConfig(act_prob=0.6, topology="ring",
                                 link_classes=("wifi", "lte"))),
    "dynamic_ideal": (ProtocolConfig(kind="dynamic", b=2, delta=0.5), None),
    "dynamic_net": (ProtocolConfig(kind="dynamic", b=2, delta=0.5),
                    NetworkConfig(act_prob=0.6, topology="ring",
                                  link_classes=("wifi", "lte"))),
    "dynamic_weighted_ideal": (
        ProtocolConfig(kind="dynamic", b=2, delta=0.5, weighted=True), None),
    "gossip_star_fallback": (ProtocolConfig(kind="gossip", b=2), None),
    "gossip_net": (ProtocolConfig(kind="gossip", b=2),
                   NetworkConfig(act_prob=0.8, topology="ring",
                                 link_classes=("wifi", "lte"))),
    "nosync_ideal": (ProtocolConfig(kind="nosync"), None),
}


def params_sha256(params) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def run_case(proto, network):
    cfg = get_arch("drift_mlp", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    weighted = getattr(proto, "weighted", False)
    streams = LearnerStreams(src, M, batch=10, seed=0,
                             batch_sizes=[5, 10, 15, 10, 5, 15]
                             if weighted else None)
    dl = DecentralizedLearner(
        loss_fn, init_fn, M, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        sample_weights=streams.weights, network=network)
    dl.run_chunk(streams.next_chunk(ROUNDS))
    return {
        "comm_totals": dl.comm_totals,
        "cumulative_loss": repr(dl.cumulative_loss),
        "params_sha256": params_sha256(dl.params),
        "link_xfer_totals": dl.link_xfer_totals.tolist(),
        "network_time": repr(dl.network_time),
    }


def main():
    out = {name: run_case(p, n) for name, (p, n) in CASES.items()}
    # bitwise goldens are only meaningful against the XLA that produced
    # them — the regression test skips on other jax versions
    out["_meta"] = {"jax_version": jax.__version__}
    path = os.path.join(os.path.dirname(__file__), "golden_pr2_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"wrote {path}")
    for k in CASES:
        print(f"  {k}: loss={out[k]['cumulative_loss']} "
              f"up={out[k]['comm_totals']['model_up']}")


if __name__ == "__main__":
    main()
