"""The protocol-spec API (ISSUE 4): stage registries, ProtocolSpec,
preset bitwise-equivalence, serialization round-trips, and the
bounded-staleness protocol defined purely through the registry.

Two load-bearing groups:

* ``test_registry_self_check`` is the fast CI gate (wired into
  ``.github/workflows/ci.yml``): every ``PROTOCOLS`` preset constructs,
  compiles, serializes, and stage-name collisions are loud.
* ``test_preset_spec_equals_kind_dispatch_bitwise`` pins that running a
  resolved ``ProtocolSpec`` DIRECTLY through the engine reproduces the
  PR-2 goldens — the same fixture the legacy ``kind`` dispatch is pinned
  against — so sugar and spec paths are interchangeable bit for bit.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import (
    HierarchyConfig, NetworkConfig, ProtocolConfig, TrainConfig, get_arch,
)
from repro.core import operators as ops
from repro.core.divergence import tree_mean
from repro.core.protocol import DecentralizedLearner
from repro.core.sync import (
    AGGREGATES, BOUNDED_STALENESS, COHORTS, COMMITS, PROTOCOLS, TRIGGERS,
    ProtocolSpec, register_trigger, resolve_spec,
)
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params

from conftest import make_stacked
from golden_pr2_capture import CASES, M, ROUNDS, params_sha256

BUILTIN_KINDS = ("nosync", "periodic", "continuous", "fedavg", "dynamic",
                 "gossip")


# ---------------------------------------------------------------------------
# registry self-check (the fast CI gate)
# ---------------------------------------------------------------------------

def test_registry_self_check():
    """Every preset constructs, compiles, serializes; capabilities are
    sane; all six built-in kinds resolve to presets."""
    assert set(BUILTIN_KINDS) <= set(PROTOCOLS)
    for name, spec in PROTOCOLS.items():
        assert isinstance(spec, ProtocolSpec), name
        assert callable(spec.compile()), name
        back = ProtocolSpec.from_json(spec.to_json())
        assert back == spec, name
        assert isinstance(spec.uses_overlay, bool)
        assert isinstance(spec.uses_coordinator, bool)
    assert PROTOCOLS["gossip"].uses_overlay
    assert not PROTOCOLS["gossip"].uses_coordinator
    for kind in ("periodic", "fedavg", "dynamic", "nosync", "stale"):
        assert PROTOCOLS[kind].uses_coordinator, kind
        assert not PROTOCOLS[kind].uses_overlay, kind
    # the registries themselves are populated with the documented stages
    assert {"never", "cadence", "divergence", "staleness"} <= set(TRIGGERS)
    assert {"all_reachable", "fraction", "balanced",
            "neighborhood"} <= set(COHORTS)
    assert {"mean", "mix"} <= set(AGGREGATES)
    assert {"average", "subset", "balancing", "mix"} <= set(COMMITS)


def test_stage_name_collisions_are_loud():
    with pytest.raises(ValueError, match="already registered"):
        register_trigger("cadence")(lambda ctx: True)
    from repro.core.sync import register_protocol
    with pytest.raises(ValueError, match="already registered"):
        register_protocol("dynamic", PROTOCOLS["dynamic"])


def test_unknown_stage_names_raise_at_construction():
    with pytest.raises(KeyError, match="unknown trigger"):
        ProtocolSpec(trigger="full-moon")
    with pytest.raises(KeyError, match="unknown cohort"):
        ProtocolSpec(trigger="cadence", cohort="everyone-and-their-dog")
    with pytest.raises(KeyError, match="unknown aggregate"):
        ProtocolSpec(trigger="cadence", aggregate="vibes")
    with pytest.raises(KeyError, match="unknown commit"):
        ProtocolSpec(trigger="cadence", commit="yolo")


def test_invalid_combos_raise_at_construction():
    # balancing machinery needs a conditional trigger (hot learners)
    with pytest.raises(ValueError, match="conditional"):
        ProtocolSpec(trigger="cadence", cohort="balanced",
                     commit="balancing")
    # the mixing aggregate needs the neighborhood cohort's matrices
    with pytest.raises(ValueError, match="mixing"):
        ProtocolSpec(trigger="cadence", aggregate="mix")
    with pytest.raises(ValueError, match="mixing"):
        ProtocolSpec(trigger="cadence", commit="mix")
    # commit families are tied to their cohort's labels
    with pytest.raises(ValueError, match="subset"):
        ProtocolSpec(trigger="cadence", commit="subset")
    # unknown params are typos, not silently-ignored knobs
    with pytest.raises(ValueError, match="not consumed"):
        ProtocolSpec(trigger="cadence", params={"tau": 3})
    # stage param validation happens at construction, not trace time
    with pytest.raises(ValueError, match="delta"):
        ProtocolSpec(trigger="divergence", cohort="balanced",
                     commit="balancing", params={"delta": 0.0})
    with pytest.raises(ValueError, match="b must be"):
        ProtocolSpec(trigger="cadence", params={"b": 0})
    with pytest.raises(ValueError, match="fedavg_c"):
        ProtocolSpec(trigger="cadence", cohort="fraction", commit="subset",
                     params={"fedavg_c": 1.5})
    with pytest.raises(ValueError, match="bytes_per_param"):
        ProtocolSpec(trigger="cadence", params={"bytes_per_param": 0})
    with pytest.raises(ValueError, match="tau"):
        ProtocolSpec(trigger="staleness", params={"tau": 0})


def test_config_sugar_resolves_only_consumed_fields():
    """delta never leaks into periodic; fedavg_c never into dynamic."""
    spec = resolve_spec(ProtocolConfig(kind="periodic", b=7, delta=0.0))
    assert spec.param("b") == 7
    assert "delta" not in dict(spec.params)
    spec = resolve_spec(ProtocolConfig(kind="dynamic", b=3, delta=0.25,
                                       augmentation="random"))
    assert spec.param("delta") == 0.25
    assert spec.param("augmentation") == "random"
    assert "fedavg_c" not in dict(spec.params)


def test_preset_pinned_params_win_over_config_defaults():
    """A param a preset pins explicitly is part of its identity: the
    ProtocolConfig sugar's field overlay (which cannot distinguish user
    values from dataclass defaults) must not clobber it. "stale" pins
    b=1, so kind sugar and the raw spec behave identically."""
    assert dict(BOUNDED_STALENESS.params)["b"] == 1
    resolved = resolve_spec(ProtocolConfig(kind="stale"))   # config b=10
    assert resolved.param("b") == 1
    assert resolved.resolved_params() == BOUNDED_STALENESS.resolved_params()
    # built-in presets pin nothing, so the sugar keeps tuning them
    assert resolve_spec(ProtocolConfig(kind="periodic")).param("b") == 10


def test_named_operators_apply_passed_weights_as_is():
    """Pre-spec contract of the NAMED ops: an explicitly passed weights
    vector is used regardless of cfg.weighted — the weighted gate lives
    only in apply_staged."""
    stacked = {"x": jnp.asarray([[1., 1.], [3., 5.], [3., 5.], [5., 5.]])}
    state = ops.init_state(tree_mean(stacked))
    w = jnp.asarray([10., 1., 1., 1.])
    cfg = ProtocolConfig(kind="periodic", b=1)          # weighted=False
    named = ops.periodic(cfg, stacked, state, w)
    want = (10 * stacked["x"][0] + stacked["x"][1] + stacked["x"][2]
            + stacked["x"][3]) / 13.0
    assert np.allclose(np.asarray(named.params["x"][0]), np.asarray(want))
    gated = ops.apply_staged(cfg, stacked, state, w)
    assert np.allclose(np.asarray(gated.params["x"][0]),
                       np.asarray(tree_mean(stacked)["x"]))


def test_non_scalar_params_rejected_at_construction():
    """jax arrays / lists as params would only explode at the compile
    cache or in to_json — construction rejects them; numpy scalars are
    canonicalized to plain Python numbers."""
    for bad in (jnp.float32(0.5), [1, 2], (3,)):
        with pytest.raises(ValueError, match="plain Python scalar"):
            ProtocolSpec(trigger="divergence", cohort="balanced",
                         commit="balancing", params={"delta": bad})
    spec = ProtocolSpec(trigger="cadence", params={"b": np.int64(4)})
    assert spec.param("b") == 4 and type(spec.param("b")) is int
    assert ProtocolSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------------
# presets == legacy kind dispatch, bitwise (against the PR-2 goldens)
# ---------------------------------------------------------------------------

with open(os.path.join(os.path.dirname(__file__),
                       "golden_pr2_engine.json")) as f:
    GOLDEN = json.load(f)
GOLDEN_JAX = GOLDEN.get("_meta", {}).get("jax_version")


def _run_spec_case(proto, network):
    """golden_pr2_capture.run_case, but driving the engine with the
    RESOLVED ProtocolSpec instead of the ProtocolConfig sugar."""
    spec = resolve_spec(proto)
    cfg = get_arch("drift_mlp", smoke=True)
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    weighted = spec.param("weighted")
    streams = LearnerStreams(src, M, batch=10, seed=0,
                             batch_sizes=[5, 10, 15, 10, 5, 15]
                             if weighted else None)
    dl = DecentralizedLearner(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k), M, spec,
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        sample_weights=streams.weights, network=network)
    dl.run_chunk(streams.next_chunk(ROUNDS))
    return {
        "comm_totals": dl.comm_totals,
        "cumulative_loss": repr(dl.cumulative_loss),
        "params_sha256": params_sha256(dl.params),
        "link_xfer_totals": dl.link_xfer_totals.tolist(),
        "network_time": repr(dl.network_time),
    }


@pytest.mark.skipif(
    jax.__version__ != GOLDEN_JAX,
    reason=f"bitwise goldens captured under jax {GOLDEN_JAX}")
@pytest.mark.parametrize("name", sorted(CASES))
def test_preset_spec_equals_kind_dispatch_bitwise(name):
    """ISSUE-4 acceptance: a resolved preset spec driven directly through
    the engine reproduces the goldens the kind dispatch is pinned to —
    comm totals, exact loss, params SHA-256, per-link transfers."""
    got = _run_spec_case(*CASES[name])
    want = GOLDEN[name]
    assert got["comm_totals"] == want["comm_totals"], name
    assert got["cumulative_loss"] == want["cumulative_loss"], name
    assert got["params_sha256"] == want["params_sha256"], name
    assert got["link_xfer_totals"] == want["link_xfer_totals"], name
    assert got["network_time"] == want["network_time"], name


# ---------------------------------------------------------------------------
# serialization round-trips (hypothesis)
# ---------------------------------------------------------------------------

# the composable families: any trigger drives any cohort/aggregate/commit
# family, except the balancing machinery which needs a conditional trigger
FAMILIES = [("all_reachable", "mean", "average"),
            ("fraction", "mean", "subset"),
            ("balanced", "mean", "balancing"),
            ("neighborhood", "mix", "mix")]
CONDITIONAL_TRIGGERS = ("divergence", "staleness")
UNCONDITIONAL_TRIGGERS = ("never", "cadence")


def _valid_spec(trigger, family, b, delta, fedavg_c, tau, weighted):
    cohort, aggregate, commit = family
    params = {"b": b, "weighted": weighted}
    if trigger == "never":
        params = {"weighted": weighted}
    if trigger == "divergence" or cohort == "balanced":
        params["delta"] = delta
    if trigger == "staleness":
        params["tau"] = tau
    if cohort == "fraction":
        params["fedavg_c"] = fedavg_c
    return ProtocolSpec(trigger=trigger, cohort=cohort,
                        aggregate=aggregate, commit=commit, params=params)


@settings(max_examples=60, deadline=None)
@given(trig_i=st.integers(0, 3), fam_i=st.integers(0, 3),
       b=st.integers(1, 20), delta=st.floats(1e-6, 10.0),
       fedavg_c=st.floats(0.01, 1.0), tau=st.integers(1, 50),
       weighted=st.booleans())
def test_spec_roundtrips_through_dict_and_json(trig_i, fam_i, b, delta,
                                               fedavg_c, tau, weighted):
    """spec -> to_dict -> from_dict -> spec (and via JSON) for random
    stage combinations; combos needing a conditional trigger raise at
    construction when handed an unconditional one."""
    triggers = CONDITIONAL_TRIGGERS + UNCONDITIONAL_TRIGGERS
    trigger, family = triggers[trig_i], FAMILIES[fam_i]
    needs_condition = family[0] == "balanced"
    if needs_condition and trigger in UNCONDITIONAL_TRIGGERS:
        with pytest.raises(ValueError, match="conditional"):
            _valid_spec(trigger, family, b, delta, fedavg_c, tau, weighted)
        return
    spec = _valid_spec(trigger, family, b, delta, fedavg_c, tau, weighted)
    assert ProtocolSpec.from_dict(spec.to_dict()) == spec
    assert ProtocolSpec.from_json(spec.to_json()) == spec
    # canonical param ordering: dict-insertion order never leaks
    shuffled = dict(reversed(list(spec.to_dict()["params"].items())))
    assert ProtocolSpec.from_dict(
        {**spec.to_dict(), "params": shuffled}) == spec
    # capabilities survive the round trip
    back = ProtocolSpec.from_json(spec.to_json())
    assert back.uses_overlay == spec.uses_overlay
    assert back.uses_coordinator == spec.uses_coordinator
    assert back.extra_state == spec.extra_state


def test_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown ProtocolSpec keys"):
        ProtocolSpec.from_dict({"trigger": "cadence", "cadence": 5})
    with pytest.raises(ValueError, match="trigger"):
        ProtocolSpec.from_dict({"cohort": "all_reachable"})


# ---------------------------------------------------------------------------
# the bounded-staleness protocol (ISSUE-4 acceptance)
# ---------------------------------------------------------------------------

def _mlp_setup():
    cfg = get_arch("drift_mlp", smoke=True)
    return (lambda p, b: cnn_loss(cfg, p, b),
            lambda k: init_cnn_params(cfg, k))


def _run_engine(proto, network=None, rounds=40, m=6, seed=0):
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=10, seed=seed)
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05), network=network)
    metrics = dl.run_chunk(streams.next_chunk(rounds))
    return dl, metrics


def test_bounded_staleness_cadence_on_ideal_network():
    """With every learner always reachable, the staleness bound degrades
    to a period: a full sync exactly every tau rounds."""
    tau, rounds = 4, 24
    spec = BOUNDED_STALENESS.with_params(tau=tau)
    dl, metrics = _run_engine(spec, rounds=rounds, m=6)
    syncs = np.asarray(metrics.comm.syncs)
    want = np.zeros(rounds, np.int32)
    want[tau - 1::tau] = 1                       # rounds tau, 2tau, ...
    assert syncs.tolist() == want.tolist()
    assert dl.comm_totals["syncs"] == rounds // tau
    assert dl.comm_totals["full_syncs"] == rounds // tau
    # between alarms the fleet is silent
    assert dl.comm_totals["model_up"] == (rounds // tau) * 6


def test_bounded_staleness_under_availability_masks():
    """The acceptance run: the spec executes inside lax.scan under
    availability masks, dark learners age past tau and trigger on
    reappearance, and the ledger balances."""
    spec = BOUNDED_STALENESS.with_params(tau=3)
    net = NetworkConfig(act_prob=0.5, seed=3, link_classes=("wifi", "lte"))
    dl, metrics = _run_engine(spec, network=net, rounds=60, m=6)
    assert dl.comm_totals["syncs"] >= 1
    assert np.isfinite(dl.cumulative_loss)
    # the per-link ledger balances against the scalar accounting
    assert int(dl.per_link_bytes().sum()) == dl.comm_bytes()
    # the trigger's counters live in the scanned carry
    assert dl.sync_state.extra["staleness"].shape == (6,)
    # under partial availability the alarm fires MORE often than the
    # ideal-network period (stale returners trigger off-cycle) and every
    # sync covers all currently-reachable learners
    assert dl.comm_totals["syncs"] >= 60 // 3
    assert dl.comm_totals["full_syncs"] == dl.comm_totals["syncs"]


def test_bounded_staleness_json_roundtrip_runs_identically():
    """A spec restored from JSON drives the engine to bitwise-identical
    results — checkpoints can restore the exact protocol."""
    spec = BOUNDED_STALENESS.with_params(tau=3)
    restored = ProtocolSpec.from_json(spec.to_json())
    net = NetworkConfig(act_prob=0.7, seed=1)
    dl_a, _ = _run_engine(spec, network=net, rounds=30, m=4)
    dl_b, _ = _run_engine(restored, network=net, rounds=30, m=4)
    assert dl_a.comm_totals == dl_b.comm_totals
    assert dl_a.cumulative_loss == dl_b.cumulative_loss
    assert params_sha256(dl_a.params) == params_sha256(dl_b.params)


def test_stale_kind_sugar_and_hierarchy_composition():
    """Registration made "stale" a valid ProtocolConfig kind — including
    as the intra tier of a hierarchy (uses_coordinator capability)."""
    proto = ProtocolConfig(
        kind="stale", b=1,
        tiers=HierarchyConfig(num_clusters=2,
                              inter=ProtocolConfig(kind="periodic", b=4)))
    dl, metrics = _run_engine(proto, rounds=16, m=6)
    assert np.isfinite(dl.cumulative_loss)
    assert int(dl.per_link_bytes().sum()) == dl.comm_bytes()
    # per-cluster staleness counters ride the vmapped intra state
    assert dl.sync_state.intra.extra["staleness"].shape == (2, 3)


def test_staleness_composes_with_other_cohort_families():
    """The trigger is reusable across cohort families with no new code:
    staleness-triggered FedAvg and staleness-triggered balancing."""
    stale_fedavg = ProtocolSpec(
        trigger="staleness", cohort="fraction", commit="subset",
        params={"tau": 2, "fedavg_c": 0.5}, name="stale_fedavg")
    dl, _ = _run_engine(stale_fedavg, rounds=12, m=6)
    # subsets of 3 sync every 2 rounds
    assert dl.comm_totals["model_up"] > 0
    assert dl.comm_totals["full_syncs"] == 0       # never everyone at once
    stale_balanced = ProtocolSpec(
        trigger="staleness", cohort="balanced", commit="balancing",
        params={"tau": 3, "delta": 0.5}, name="stale_balanced")
    dl2, _ = _run_engine(stale_balanced, rounds=12, m=6)
    assert dl2.comm_totals["syncs"] >= 1
    assert dl2.comm_totals["messages"] > 0         # polls are accounted


def test_checkpoint_roundtrip_with_extra_state_and_spec(tmp_path):
    """SyncState.extra and the serialized spec survive the npz/json
    round trip; old checkpoints (no extra, no spec file) still load."""
    from repro.checkpoint.io import (
        load_protocol_spec, load_protocol_state, save_protocol_state,
    )
    spec = BOUNDED_STALENESS.with_params(tau=2)
    dl, _ = _run_engine(spec, rounds=8, m=4)
    path = str(tmp_path / "ckpt")
    save_protocol_state(path, dl.params, dl.opt_state, dl.sync_state,
                        protocol=spec)
    params, opt, state = load_protocol_state(path)
    assert np.array_equal(state.extra["staleness"],
                          dl.sync_state.extra["staleness"])
    assert load_protocol_spec(path) == spec
    # pre-spec checkpoints: no extra, no spec sidecar
    stacked = make_stacked(jax.random.PRNGKey(0), 4)
    plain = ops.init_state(tree_mean(stacked))
    save_protocol_state(str(tmp_path / "old"), stacked, {"n": jnp.zeros(())},
                        plain)
    _, _, loaded = load_protocol_state(str(tmp_path / "old"))
    assert loaded.extra == {}
    assert load_protocol_spec(str(tmp_path / "old")) is None


def test_hierarchical_checkpoint_sidecar_keeps_tiers(tmp_path):
    """The spec sidecar of a hierarchical run records the tier structure
    too — intra spec, cluster count, uplink class and the inter spec all
    survive the round trip."""
    from repro.checkpoint.io import (
        load_protocol_spec, load_protocol_tiers, save_protocol_state,
    )
    proto = ProtocolConfig(
        kind="dynamic", b=2, delta=0.5,
        tiers=HierarchyConfig(num_clusters=2, link_class="lte",
                              inter=ProtocolConfig(kind="periodic", b=6)))
    dl, _ = _run_engine(proto, rounds=4, m=4)
    path = str(tmp_path / "hier")
    save_protocol_state(path, dl.params, dl.opt_state, dl.sync_state,
                        protocol=proto)
    assert load_protocol_spec(path) == resolve_spec(proto)
    tiers = load_protocol_tiers(path)
    assert tiers["num_clusters"] == 2
    assert tiers["link_class"] == "lte"
    assert tiers["inter"] == resolve_spec(proto.tiers.inter)
    # flat checkpoints have no tiers block
    flat = str(tmp_path / "flat")
    save_protocol_state(flat, dl.params, dl.opt_state, dl.sync_state,
                        protocol=ProtocolConfig(kind="periodic", b=3))
    assert load_protocol_tiers(flat) is None


def test_engine_runs_raw_spec_without_config():
    """ISSUE-4: the engine consumes a ProtocolSpec directly (benchmarks
    run specs from files without a ProtocolConfig wrapper)."""
    spec = resolve_spec(ProtocolConfig(kind="dynamic", b=2, delta=0.5))
    dl_spec, _ = _run_engine(spec, rounds=20, m=4)
    dl_cfg, _ = _run_engine(ProtocolConfig(kind="dynamic", b=2, delta=0.5),
                            rounds=20, m=4)
    assert dl_spec.comm_totals == dl_cfg.comm_totals
    assert params_sha256(dl_spec.params) == params_sha256(dl_cfg.params)
