"""The static-analysis gate: contract checker, jaxpr auditor, repo lint.

Two halves: the REAL repo must pass every analyzer clean (the CI gate's
contract), and deliberately-broken fixture stages must each be caught by
the rule built for them — a checker that never fires is worse than none.
Fixture stages register into the global registries, so every registering
test runs inside the snapshot/restore fixture."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

import repro.core.sync  # noqa: F401 — populate the stage registries
import repro.core.sync.registry as reg
from repro.analysis import audit, contracts, lint
from repro.analysis.__main__ import main as analysis_main
from repro.core.sync.registry import (
    CohortOut, CommRecord, StageContract, SyncOut, register_aggregate,
    register_commit, register_trigger,
)
from repro.core.sync.spec import LAYOUTS, ProtocolSpec


_REGISTRIES = ("TRIGGERS", "COHORTS", "AGGREGATES", "COMMITS", "PROTOCOLS")


@pytest.fixture
def registry_sandbox():
    """Registrations are global and permanent; snapshot the four stage
    registries (+ presets) and restore them after the test so fixture
    stages never leak into the hypothesis-over-registry tests."""
    saved = {n: dict(getattr(reg, n)) for n in _REGISTRIES}
    try:
        yield reg
    finally:
        for n, d in saved.items():
            live = getattr(reg, n)
            live.clear()
            live.update(d)


# ---------------------------------------------------------------------------
# the repo itself is clean
# ---------------------------------------------------------------------------

def test_every_registered_stage_declares_a_contract():
    assert contracts.check_registry() == []


def test_contract_matrix_clean_all_presets_all_layouts():
    findings = contracts.check_preset_matrix()
    assert findings == [], "\n".join(f.render() for f in findings)


def test_audit_clean_all_presets():
    findings = audit.audit_presets()
    assert findings == [], "\n".join(f.render() for f in findings)


def test_lint_clean_repo():
    findings = lint.lint_paths()
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_check_all_exits_zero():
    assert analysis_main(["--check-all"]) == 0


def test_layout_equivalence_every_preset():
    """tree and flat compile to abstractly identical StageResult trees
    for every registered preset — the conformance matrix a future
    sharded layout joins via spec.LAYOUTS."""
    assert len(LAYOUTS) >= 2
    for name in sorted(reg.PROTOCOLS):
        f = contracts.check_layout_equivalence(reg.get_protocol(name))
        assert f == [], "\n".join(x.render() for x in f)


# ---------------------------------------------------------------------------
# broken fixtures: each rule catches the bug built for it
# ---------------------------------------------------------------------------

def test_wrong_dtype_aggregate_is_caught(registry_sandbox):
    """An aggregate that silently promotes every leaf to f32 violates its
    out='model' contract on the mixed f32+bf16 template."""
    @register_aggregate("fx_f32_mean", contract=StageContract(
        summary="broken: promotes to f32", out="model"))
    def bad_agg(ctx, cout):
        mean = jax.tree.map(lambda x: jnp.mean(x, axis=0),
                            ctx.stacked)
        return jax.tree.map(lambda x: x.astype(jnp.float32), mean)

    spec = ProtocolSpec(trigger="cadence", cohort="all_reachable",
                        aggregate="fx_f32_mean", commit="average",
                        name="fx-f32")
    findings = contracts.check_spec(spec)
    rules = {f.rule for f in findings}
    assert "aggregate-out" in rules, [f.render() for f in findings]
    # and the compiled round leaks the promotion into the scan carry
    assert any(f.rule in ("round-params", "trace-error")
               for f in contracts.check_round(spec))


def test_undeclared_counter_owner_is_caught(registry_sandbox):
    """A cohort returning v without declaring manages_v is flagged."""
    @reg.register_cohort("fx_rogue_v", provides=("full-cohort",),
                         contract=StageContract(summary="broken: rogue v"))
    def rogue(ctx, hot, nhot, rng):
        from repro.core.sync.stages import cohort_all
        return CohortOut(mask=cohort_all(ctx.m, ctx.active), rng=rng,
                         v=jnp.int32(0), full=jnp.asarray(False))

    spec = ProtocolSpec(trigger="cadence", cohort="fx_rogue_v",
                        name="fx-rogue")
    rules = {f.rule for f in contracts.check_spec(spec)}
    assert "counter-owner" in rules


def test_int32_ledger_accumulator_is_caught(registry_sandbox):
    """A trigger carrying an int32 per-learner byte counter that grows by
    a data-dependent amount with no reset: exactly the silent-wrap bug
    the int64 host-side ledger exists to avoid."""
    @register_trigger(
        "fx_bytes", params={"b": 1},
        init_extra=lambda p, m: {"bytes": jnp.zeros((m,), jnp.int32)},
        commit_extra=lambda ctx, mask:
            {"bytes": ctx.state.extra["bytes"]
             + mask.astype(jnp.int32) * 1000},
        skip_extra=lambda ctx: ctx.state.extra,
        contract=StageContract(summary="broken: int32 byte ledger",
                               extra_state=(("bytes", "int32"),)))
    def gate(ctx):
        return (ctx.t % ctx.params["b"]) == 0

    spec = ProtocolSpec(trigger="fx_bytes", name="fx-bytes")
    findings = audit.audit_spec(spec)
    assert any(f.rule == "int32-accumulator" for f in findings), \
        [f.render() for f in findings]
    # the contract checker accepts it (shapes/dtypes are consistent):
    # wrapping is a PROGRAM property, which is the auditor's job
    assert contracts.check_spec(spec) == []


def test_callback_in_scan_is_caught(registry_sandbox):
    @register_trigger("fx_chatty", params={"b": 1},
                      contract=StageContract(summary="broken: host debug"))
    def gate(ctx):
        jax.debug.print("t={t}", t=ctx.t)
        return (ctx.t % ctx.params["b"]) == 0

    spec = ProtocolSpec(trigger="fx_chatty", name="fx-chatty")
    findings = audit.audit_spec(spec)
    assert any(f.rule == "callback-in-scan" for f in findings), \
        [f.render() for f in findings]


def test_missing_contract_is_caught(registry_sandbox):
    @register_commit("fx_bare", needs=("full-cohort",))
    def bare_commit(ctx, cout, mean, hot, nhot):
        return SyncOut(ctx.stacked, ctx.state.ref, ctx.state.v, cout.rng,
                       ctx.state.extra, CommRecord.zero(),
                       jnp.zeros((ctx.m,), jnp.int32),
                       jnp.zeros((ctx.m,), jnp.int32))

    findings = contracts.check_registry()
    assert any(f.rule == "missing-contract" and "fx_bare" in f.where
               for f in findings)


def test_extra_state_declaration_mismatch_is_caught(registry_sandbox):
    @register_trigger(
        "fx_wrong_decl", params={"b": 1},
        init_extra=lambda p, m: {"age": jnp.zeros((m,), jnp.int32)},
        contract=StageContract(summary="broken: declares float32",
                               extra_state=(("age", "float32"),)))
    def gate(ctx):
        return (ctx.t % ctx.params["b"]) == 0

    findings = contracts.check_registry()
    assert any(f.rule == "extra-state" and "fx_wrong_decl" in f.where
               for f in findings)


# ---------------------------------------------------------------------------
# auditor unit rules (no registries involved)
# ---------------------------------------------------------------------------

def test_audit_flags_data_dependent_int32_carry():
    def chunk(x):
        def body(carry, _):
            acc, y = carry
            return (acc + jnp.sum(y).astype(jnp.int32), y * 2), ()
        return jax.lax.scan(body, (jnp.int32(0), x), None, length=4)

    findings = audit.audit_fn(chunk, jax.ShapeDtypeStruct((3,), jnp.float32))
    assert any(f.rule == "int32-accumulator" for f in findings)


def test_audit_exempts_clock_and_reset_counters():
    """The engine's own idioms must stay clean: a literal-step clock and
    a counter reset through jnp.where."""
    def chunk(x):
        def body(carry, _):
            t, v, y = carry
            vn = v + jnp.sum(y > 0).astype(jnp.int32)
            vn = jnp.where(vn >= 3, jnp.int32(0), vn)
            return (t + 1, vn, y * 0.5), ()
        return jax.lax.scan(body, (jnp.int32(0), jnp.int32(0), x), None,
                            length=4)

    assert audit.audit_fn(chunk,
                          jax.ShapeDtypeStruct((3,), jnp.float32)) == []


def test_audit_flags_float64_leak():
    def leak(x):
        return x.astype(jnp.float64) * 2

    jax.config.update("jax_enable_x64", True)
    try:
        findings = audit.audit_fn(leak,
                                  jax.ShapeDtypeStruct((3,), jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", False)
    assert any(f.rule == "float64-leak" for f in findings)


def test_audit_hlo_text_backend():
    hlo = """HloModule m
  %p = f64[128]{0} parameter(0)
  %cc = f32[4]{0} custom-call(), custom_call_target="xla_python_cpu_callback"
"""
    rules = {f.rule for f in audit.audit_hlo(hlo)}
    assert "float64-leak" in rules
    assert "host-callback" in rules


# ---------------------------------------------------------------------------
# lint rules on source fixtures
# ---------------------------------------------------------------------------

def test_lint_bare_assert_and_version_probe():
    src = "import jax\ndef f(x):\n    assert x > 0\n    return jax.__version__\n"
    rules = {f.rule for f in lint.lint_source(src, "pkg/module.py")}
    assert rules == {"bare-assert", "jax-version"}
    # the same probe is LEGAL in the compat shim
    assert not any(f.rule == "jax-version"
                   for f in lint.lint_source(src, "pkg/compat.py"))


def test_lint_network_purity():
    clean = "import jax\nkey = jax.random.fold_in(jax.random.PRNGKey(0), 3)\n"
    assert lint.lint_source(clean, "repro/network/avail.py") == []
    for bad in ("import time\n", "import random\n",
                "import numpy as np\nx = np.random\n",
                "import jax\nk = jax.random.split\n",
                "def f():\n    global _state\n"):
        findings = lint.lint_source(bad, "repro/network/avail.py")
        assert any(f.rule == "network-impure" for f in findings), bad
        # identical source outside network/ is unconstrained
        assert not any(f.rule == "network-impure"
                       for f in lint.lint_source(bad, "repro/core/x.py"))


def test_lint_register_without_contract():
    src = "register_trigger('x', params={'b': 1})(lambda ctx: False)\n"
    assert any(f.rule == "contract-required"
               for f in lint.lint_source(src, "pkg/stages.py"))
    ok = ("register_trigger('x', contract=StageContract(summary='s'))"
          "(lambda ctx: False)\n")
    assert lint.lint_source(ok, "pkg/stages.py") == []


def test_lint_syntax_error_is_a_finding_not_a_crash():
    findings = lint.lint_source("def f(:\n", "pkg/broken.py")
    assert [f.rule for f in findings] == ["syntax-error"]


# ---------------------------------------------------------------------------
# the CLI gate
# ---------------------------------------------------------------------------

def test_cli_lint_nonzero_on_fixture(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(x):\n    assert x\n")
    assert analysis_main(["--lint", str(bad)]) == 1
    good = tmp_path / "good.py"
    good.write_text("def f(x):\n    return x\n")
    assert analysis_main(["--lint", str(good)]) == 0


def test_cli_no_args_prints_help():
    assert analysis_main([]) == 2


@pytest.mark.slow
def test_cli_subprocess_check_all():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--check-all"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
