"""Fault-tolerant fleet (ISSUE 10).

Claims pinned here:

* **purity** — every fault mask is a pure function of ``(fault_seed, t)``:
  reconstructable out of order, stable across evaluations, and a crash
  episode's restart round follows directly from the mask (the hypothesis
  properties).
* **inertness** — ``faults=None`` and a default ``FaultConfig()`` (all
  faults off) are BITWISE identical across every preset and layout:
  comm counters, per-link ledger, net-time, parameter bytes, and the
  telemetry JSONL of ``faults=None`` carries no fault fields at all.
* **defenses** — the ``trimmed_mean``/``median`` aggregates exclude
  non-finite values per coordinate; the ``quarantine`` commit heals
  suspect rows from the reference and the health counters reset exactly
  on the recovery commit; on an HONEST fleet the robust pipeline's comm
  counters stay bitwise vs the plain one and ``trim_frac=0`` reproduces
  the mean to reassociation tolerance.
* **engine** — under heavy injected faults the robust presets keep every
  reachable honest row finite while the plain mean pipeline is poisoned;
  the one-shot ``nonfinite_loss`` event names the offending learners.
* **checkpoints** — a crash mid-save leaves the previous complete
  checkpoint on disk (atomic ``os.replace`` writes), never a truncated
  file.
"""
import hashlib
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (
    load_counters, load_protocol_spec, load_protocol_state,
    save_protocol_state,
)
from repro.config import FaultConfig, NetworkConfig, TelemetryConfig
from repro.core.protocol import DecentralizedLearner
from repro.core.sync import PROTOCOLS, apply_staged, init_state
from repro.core.sync.robust import (
    flat_median, flat_trimmed_mean, hardened,
)
from repro.network import faults as nf
from repro.telemetry.sink import get_logger

from hypothesis_compat import given, settings, st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_DEV = jax.device_count()
multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >= 2 devices (run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# tiny deterministic fleet (the test_async idiom)
# ---------------------------------------------------------------------------

def _loss(p, batch):
    x, y = batch
    return jnp.mean((x @ p["w"] - y) ** 2)


def _init(key):
    return {"w": jax.random.normal(key, (4,)) * 0.1}


def _batches(m, n, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (n, m, 8, 4))
    ys = jnp.sum(xs, axis=-1) * 0.5
    return (xs, ys)


def _digest(params) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _fingerprint(spec, *, network=None, faults=None, m=4, rounds=8,
                 seed=0, telemetry=None):
    dl = DecentralizedLearner(_loss, _init, m, spec, seed=seed,
                              network=network, faults=faults,
                              telemetry=telemetry)
    metrics = dl.run_chunk(_batches(m, rounds, seed))
    return dl, metrics, (dict(dl.comm_totals),
                         np.asarray(dl.link_bytes_totals).tolist(),
                         float(dl.network_time), _digest(dl.params))


BASE_SPECS = {
    "periodic": PROTOCOLS["periodic"].with_params(b=2),
    "continuous": PROTOCOLS["continuous"],
    "fedavg": PROTOCOLS["fedavg"].with_params(b=2),
    "gossip": PROTOCOLS["gossip"].with_params(b=2),
    "dynamic": PROTOCOLS["dynamic"].with_params(b=1, delta=0.05),
    "nosync": PROTOCOLS["nosync"],
    "stale": PROTOCOLS["stale"].with_params(tau=3),
    "robust_periodic": PROTOCOLS["robust_periodic"].with_params(b=2),
    "robust_dynamic": PROTOCOLS["robust_dynamic"].with_params(
        b=1, delta=0.05),
}

# heavy everything: crashes, corruption, adversaries, bursts
HEAVY = FaultConfig(fault_seed=7, crash_prob=0.3, byzantine_frac=0.25,
                    corrupt_prob=0.05, straggler_prob=0.3)


# ---------------------------------------------------------------------------
# the fault plane is pure in (fault_seed, t)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), t=st.integers(0, 200),
       m=st.integers(1, 12))
def test_crash_schedule_pure_in_seed_and_t(seed, t, m):
    cfg = FaultConfig(fault_seed=seed, crash_prob=0.4, crash_every=8,
                      outage_min=1, outage_max=4)
    a = np.asarray(nf.crash_mask(cfg, m, t))
    b = np.asarray(nf.crash_mask(cfg, m, t))      # out-of-order re-eval
    assert (a == b).all()
    # the restart round follows from the mask alone: crashed at t-1,
    # up at t
    r = np.asarray(nf.restart_mask(cfg, m, t))
    if t > 0:
        prev = np.asarray(nf.crash_mask(cfg, m, t - 1))
        assert (r == (prev & ~a)).all()
    else:
        assert not r.any()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), t=st.integers(0, 200),
       act_seed=st.integers(0, 2**16))
def test_crashed_learner_is_never_active(seed, t, act_seed):
    """crash ∧ availability never yields an active-but-stateless
    learner: the composition only removes."""
    m = 8
    cfg = FaultConfig(fault_seed=seed, crash_prob=0.5, straggler_prob=0.4,
                      straggler_frac=0.5)
    key = jax.random.fold_in(jax.random.PRNGKey(act_seed), t)
    avail = jax.random.uniform(key, (m,)) < 0.7
    active = np.asarray(nf.compose_active(cfg, avail, m, t))
    crashed = np.asarray(nf.crash_mask(cfg, m, t))
    burst = np.asarray(nf.straggler_burst_mask(cfg, m, t))
    assert not (active & crashed).any()
    assert not (active & burst).any()
    assert (active <= np.asarray(avail)).all()    # only ever removes


def test_byzantine_subset_is_fixed_and_sized():
    cfg = FaultConfig(fault_seed=3, byzantine_frac=0.25)
    a = np.asarray(nf.byzantine_mask(cfg, 8))
    assert a.sum() == 2
    assert (a == np.asarray(nf.byzantine_mask(cfg, 8))).all()
    assert not np.asarray(nf.byzantine_mask(FaultConfig(), 8)).any()


def test_perturb_modes_touch_only_marked_rows():
    cfg = FaultConfig(fault_seed=3, byzantine_frac=0.25,
                      byzantine_mode="sign_flip")
    byz = np.asarray(nf.byzantine_mask(cfg, 8))
    p = {"w": jnp.ones((8, 4))}
    out = np.asarray(nf.perturb_params(cfg, p, 8, 0)["w"])
    assert (out[byz] == -1.0).all() and (out[~byz] == 1.0).all()
    cfg = FaultConfig(fault_seed=3, byzantine_frac=0.25,
                      byzantine_mode="scale", byzantine_scale=10.0)
    out = np.asarray(nf.perturb_params(cfg, p, 8, 0)["w"])
    assert (out[byz] == 10.0).all() and (out[~byz] == 1.0).all()
    # corruption alternates Inf (even t) / NaN (odd t)
    cfg = FaultConfig(fault_seed=0, corrupt_prob=1.0)
    even = np.asarray(nf.perturb_params(cfg, p, 8, 0)["w"])
    odd = np.asarray(nf.perturb_params(cfg, p, 8, 1)["w"])
    assert np.isinf(even).all() and np.isnan(odd).all()


def test_fault_config_validation():
    with pytest.raises(ValueError, match="crash_prob"):
        FaultConfig(crash_prob=1.5)
    with pytest.raises(KeyError, match="byzantine_mode"):
        FaultConfig(byzantine_mode="gaslight")
    with pytest.raises(ValueError, match="outage"):
        FaultConfig(outage_min=5, outage_max=2)


# ---------------------------------------------------------------------------
# inertness: FaultConfig() == faults=None, bitwise
# ---------------------------------------------------------------------------

def test_inert_faultconfig_is_bitwise_noop():
    net = NetworkConfig(link_classes=("wired", "wifi"), act_prob=0.8,
                        seed=3)
    for name, spec in BASE_SPECS.items():
        for layout in ("tree", "flat"):
            s = spec.with_params(layout=layout)
            _, _, none_fp = _fingerprint(s, network=net)
            _, _, inert_fp = _fingerprint(s, network=net,
                                          faults=FaultConfig())
            assert inert_fp == none_fp, (name, layout)


def test_inert_faultconfig_ideal_network_bitwise():
    """No network: active stays None, so the inert config must keep the
    engine on the IDEAL expressions (compose_active passes None
    through)."""
    for name in ("periodic", "dynamic", "robust_dynamic"):
        _, _, none_fp = _fingerprint(BASE_SPECS[name])
        _, _, inert_fp = _fingerprint(BASE_SPECS[name],
                                      faults=FaultConfig())
        assert inert_fp == none_fp, name


@multi_device
def test_inert_faultconfig_sharded_bitwise():
    net = NetworkConfig(link_classes=("wired",), act_prob=0.8, seed=3)
    for name in ("periodic", "dynamic", "robust_periodic",
                 "robust_dynamic"):
        s = BASE_SPECS[name].with_params(layout="sharded")
        _, _, none_fp = _fingerprint(s, network=net, m=N_DEV)
        _, _, inert_fp = _fingerprint(s, network=net, m=N_DEV,
                                      faults=FaultConfig())
        assert inert_fp == none_fp, name


def test_none_faults_stream_has_no_fault_fields(tmp_path):
    """The faults=None JSONL carries no fault keys (static gating keeps
    old streams byte-compatible); a faulty robust run carries all three
    and two identical runs stream identical bytes."""
    a = str(tmp_path / "clean.jsonl")
    dl, _, _ = _fingerprint(BASE_SPECS["dynamic"],
                            telemetry=TelemetryConfig(path=a))
    dl.recorder.close()
    with open(a) as f:
        recs = [json.loads(ln) for ln in f]
    for r in recs:
        if r["kind"] == "round":
            assert "num_faulty" not in r
            assert "num_quarantined" not in r

    def faulty(path):
        dl, _, _ = _fingerprint(
            BASE_SPECS["robust_dynamic"], faults=HEAVY,
            telemetry=TelemetryConfig(path=path))
        dl.recorder.close()

    b, c = str(tmp_path / "b.jsonl"), str(tmp_path / "c.jsonl")
    faulty(b)
    faulty(c)
    with open(b, "rb") as fb, open(c, "rb") as fc:
        assert fb.read() == fc.read()             # pure in (seed, t)
    with open(b) as f:
        rounds = [json.loads(ln) for ln in f
                  if json.loads(ln)["kind"] == "round"]
    assert all("num_faulty" in r and "num_quarantined" in r
               and "num_recovered" in r for r in rounds)
    assert any(r["num_faulty"] > 0 for r in rounds)


# ---------------------------------------------------------------------------
# robust aggregates: order statistics vs numpy
# ---------------------------------------------------------------------------

def test_trimmed_mean_and_median_match_numpy():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(7, 5)).astype(np.float32)
    X[2, 1] = np.nan
    X[5, 3] = np.inf
    mask = np.array([1, 1, 1, 0, 1, 1, 1], bool)
    got_med = np.asarray(flat_median(jnp.asarray(X), jnp.asarray(mask)))
    got_tm = np.asarray(flat_trimmed_mean(jnp.asarray(X),
                                          jnp.asarray(mask), 0.2))
    for j in range(5):
        col = X[mask, j]
        col = col[np.isfinite(col)]
        assert got_med[j] == pytest.approx(np.median(col), abs=1e-6), j
        k = int(np.floor(0.2 * len(col)))
        kept = np.sort(col)[k:len(col) - k] if len(col) > 2 * k else col
        assert got_tm[j] == pytest.approx(kept.mean(), abs=1e-6), j
    # empty coordinate -> 0, not NaN
    none = flat_median(jnp.full((3, 2), jnp.nan),
                       jnp.ones((3,), bool))
    assert (np.asarray(none) == 0.0).all()


def test_trim_frac_zero_reproduces_mean():
    """trim_frac=0 on an honest fleet is the plain mean to
    reassociation tolerance (the sum runs in sorted order)."""
    plain = PROTOCOLS["periodic"].with_params(b=2)
    robust = PROTOCOLS["robust_periodic"].with_params(b=2, trim_frac=0.0)
    a, _, _ = _fingerprint(plain, rounds=8)
    b, _, _ = _fingerprint(robust, rounds=8)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_honest_fleet_comm_counters_bitwise_vs_plain():
    """The quarantine ledger is expression-identical to commit_average:
    on an honest fleet every comm counter matches the plain pipeline
    bitwise, with and without availability masks."""
    net = NetworkConfig(link_classes=("wired", "wifi"), act_prob=0.7,
                        seed=5)
    for network in (None, net):
        a, am, _ = _fingerprint(PROTOCOLS["periodic"].with_params(b=2),
                                network=network)
        b, bm, _ = _fingerprint(
            PROTOCOLS["robust_periodic"].with_params(b=2),
            network=network)
        assert a.comm_totals == b.comm_totals
        assert a.link_xfer_totals.tolist() == b.link_xfer_totals.tolist()
        assert np.asarray(a.link_bytes_totals).tolist() == \
            np.asarray(b.link_bytes_totals).tolist()
        assert float(a.network_time) == float(b.network_time)


def test_robust_validation():
    with pytest.raises(ValueError, match="trim_frac"):
        PROTOCOLS["robust_periodic"].with_params(trim_frac=0.5)
    with pytest.raises(ValueError, match="quarantine_mult"):
        PROTOCOLS["robust_periodic"].with_params(quarantine_mult=1.0)


# ---------------------------------------------------------------------------
# quarantine + health counters at the stage level
# ---------------------------------------------------------------------------

def _stage_fleet(m=6, d=4, bad_rows=(), byz_rows=()):
    ref = {"w": jnp.ones((d,))}
    stacked = jnp.broadcast_to(ref["w"], (m, d)) + 0.01
    for r in bad_rows:
        stacked = stacked.at[r].set(jnp.nan)
    for r in byz_rows:
        stacked = stacked.at[r].set(-5.0)
    return ref, {"w": stacked}


@pytest.mark.parametrize("layout", ["tree", "flat"])
def test_quarantine_heals_and_health_counts(layout):
    spec = PROTOCOLS["robust_periodic"].with_params(b=1, layout=layout)
    m = 6
    ref, stacked = _stage_fleet(m, bad_rows=(1,), byz_rows=(4,))
    state = init_state(ref, 0, spec=spec, m=m)
    res = apply_staged(spec, stacked, state)
    w = np.asarray(res.params["w"])
    assert np.isfinite(w).all()
    # suspect rows got the REFERENCE, not the aggregate
    assert (w[1] == np.asarray(ref["w"])).all()
    assert (w[4] == np.asarray(ref["w"])).all()
    assert np.asarray(res.state.extra["health"]).tolist() == [
        0, 1, 0, 0, 1, 0]
    assert np.asarray(res.state.extra["recovered"]).tolist() == [0] * m
    # next commit: the healed rows have caught up with the fleet (in the
    # engine a local-training step moves them off the stale warm-start
    # point) and come back clean -> recovery flags, health resets exactly
    caught_up = {"w": jnp.broadcast_to(res.state.ref["w"], (m, 4)) + 0.01}
    res2 = apply_staged(spec, caught_up, res.state)
    assert np.asarray(res2.state.extra["health"]).tolist() == [0] * m
    assert np.asarray(res2.state.extra["recovered"]).tolist() == [
        0, 1, 0, 0, 1, 0]


@settings(max_examples=10, deadline=None)
@given(bad=st.sets(st.integers(0, 5), max_size=2))
def test_health_resets_exactly_on_recovery_commit(bad):
    """For any minority set of NaN rows: one commit quarantines exactly
    that set, the next (clean) commit flags exactly that set as
    recovered and zeroes every counter."""
    spec = PROTOCOLS["robust_periodic"].with_params(b=1)
    m = 6
    ref, stacked = _stage_fleet(m, bad_rows=tuple(bad))
    state = init_state(ref, 0, spec=spec, m=m)
    res = apply_staged(spec, stacked, state)
    want = [1 if i in bad else 0 for i in range(m)]
    assert np.asarray(res.state.extra["health"]).tolist() == want
    caught_up = {"w": jnp.broadcast_to(res.state.ref["w"], (m, 4)) + 0.01}
    res2 = apply_staged(spec, caught_up, res.state)
    assert np.asarray(res2.state.extra["health"]).tolist() == [0] * m
    assert np.asarray(res2.state.extra["recovered"]).tolist() == want


def test_skip_rounds_keep_health_and_clear_recovered():
    spec = PROTOCOLS["robust_periodic"].with_params(b=4)
    m = 6
    ref, stacked = _stage_fleet(m, bad_rows=(2,))
    state = init_state(ref, 0, spec=spec, m=m)
    res = apply_staged(spec, stacked, state)      # t=1: gate closed
    assert int(np.asarray(res.rec.syncs)) == 0
    assert np.asarray(res.state.extra["health"]).tolist() == [0] * m


def test_robust_divergence_fires_on_nan_row():
    """The finite guard: a NaN row never exceeds delta numerically, but
    it must still pull the fleet into the healing sync."""
    spec = PROTOCOLS["robust_dynamic"].with_params(b=1, delta=1e9)
    m = 6
    ref, stacked = _stage_fleet(m, bad_rows=(2,))
    state = init_state(ref, 0, spec=spec, m=m)
    res = apply_staged(spec, stacked, state)
    assert int(np.asarray(res.rec.syncs)) == 1
    assert np.isfinite(np.asarray(res.params["w"])).all()
    # honest fleet at the same huge delta: nothing fires
    _, honest = _stage_fleet(m)
    res = apply_staged(spec, honest, init_state(ref, 0, spec=spec, m=m))
    assert int(np.asarray(res.rec.syncs)) == 0


# ---------------------------------------------------------------------------
# hardened(): the robust rewriter
# ---------------------------------------------------------------------------

def test_hardened_rewrites_and_preserves_params():
    sp = hardened(PROTOCOLS["periodic"].with_params(b=3))
    assert sp.trigger == "robust_cadence"
    assert sp.aggregate == "trimmed_mean" and sp.commit == "quarantine"
    assert sp.param("b") == 3
    sp = hardened(sp)                              # idempotent
    assert sp.trigger == "robust_cadence"
    sp = hardened(PROTOCOLS["periodic"], aggregate="median",
                  quarantine_mult=9.0)
    assert sp.aggregate == "median"
    assert sp.param("quarantine_mult") == 9.0
    sp = hardened(PROTOCOLS["periodic"], trim_frac=0.3)
    assert sp.aggregate == "trimmed_mean"
    assert sp.param("trim_frac") == 0.3


def test_hardened_rejects_unrewritable_compositions():
    with pytest.raises(ValueError, match="robust_dynamic"):
        hardened(PROTOCOLS["dynamic"])             # balancing commit
    with pytest.raises(ValueError, match="trigger"):
        hardened(PROTOCOLS["stale"])
    with pytest.raises(ValueError, match="aggregate"):
        hardened(PROTOCOLS["periodic"], aggregate="mean")


# ---------------------------------------------------------------------------
# the engine under heavy faults
# ---------------------------------------------------------------------------

def test_robust_presets_survive_heavy_faults():
    m = 8
    byz = np.asarray(nf.byzantine_mask(HEAVY, m))
    for name in ("robust_periodic", "robust_dynamic"):
        dl, metrics, _ = _fingerprint(BASE_SPECS[name], faults=HEAVY,
                                      m=m, rounds=24)
        t_last = 23
        reach = np.asarray(nf.compose_active(HEAVY, None, m, t_last))
        corrupt = np.asarray(nf.corrupt_mask(HEAVY, m, t_last))
        ok = reach & ~byz & ~corrupt
        w = np.asarray(jax.device_get(dl.params["w"]))
        assert np.isfinite(w[ok]).all(), name
        assert np.isfinite(
            np.asarray(jax.device_get(dl.sync_state.ref["w"]))).all()
        # the per-round fault metrics see the injections
        assert int(np.asarray(metrics.num_faulty).sum()) > 0
        assert int(np.asarray(metrics.num_quarantined).max()) > 0


def test_plain_mean_is_poisoned_by_corruption():
    dl, _, _ = _fingerprint(BASE_SPECS["dynamic"],
                            faults=FaultConfig(fault_seed=7,
                                               corrupt_prob=0.2),
                            rounds=16)
    assert not np.isfinite(dl.cumulative_loss_per_learner).all()


def test_crash_freezes_training_and_restarts_cold():
    """A crashed learner observes zero loss; the restart round zeroes
    its carried rows before the local step."""
    m = 4
    cfg = FaultConfig(fault_seed=1, crash_prob=0.9, crash_every=8,
                      outage_min=2, outage_max=4)
    dl, metrics, _ = _fingerprint(PROTOCOLS["nosync"], faults=cfg,
                                  m=m, rounds=8)
    losses = np.asarray(metrics.loss_per_learner)          # (n, m)
    for t in range(8):
        crashed = np.asarray(nf.crash_mask(cfg, m, t))
        assert (losses[t][crashed] == 0.0).all(), t


def test_nonfinite_loss_event_fires_once_with_learners(tmp_path):
    events = []
    log = get_logger()
    handler = log.add_handler(events.append)
    try:
        dl, _, _ = _fingerprint(
            BASE_SPECS["dynamic"],
            faults=FaultConfig(fault_seed=7, corrupt_prob=0.3),
            rounds=16)
        hits = [e for e in events if e["kind"] == "nonfinite_loss"]
        assert len(hits) == 1                      # one-shot
        assert hits[0]["learners"], hits
        bad = ~np.isfinite(dl.cumulative_loss_per_learner)
        assert set(hits[0]["learners"]) <= set(np.flatnonzero(bad))
        # more rounds: still silent
        dl.run_chunk(_batches(4, 4, seed=9))
        assert len([e for e in events
                    if e["kind"] == "nonfinite_loss"]) == 1
    finally:
        log.remove_handler(handler)
    # a clean run emits nothing
    events.clear()
    handler = log.add_handler(events.append)
    try:
        _fingerprint(BASE_SPECS["dynamic"], rounds=8)
        assert not [e for e in events if e["kind"] == "nonfinite_loss"]
    finally:
        log.remove_handler(handler)


def test_fault_card_reconstructs_from_stream(tmp_path):
    path = str(tmp_path / "faulty.jsonl")
    dl, _, _ = _fingerprint(BASE_SPECS["robust_dynamic"], faults=HEAVY,
                            rounds=24, m=8,
                            telemetry=TelemetryConfig(path=path))
    dl.recorder.close()
    from repro.telemetry.observatory import load_run, summarize
    card = summarize(load_run(path))
    assert "faults" in card
    assert card["faults"]["faulty_rounds"] > 0
    assert card["faults"]["max_faulty"] >= 1
    assert card["faults"]["total_recovered"] >= 0
    assert card["faults"]["faulty"] and card["faults"]["quarantine"]


# ---------------------------------------------------------------------------
# atomic checkpoints: crash mid-save leaves the previous one intact
# ---------------------------------------------------------------------------

def _checkpointable(m=4):
    dl = DecentralizedLearner(_loss, _init, m,
                              PROTOCOLS["robust_periodic"].with_params(b=2))
    dl.run_chunk(_batches(m, 4))
    return dl


def test_checkpoint_roundtrip_with_health_state(tmp_path):
    dl = _checkpointable()
    base = str(tmp_path / "ckpt")
    save_protocol_state(base, dl.params, dl.opt_state, dl.sync_state,
                        protocol=dl.spec, counters={"rounds": 4})
    params, _, state = load_protocol_state(base)
    assert _digest(params) == _digest(dl.params)
    assert sorted(state.extra) == ["health", "recovered"]
    assert load_protocol_spec(base).trigger == "robust_cadence"
    assert load_counters(base) == {"rounds": 4}


def test_checkpoint_crash_mid_save_keeps_previous(tmp_path, monkeypatch):
    dl = _checkpointable()
    base = str(tmp_path / "ckpt")
    save_protocol_state(base, dl.params, dl.opt_state, dl.sync_state,
                        protocol=dl.spec, counters={"rounds": 4})
    want = _digest(load_protocol_state(base)[0])

    real_savez = np.savez

    def dying_savez(f, **kw):
        f.write(b"this is not an npz")            # partial garbage...
        raise RuntimeError("simulated crash mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    dl.run_chunk(_batches(4, 2, seed=5))          # newer state to save
    with pytest.raises(RuntimeError, match="simulated crash"):
        save_protocol_state(base, dl.params, dl.opt_state, dl.sync_state)
    monkeypatch.setattr(np, "savez", real_savez)

    # the previous complete checkpoint is untouched and still loads;
    # no temp litter remains
    params, _, state = load_protocol_state(base)
    assert _digest(params) == want
    assert not [f for f in os.listdir(tmp_path) if ".tmp." in f]


def test_checkpoint_crash_mid_sidecar_keeps_previous(tmp_path,
                                                     monkeypatch):
    dl = _checkpointable()
    base = str(tmp_path / "ckpt")
    save_protocol_state(base, dl.params, dl.opt_state, dl.sync_state,
                        counters={"rounds": 4})

    import repro.checkpoint.io as io

    def dying_text(path, text):
        raise RuntimeError("simulated crash before sidecar write")

    monkeypatch.setattr(io, "_atomic_text", dying_text)
    with pytest.raises(RuntimeError):
        save_protocol_state(base, dl.params, dl.opt_state, dl.sync_state,
                            counters={"rounds": 9})
    monkeypatch.undo()
    assert load_counters(base) == {"rounds": 4}


# ---------------------------------------------------------------------------
# the example is runnable (subprocess; excluded from tier-1 via -m slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_faulty_fleet_example_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "faulty_fleet.py"),
         "--smoke"],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "faulty_fleet_done" in r.stdout
