"""Substrate tests: optimizers, data sources, checkpointing, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_arch
from repro.optim import make_optimizer


# ---------------------------------------------------------------------------
# optimizers (the paper's black-box phi: SGD / momentum / Adam / RMSprop)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "rmsprop"])
def test_optimizer_minimizes_quadratic(name):
    opt = make_optimizer(TrainConfig(optimizer=name, learning_rate=0.05))
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(params, g, state)
    assert float(loss(params)) < 1e-2


def test_sgd_exact_step():
    opt = make_optimizer(TrainConfig(optimizer="sgd", learning_rate=0.1))
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 2.0)}
    new, _ = opt.update(p, g, opt.init(p))
    np.testing.assert_allclose(np.asarray(new["w"]), 0.8)


# ---------------------------------------------------------------------------
# data sources
# ---------------------------------------------------------------------------

def test_synthetic_mnist_learnable_shapes():
    from repro.data.synthetic import SyntheticMNIST
    src = SyntheticMNIST(seed=0)
    b = src.sample(jax.random.PRNGKey(0), 16)
    assert b["x"].shape == (16, 28, 28, 1)
    assert b["y"].shape == (16,)
    assert int(jnp.max(b["y"])) <= 9


def test_graphical_model_drift_changes_concept():
    from repro.data.synthetic import GraphicalModelStream
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    k = jax.random.PRNGKey(0)
    y1 = src.sample(k, 512)["y"]
    src.force_drift()
    y2 = src.sample(k, 512)["y"]     # same inputs key, new concept
    # labels differ for a nontrivial fraction of points
    frac = float(jnp.mean((y1 != y2).astype(jnp.float32)))
    assert frac > 0.05


def test_token_stream_and_determinism():
    from repro.data.synthetic import TokenStream
    src = TokenStream(seed=0, vocab=64)
    b1 = src.sample(jax.random.PRNGKey(1), 4, 16)
    b2 = src.sample(jax.random.PRNGKey(1), 4, 16)
    assert b1["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_learner_streams_layout():
    from repro.data.pipeline import LearnerStreams
    from repro.data.synthetic import GraphicalModelStream
    src = GraphicalModelStream(seed=0)
    streams = LearnerStreams(src, m=5, batch=7, seed=0)
    b = streams.next()
    assert b["x"].shape == (5, 7, 50)
    # different learners see different samples
    assert not np.allclose(np.asarray(b["x"][0]), np.asarray(b["x"][1]))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.io import load_pytree, save_pytree
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "layers": [{"b": jnp.ones((2,))}, {"b": jnp.zeros((2,))}]},
        "step": jnp.int32(7),
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    loaded = load_pytree(path)
    flat_a = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(loaded)[0]
    assert len(flat_a) == len(flat_b)
    for (pa, la), (pb, lb) in zip(flat_a, flat_b):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_protocol_state(tmp_path):
    from repro.checkpoint.io import load_pytree, save_pytree
    from repro.core import operators as ops
    state = ops.init_state({"w": jnp.ones((3,))}, seed=4)
    path = os.path.join(tmp_path, "proto.npz")
    save_pytree(path, state._asdict())
    loaded = load_pytree(path)
    np.testing.assert_array_equal(np.asarray(loaded["ref"]["w"]),
                                  np.ones(3))
    assert int(loaded["v"]) == 0


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serve_engine_generates():
    from repro.models.model import init_lm_params
    from repro.serve.engine import ServeEngine
    cfg = get_arch("llama3-8b", smoke=True)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=64, batch=2)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    logits = eng.feed(prompt)
    assert logits.shape == (2, cfg.vocab_size)
    out = eng.generate(8, first_logits=logits)
    assert out.shape == (2, 8)
    assert int(jnp.max(out)) < cfg.vocab_size


def test_sliding_window_ring_buffer_decode():
    """Decode beyond the window: ring-buffer cache stays bounded and matches
    a full forward restricted to the window."""
    from repro.models.model import (
        init_lm_cache, init_lm_params, lm_apply, lm_decode_step)
    cfg = get_arch("mixtral-8x22b", smoke=True)   # sliding_window=16
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    T = 40   # > 2x window
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, T), 0,
                              cfg.vocab_size)
    cache = init_lm_cache(cfg, 1, max_seq=T)
    # ring buffer: cache seq dim == window, not T
    assert jax.tree.leaves(cache)[0].shape[2] <= cfg.sliding_window + 1
    step = jax.jit(lambda p, c, t, pos: lm_decode_step(cfg, p, t, c, pos))
    for t in range(T):
        logits, cache = step(params, cache, toks[:, t], jnp.int32(t))
    full_logits, _ = lm_apply(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-2, atol=2e-3)
