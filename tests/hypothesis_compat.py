"""Optional-`hypothesis` shim for the property-based test modules.

``from hypothesis_compat import given, settings, st`` behaves exactly like
the real ``hypothesis`` import when the package is installed (pinned in
requirements-dev.txt). When it is missing, the decorators become stubs that
replace each property-based test with a ``pytest.skip`` — so collection
never errors and every non-property test in the module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _skipping_decorator(*_args, **_kwargs):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake the property
            # arguments (m=..., seed=...) for fixtures
            def wrapper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            wrapper.__name__ = getattr(fn, "__name__", "property_test")
            wrapper.__doc__ = getattr(fn, "__doc__", None)
            return wrapper
        return deco

    given = _skipping_decorator
    settings = _skipping_decorator

    class _AnyStrategy:
        """Accepts any ``st.<strategy>(...)`` call the tests make."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
