"""Integration tests for the m-learner simulator (paper Section 5 dynamics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream, SyntheticMNIST
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training


def _mlp_setup():
    cfg = get_arch("drift_mlp", smoke=True)
    return (lambda p, b: cnn_loss(cfg, p, b),
            lambda k: init_cnn_params(cfg, k))


def test_learners_learn_and_account_comm():
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    dl, traj = run_protocol_training(
        loss_fn, init_fn, src, m=5, rounds=60,
        protocol=ProtocolConfig(kind="periodic", b=10),
        train=TrainConfig(optimizer="sgd", learning_rate=0.05),
        batch=10, record_every=10)
    # loss per round decreases
    per_round = np.diff([0.0] + traj.cumulative_loss)
    assert per_round[-1] < per_round[0]
    # communication: 6 syncs * 2 transfers * m models
    assert dl.comm_totals["syncs"] == 6
    assert dl.comm_totals["model_up"] == 6 * 5
    assert dl.comm_bytes() == 6 * 2 * 5 * dl.model_size * 4


def test_dynamic_beats_periodic_comm_similar_loss():
    """The paper's core claim (Fig. 5.1) on a small task."""
    loss_fn, init_fn = _mlp_setup()

    def run(proto, seed=0):
        src = GraphicalModelStream(seed=1, drift_prob=0.0)
        return run_protocol_training(
            loss_fn, init_fn, src, m=8, rounds=80, protocol=proto,
            train=TrainConfig(optimizer="sgd", learning_rate=0.05),
            batch=10, seed=seed)

    dl_p, _ = run(ProtocolConfig(kind="periodic", b=10))
    dl_d, _ = run(ProtocolConfig(kind="dynamic", b=10, delta=0.3))
    assert dl_d.comm_bytes() < dl_p.comm_bytes()
    # predictive performance within 15%
    assert dl_d.cumulative_loss < 1.15 * dl_p.cumulative_loss


def test_drift_triggers_communication_burst():
    """Fig. 5.4(b): dynamic averaging concentrates COMMUNICATION right
    after a drift.

    The paper's claim is about communication volume, not sync-event
    counts: in a calm converged fleet the reference model r goes stale
    (it only refreshes on full syncs), so SGD noise produces a steady
    trickle of CHEAP partial averages — 1-3 models moved per event. A
    drift instead moves every learner coherently away from r, balancing
    escalates to B = [m], and the protocol pays full synchronizations
    (m models up + m down, plus a reference reset). Sync-event counts
    can therefore TIE or even favour calm (the post-drift reference
    resets suppress follow-up violations); model transfers separate the
    regimes robustly (measured over seeds 0-3: calm 4-8 transfers vs
    burst 17-19, with >= 2 full syncs after every drift)."""
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, 6, batch=10, seed=0)
    dl = DecentralizedLearner(
        loss_fn, init_fn, 6,
        ProtocolConfig(kind="dynamic", b=2, delta=0.5),
        TrainConfig(optimizer="sgd", learning_rate=0.1))
    # converge first
    dl.run_chunk(streams.next_chunk(100))
    before = dict(dl.comm_totals)
    dl.run_chunk(streams.next_chunk(24))
    calm_up = dl.comm_totals["model_up"] - before["model_up"]
    src.force_drift()
    before = dict(dl.comm_totals)
    dl.run_chunk(streams.next_chunk(24))
    burst_up = dl.comm_totals["model_up"] - before["model_up"]
    burst_full = dl.comm_totals["full_syncs"] - before["full_syncs"]
    assert burst_up > calm_up
    assert burst_full >= 1           # the drift forced a reference reset
    assert dl.comm_totals["syncs"] - before["syncs"] >= 1


def test_heterogeneous_init_increases_divergence():
    loss_fn, init_fn = _mlp_setup()
    dl_hom = DecentralizedLearner(
        loss_fn, init_fn, 4, ProtocolConfig(kind="nosync"),
        track_divergence=True)
    dl_het = DecentralizedLearner(
        loss_fn, init_fn, 4, ProtocolConfig(kind="nosync"),
        init_heterogeneity=3.0, track_divergence=True)
    from repro.core.divergence import divergence
    assert float(divergence(dl_hom.params)) < 1e-10
    assert float(divergence(dl_het.params)) > 1e-3


def test_unbalanced_streams_weighted_protocol():
    """Algorithm 2: unbalanced B^i with weighted averaging runs and the
    weighted mean preserves the sample-weighted model (App. C)."""
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    sizes = [5, 10, 20]
    streams = LearnerStreams(src, 3, batch=10, seed=0, batch_sizes=sizes)
    dl = DecentralizedLearner(
        loss_fn, init_fn, 3,
        ProtocolConfig(kind="dynamic", b=1, delta=1e-9, weighted=True),
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        sample_weights=streams.weights)
    for _ in range(5):
        m = dl.step(streams.next())
    assert dl.comm_totals["syncs"] >= 1
    assert np.isfinite(dl.cumulative_loss)


def test_mnist_cnn_protocol_end_to_end():
    """The paper's main experimental setup, reduced: CNN + dynamic avg."""
    cfg = get_arch("mnist_cnn", smoke=True)
    loss_fn = lambda p, b: cnn_loss(cfg, p, b)
    init_fn = lambda k: init_cnn_params(cfg, k)
    src = SyntheticMNIST(seed=0, image_size=14)
    dl, traj = run_protocol_training(
        loss_fn, init_fn, src, m=4, rounds=50,
        protocol=ProtocolConfig(kind="dynamic", b=5, delta=0.5),
        train=TrainConfig(optimizer="sgd", learning_rate=0.1), batch=10)
    from repro.models.cnn import cnn_accuracy
    batch = src.sample(jax.random.PRNGKey(99), 256)
    acc = float(cnn_accuracy(cfg, dl.mean_model(), batch))
    assert acc > 0.5       # well above 10% chance
