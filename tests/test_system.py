"""End-to-end system behaviour: the paper's full pipeline on a small task.

One test = one claim of the paper, reduced to CPU scale:
  * a fleet of learners + dynamic averaging reaches the periodic baseline's
    loss with strictly less communication (Fig. 5.1 / 5.3),
  * the protocol is black-box in the optimizer (Fig. A.6),
  * scale-out in m keeps the advantage (Fig. 6.1).
"""
import jax
import numpy as np
import pytest

from repro.config import ProtocolConfig, TrainConfig, get_arch
from repro.data.synthetic import SyntheticMNIST
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.train.loop import run_protocol_training


def _setup():
    cfg = get_arch("mnist_cnn", smoke=True)
    return (lambda p, b: cnn_loss(cfg, p, b),
            lambda k: init_cnn_params(cfg, k))


def _run(proto, m=6, rounds=60, optimizer="sgd", lr=0.1, seed=0):
    loss_fn, init_fn = _setup()
    src = SyntheticMNIST(seed=0, image_size=14)
    return run_protocol_training(
        loss_fn, init_fn, src, m=m, rounds=rounds, protocol=proto,
        train=TrainConfig(optimizer=optimizer, learning_rate=lr),
        batch=10, seed=seed)


def test_dynamic_vs_periodic_tradeoff():
    dl_p, _ = _run(ProtocolConfig(kind="periodic", b=10))
    dl_d, _ = _run(ProtocolConfig(kind="dynamic", b=10, delta=0.7))
    assert dl_d.comm_bytes() < dl_p.comm_bytes()
    assert dl_d.cumulative_loss < 1.2 * dl_p.cumulative_loss


def test_fedavg_vs_dynamic():
    dl_f, _ = _run(ProtocolConfig(kind="fedavg", b=10, fedavg_c=0.3))
    dl_d, _ = _run(ProtocolConfig(kind="dynamic", b=10, delta=0.7))
    assert np.isfinite(dl_d.cumulative_loss)
    assert np.isfinite(dl_f.cumulative_loss)
    # FedAvg's comm is fixed-rate; dynamic adapts downward as models converge
    assert dl_d.comm_bytes() <= dl_f.comm_bytes() * 2


@pytest.mark.parametrize("optimizer,lr", [
    ("sgd", 0.1), ("adam", 1e-3), ("rmsprop", 1e-3)])
def test_black_box_optimizers(optimizer, lr):
    """Paper A.5: the protocol works with phi = SGD / Adam / RMSprop."""
    dl, _ = _run(ProtocolConfig(kind="dynamic", b=5, delta=0.7),
                 rounds=40, optimizer=optimizer, lr=lr)
    per_round = dl.cumulative_loss / dl.rounds
    assert np.isfinite(per_round)
    assert dl.comm_totals["syncs"] >= 0


def test_scaleout_m():
    """Fig. 6.1: growing m keeps communication sublinear vs periodic."""
    for m in (4, 8):
        dl_p, _ = _run(ProtocolConfig(kind="periodic", b=10), m=m, rounds=40)
        dl_d, _ = _run(ProtocolConfig(kind="dynamic", b=10, delta=0.7),
                       m=m, rounds=40)
        assert dl_d.comm_bytes() <= dl_p.comm_bytes()
