"""Staged sync kernel (ISSUE 3): stage compositions, the bytes ledger,
and the two-tier hierarchy.

The load-bearing test is the golden regression: with ``tiers=None`` every
operator (periodic/fedavg/dynamic/gossip/nosync, with and without a
``NetworkConfig``, weighted and not) must reproduce the PRE-KERNEL engine
bitwise — comm-counter totals, exact cumulative loss, SHA-256 over the
final parameter bytes, per-link transfer totals — pinned by
``tests/golden_pr2_engine.json`` (captured from the PR-2 monoliths;
regenerate with ``tests/golden_pr2_capture.py`` only against a
known-good engine).
"""
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import (
    HierarchyConfig, NetworkConfig, ProtocolConfig, TrainConfig, get_arch,
)
from repro.core import operators as ops
from repro.core.divergence import tree_mean
from repro.core.sync import hierarchy as hier
from repro.core.sync import kernel, stages
from repro.core.protocol import DecentralizedLearner, SerialLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.network import topology

from conftest import make_stacked, tree_allclose
from golden_pr2_capture import CASES, M, ROUNDS, params_sha256, run_case


# ---------------------------------------------------------------------------
# golden regression: the staged kernel == the PR-2 monoliths, bitwise
# ---------------------------------------------------------------------------

with open(os.path.join(os.path.dirname(__file__),
                       "golden_pr2_engine.json")) as f:
    GOLDEN = json.load(f)
GOLDEN_JAX = GOLDEN.get("_meta", {}).get("jax_version")


@pytest.mark.skipif(
    jax.__version__ != GOLDEN_JAX,
    reason=f"bitwise goldens captured under jax {GOLDEN_JAX}; XLA codegen "
           f"on jax {jax.__version__} need not match bit-for-bit — "
           f"regenerate with tests/golden_pr2_capture.py to pin this "
           f"version")
@pytest.mark.parametrize("name", sorted(CASES))
def test_staged_kernel_reproduces_pr2_engine_bitwise(name):
    """ISSUE-3 acceptance: tiers=None + the staged compositions are the
    PR-2 engine — params SHA-256, comm totals, loss, per-link transfers."""
    got = run_case(*CASES[name])
    want = GOLDEN[name]
    assert got["comm_totals"] == want["comm_totals"], name
    assert got["cumulative_loss"] == want["cumulative_loss"], name
    assert got["params_sha256"] == want["params_sha256"], name
    assert got["link_xfer_totals"] == want["link_xfer_totals"], name
    assert got["network_time"] == want["network_time"], name


def test_apply_operator_signature_unchanged():
    """The pre-kernel 4-tuple contract survives the decomposition."""
    stacked = make_stacked(jax.random.PRNGKey(0), 4)
    state = ops.init_state(tree_mean(stacked))
    out = ops.apply_operator(ProtocolConfig(kind="periodic", b=1),
                             stacked, state)
    assert len(out) == 4
    new, st2, rec, xfers = out
    assert isinstance(rec, ops.CommRecord) and xfers.shape == (4,)


# ---------------------------------------------------------------------------
# xfers / CommRecord invariants through the staged kernel (satellite)
# ---------------------------------------------------------------------------

ALL_KINDS = ["nosync", "periodic", "fedavg", "dynamic", "gossip"]


@settings(max_examples=40, deadline=None)
@given(kind=st.sampled_from(ALL_KINDS), m=st.integers(2, 8),
       seed=st.integers(0, 10_000), mask_bits=st.integers(0, 255),
       weighted=st.booleans())
def test_xfers_invariant_for_every_staged_operator(kind, m, seed, mask_bits,
                                                   weighted):
    """Documented ledger invariants, for EVERY operator through the staged
    kernel: coordinator links carry up+down (``sum(xfers) ==
    model_up + model_down``), a gossip transfer occupies BOTH endpoints'
    links (``== 2*(up+down)``), and the per-link control messages sum to
    the scalar record (``sum(link_msgs) == messages``)."""
    stacked = make_stacked(jax.random.PRNGKey(seed), m)
    active = jnp.asarray([(mask_bits >> i) & 1 == 1 for i in range(m)])
    kw = dict(b=1)
    if kind == "dynamic":
        kw["delta"] = 0.05
    cfg = ProtocolConfig(kind=kind, weighted=weighted, **kw)
    weights = jnp.arange(1.0, m + 1.0) if weighted else None
    adj = topology.ring(m) if kind == "gossip" else None
    res = ops.apply_staged(cfg, stacked, ops.init_state(tree_mean(stacked),
                                                        seed),
                           weights, active=active, adjacency=adj)
    up, down = int(res.rec.model_up), int(res.rec.model_down)
    assert up == down
    assert (np.asarray(res.xfers) >= 0).all()
    assert (np.asarray(res.link_msgs) >= 0).all()
    total = int(jnp.sum(res.xfers))
    assert total == (2 * (up + down) if kind == "gossip" else up + down)
    assert int(jnp.sum(res.link_msgs)) == int(res.rec.messages)
    # a learner that moved no models and sent no messages is dark
    dark = (np.asarray(res.xfers) == 0) & (np.asarray(res.link_msgs) == 0)
    for i in np.flatnonzero(dark & ~np.asarray(active)):
        a = jax.tree.map(lambda x: x[i], res.params)
        b = jax.tree.map(lambda x: x[i], stacked)
        assert all(np.array_equal(x, y) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_dynamic_link_msgs_split_violators_and_polls():
    """Per-link chatter attribution: one notice on each violator's link,
    one poll on each polled member's link."""
    m = 6
    stacked = jax.tree.map(lambda x: x * 0.01,
                           make_stacked(jax.random.PRNGKey(0), m))
    ref = tree_mean(stacked)
    stacked = jax.tree.map(lambda x: x.at[0].set(x[0] + 5.0), stacked)
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=1e-8)
    res = ops.apply_staged(cfg, stacked, ops.init_state(ref))
    msgs = np.asarray(res.link_msgs)
    # learner 0 violated; the balancing loop polled the rest
    assert msgs[0] == 1 and (msgs[1:] == 1).all()
    assert int(res.rec.messages) == m


# ---------------------------------------------------------------------------
# stage library units
# ---------------------------------------------------------------------------

def test_cohort_neighborhood_rows_are_stochastic():
    m = 6
    active = jnp.asarray([True, True, False, True, True, True])
    A, W = stages.cohort_neighborhood(m, active, topology.ring(m))
    W = np.asarray(W)
    assert np.allclose(W.sum(axis=1), 1.0, atol=1e-6)
    assert np.allclose(W.sum(axis=0), 1.0, atol=1e-6)   # doubly stochastic
    # the inactive learner is isolated: row e_i
    assert W[2, 2] == 1.0 and np.allclose(np.delete(W[2], 2), 0.0)


def test_cohort_fraction_masked_respects_target_and_reach():
    m, k = 8, 3
    active = jnp.asarray([True, False, True, True, False, True, True, True])
    sub = jax.random.PRNGKey(3)
    mask = stages.cohort_fraction_masked(sub, m, k, active)
    assert int(mask.sum()) == k
    assert bool(jnp.all(~mask | active))
    # fewer reachable than k: take everyone reachable
    few = jnp.zeros((m,), bool).at[2].set(True)
    mask2 = stages.cohort_fraction_masked(sub, m, k, few)
    assert int(mask2.sum()) == 1 and bool(mask2[2])


# ---------------------------------------------------------------------------
# hierarchy: config validation (satellite)
# ---------------------------------------------------------------------------

def test_hierarchy_config_validation():
    inter = ProtocolConfig(kind="periodic", b=5)
    with pytest.raises(ValueError):
        HierarchyConfig(num_clusters=1, inter=inter)
    with pytest.raises(ValueError):
        HierarchyConfig(num_clusters=4, inter=ProtocolConfig(kind="gossip"))
    with pytest.raises(ValueError):   # no nesting
        HierarchyConfig(num_clusters=4, inter=ProtocolConfig(
            kind="periodic", tiers=HierarchyConfig(num_clusters=2,
                                                   inter=inter)))
    with pytest.raises(KeyError):     # unknown uplink class at config time
        HierarchyConfig(num_clusters=4, inter=inter,
                        link_class="quantum-entanglement")
    with pytest.raises(ValueError):   # gossip cannot be the intra tier
        ProtocolConfig(kind="gossip",
                       tiers=HierarchyConfig(num_clusters=2, inter=inter))
    # a fleet that doesn't partition fails at engine construction
    cfg = get_arch("drift_mlp", smoke=True)
    with pytest.raises(ValueError):
        DecentralizedLearner(
            lambda p, b: cnn_loss(cfg, p, b),
            lambda k: init_cnn_params(cfg, k), 7,
            ProtocolConfig(kind="dynamic", b=2, delta=0.5,
                           tiers=HierarchyConfig(num_clusters=3,
                                                 inter=inter)))


def test_link_class_typos_fail_at_config_time():
    with pytest.raises(KeyError):
        NetworkConfig(link_classes=("warp-drive",))


# ---------------------------------------------------------------------------
# hierarchy: operator-level semantics
# ---------------------------------------------------------------------------

def _hier_state(stacked, tiers, seed=0):
    return hier.init_hier_state(tree_mean(stacked), tiers, seed)


def test_hierarchical_continuous_equals_flat_continuous():
    """intra periodic b=1 + inter periodic b=1 on an ideal network is the
    global mean everywhere — the flat continuous operator (to float
    tolerance; the hierarchy averages in two hops)."""
    m, g = 8, 4
    stacked = jax.tree.map(lambda x: x * 2.0,
                           make_stacked(jax.random.PRNGKey(1), m))
    cfg = ProtocolConfig(kind="periodic", b=1,
                         tiers=HierarchyConfig(
                             num_clusters=g,
                             inter=ProtocolConfig(kind="periodic", b=1)))
    res = hier.apply_hierarchical(cfg, cfg.tiers, stacked,
                                  _hier_state(stacked, cfg.tiers))
    mean = tree_mean(stacked)
    for i in range(m):
        fi = jax.tree.map(lambda x: x[i], res.params)
        assert tree_allclose(fi, mean, rtol=1e-5, atol=1e-6)
    # member links: 2 intra transfers + 1 down-push each; aggregator
    # uplinks: 2 each
    assert (np.asarray(res.member_xfers) == 3).all()
    assert (np.asarray(res.agg_xfers) == 2).all()
    assert int(res.rec.full_syncs) == 1


def test_hierarchy_inter_nosync_keeps_clusters_independent():
    """With a nosync inter tier, clusters never see each other: each
    cluster ends at its own mean, no aggregator uplink traffic."""
    m, g = 6, 2
    stacked = make_stacked(jax.random.PRNGKey(2), m)
    cfg = ProtocolConfig(kind="periodic", b=1,
                         tiers=HierarchyConfig(
                             num_clusters=g,
                             inter=ProtocolConfig(kind="nosync")))
    res = hier.apply_hierarchical(cfg, cfg.tiers, stacked,
                                  _hier_state(stacked, cfg.tiers))
    k = m // g
    for c in range(g):
        cmean = tree_mean(jax.tree.map(lambda x: x[c * k:(c + 1) * k],
                                       stacked))
        for i in range(c * k, (c + 1) * k):
            fi = jax.tree.map(lambda x: x[i], res.params)
            assert tree_allclose(fi, cmean, rtol=1e-5, atol=1e-6)
    assert (np.asarray(res.agg_xfers) == 0).all()
    assert (np.asarray(res.member_xfers) == 2).all()   # intra only


def test_weighted_hierarchy_reaches_weighted_global_mean():
    """Algorithm-2 mass flows up the hierarchy: with weighted intra tiers
    the inter tier weights aggregators by their cluster's total B^i, so a
    full two-hop sync lands on the WEIGHTED global mean (not the
    unweighted mean of cluster means)."""
    m, g = 6, 2
    stacked = make_stacked(jax.random.PRNGKey(9), m)
    w = jnp.asarray([1.0, 1.0, 1.0, 3.0, 3.0, 3.0])
    cfg = ProtocolConfig(kind="periodic", b=1, weighted=True,
                         tiers=HierarchyConfig(
                             num_clusters=g,
                             inter=ProtocolConfig(kind="periodic", b=1)))
    res = hier.apply_hierarchical(cfg, cfg.tiers, stacked,
                                  _hier_state(stacked, cfg.tiers),
                                  weights=w)
    want = jax.tree.map(
        lambda x: jnp.einsum("m...,m->...", x, w) / jnp.sum(w), stacked)
    for i in range(m):
        fi = jax.tree.map(lambda x: x[i], res.params)
        assert tree_allclose(fi, want, rtol=1e-5, atol=1e-6)


def test_gossip_ledger_counts_both_endpoints():
    """Gossip's ledger is link OCCUPANCY: every transfer sits on both
    endpoints' links, so the ledger sums to exactly 2x the paper's c(f)
    (coordinator protocols sum to exactly 1x — see
    test_flat_engine_ledger_matches_comm_bytes)."""
    proto = ProtocolConfig(kind="gossip", b=2)
    net = NetworkConfig(topology="ring")
    dl, _ = _run_engine(proto, net, rounds=20, m=6)
    assert dl.comm_bytes() > 0
    assert int(dl.per_link_bytes().sum()) == 2 * dl.comm_bytes()


def test_hierarchy_mean_invariance_full_participation():
    m, g = 8, 2
    stacked = jax.tree.map(lambda x: x * 3.0,
                           make_stacked(jax.random.PRNGKey(3), m))
    cfg = ProtocolConfig(kind="dynamic", b=1, delta=1e-6,
                         tiers=HierarchyConfig(
                             num_clusters=g,
                             inter=ProtocolConfig(kind="dynamic", b=1,
                                                  delta=1e-6)))
    res = hier.apply_hierarchical(cfg, cfg.tiers, stacked,
                                  _hier_state(stacked, cfg.tiers))
    assert tree_allclose(tree_mean(stacked), tree_mean(res.params),
                         rtol=1e-4, atol=1e-5)


def test_hierarchy_inactive_members_untouched():
    m, g = 8, 2
    stacked = jax.tree.map(lambda x: x * 2.0,
                           make_stacked(jax.random.PRNGKey(4), m))
    cfg = ProtocolConfig(kind="periodic", b=1,
                         tiers=HierarchyConfig(
                             num_clusters=g,
                             inter=ProtocolConfig(kind="periodic", b=1)))
    active = jnp.asarray([True, False, True, True, True, True, False, True])
    res = hier.apply_hierarchical(cfg, cfg.tiers, stacked,
                                  _hier_state(stacked, cfg.tiers),
                                  active=active)
    for i in np.flatnonzero(~np.asarray(active)):
        a = jax.tree.map(lambda x: x[i], res.params)
        b = jax.tree.map(lambda x: x[i], stacked)
        assert all(np.array_equal(x, y) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        assert int(res.member_xfers[i]) == 0
        assert int(res.member_msgs[i]) == 0


def test_hierarchy_dark_cluster_is_unreachable_upstream():
    """A cluster with no reachable member is dark at the inter tier too."""
    m, g = 6, 3
    stacked = make_stacked(jax.random.PRNGKey(5), m)
    cfg = ProtocolConfig(kind="periodic", b=1,
                         tiers=HierarchyConfig(
                             num_clusters=g,
                             inter=ProtocolConfig(kind="periodic", b=1)))
    active = jnp.asarray([True, True, False, False, True, True])
    res = hier.apply_hierarchical(cfg, cfg.tiers, stacked,
                                  _hier_state(stacked, cfg.tiers),
                                  active=active)
    assert int(res.agg_xfers[1]) == 0          # cluster 1 fully dark
    assert int(res.agg_xfers[0]) > 0 and int(res.agg_xfers[2]) > 0
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(res.params))


# ---------------------------------------------------------------------------
# hierarchy: end-to-end inside lax.scan (acceptance)
# ---------------------------------------------------------------------------

def _mlp_setup():
    cfg = get_arch("drift_mlp", smoke=True)
    return (lambda p, b: cnn_loss(cfg, p, b),
            lambda k: init_cnn_params(cfg, k))


def _run_engine(proto, network=None, rounds=40, m=6, seed=0):
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=10, seed=seed)
    dl = DecentralizedLearner(
        loss_fn, init_fn, m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05), network=network)
    metrics = dl.run_chunk(streams.next_chunk(rounds))
    return dl, metrics


def test_two_tier_dynamic_runs_scanned_and_ledger_balances():
    """ISSUE-3 acceptance: a two-tier dynamic run on a clustered fleet
    completes via run_chunk and the bytes ledger balances — per-link sums
    equal the global total."""
    g = 3
    proto = ProtocolConfig(
        kind="dynamic", b=2, delta=0.3,
        tiers=HierarchyConfig(num_clusters=g,
                              inter=ProtocolConfig(kind="dynamic", b=4,
                                                   delta=0.6)))
    net = NetworkConfig(act_prob=0.8, link_classes=("wifi", "lte"))
    dl, metrics = _run_engine(proto, net, rounds=40, m=6)
    n = 40
    assert metrics.link_counts.shape == (n, 6 + g, 2)
    assert np.isfinite(dl.cumulative_loss)
    assert dl.network_time >= 0.0
    # the ledger balances: per-link sums == the global byte total
    assert int(dl.per_link_bytes().sum()) == dl.comm_bytes()
    # member rows carry the intra tier, aggregator rows the inter tier
    assert dl.per_link_bytes().shape == (6 + g,)
    assert dl.num_links == 6 + g


def test_hierarchy_ideal_network_ledger_balances_too():
    proto = ProtocolConfig(
        kind="periodic", b=3,
        tiers=HierarchyConfig(num_clusters=2,
                              inter=ProtocolConfig(kind="periodic", b=6)))
    dl, metrics = _run_engine(proto, None, rounds=24, m=6)
    assert int(dl.per_link_bytes().sum()) == dl.comm_bytes()
    assert dl.comm_totals["syncs"] >= 1


def test_hierarchy_quantized_backhaul_prices_tiers_separately():
    """inter.bytes_per_param=1 (a quantized uplink) must be priced exactly:
    aggregator rows move 4x fewer bytes per transfer than member rows."""
    proto = ProtocolConfig(
        kind="periodic", b=2,
        tiers=HierarchyConfig(num_clusters=2,
                              inter=ProtocolConfig(kind="periodic", b=2,
                                                   bytes_per_param=1)))
    dl, metrics = _run_engine(proto, None, rounds=8, m=4)
    assert dl.inter_model_bytes * 4 == dl.model_bytes * 1
    agg_rows = dl.per_link_bytes()[4:]
    agg_xfer_total = int(np.asarray(
        jnp.sum(metrics.link_counts[:, 4:, 0], axis=0)).sum())
    assert agg_rows.sum() == agg_xfer_total * dl.inter_model_bytes
    # every aggregator byte is a whole quantized model
    assert agg_rows.sum() % dl.inter_model_bytes == 0
    assert agg_rows.sum() > 0


def test_ledger_survives_billion_byte_payloads():
    """Pricing happens host-side in int64: a payload size past int32
    (bytes_per_param blown up to stand in for a multi-billion-parameter
    model) must never wrap the ledger negative."""
    proto = ProtocolConfig(kind="periodic", b=1,
                           bytes_per_param=200_000_000)
    dl, _ = _run_engine(proto, None, rounds=2, m=4)
    assert dl.model_bytes > 2**31                  # would wrap in int32
    assert (dl.per_link_bytes() > 0).all()
    # periodic b=1: 2 transfers per link per round, 2 rounds, no messages
    assert (dl.per_link_bytes() == 4 * dl.model_bytes).all()
    assert int(dl.per_link_bytes().sum()) == dl.comm_bytes()


def test_flat_engine_ledger_matches_comm_bytes():
    """tiers=None: the ledger's sum is exactly the paper's c(f)."""
    proto = ProtocolConfig(kind="dynamic", b=2, delta=0.5)
    net = NetworkConfig(act_prob=0.6, topology="ring",
                        link_classes=("wifi", "lte"))
    dl, _ = _run_engine(proto, net, rounds=40, m=6)
    assert int(dl.per_link_bytes().sum()) == dl.comm_bytes()


# ---------------------------------------------------------------------------
# serial baseline scanned (satellite)
# ---------------------------------------------------------------------------

def test_serial_run_chunk_matches_step_loop_bitwise():
    loss_fn, init_fn = _mlp_setup()
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    key = jax.random.PRNGKey(11)
    batches = [src.sample(jax.random.fold_in(key, t), 16) for t in range(12)]

    tc = TrainConfig(optimizer="sgd", learning_rate=0.05)
    a = SerialLearner(loss_fn, init_fn, tc)
    per_round = [float(a.step(b)) for b in batches]
    b = SerialLearner(loss_fn, init_fn, tc)
    losses = b.run_chunk(jax.tree.map(lambda *xs: jnp.stack(xs), *batches))
    assert losses.shape == (12,)
    assert [float(x) for x in losses] == per_round
    assert all(np.array_equal(x, y) for x, y in
               zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))
    # the running total accumulates in float64 exactly like the step loop
    assert a.cumulative_loss == b.cumulative_loss
