"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True on
CPU executes the kernel body in Python)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# sqdist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 256, 1000, 65536 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sqdist_sweep(n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n))
    x = jax.random.normal(k1, (n,), dtype)
    r = jax.random.normal(k2, (n,), dtype)
    got = float(ops.sqdist(x, r, block=256))
    want = float(ref.sqdist_ref(x, r))
    assert np.isclose(got, want, rtol=1e-3), (got, want)


def test_sqdist_tree():
    k = jax.random.PRNGKey(0)
    a = {"w": jax.random.normal(k, (13, 7)), "b": jnp.ones((5,))}
    b = {"w": jnp.zeros((13, 7)), "b": jnp.zeros((5,))}
    got = float(ops.tree_sqdist(a, b, block=64))
    want = float(ref.sqdist_ref(a["w"], b["w"]) + ref.sqdist_ref(a["b"], b["b"]))
    assert np.isclose(got, want, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 4096), seed=st.integers(0, 1000))
def test_sqdist_property(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n,))
    r = jax.random.normal(k2, (n,))
    assert np.isclose(float(ops.sqdist(x, r, block=512)),
                      float(ref.sqdist_ref(x, r)), rtol=1e-4)


# ---------------------------------------------------------------------------
# batched sqdist over the flat fleet-plane: (m, P) x (P,) -> (m,)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(1, 1), (3, 7), (8, 256), (5, 1000),
                                 (17, 512 + 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sqdist_rows_sweep(m, n, dtype):
    """The fleet-plane grid variant vs the single-vector oracle, row by
    row — odd shapes exercise both the row and the column padding."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 1000 + n))
    X = jax.random.normal(k1, (m, n), dtype)
    r = jax.random.normal(k2, (n,), dtype)
    got = np.asarray(ops.sqdist_rows(X, r, block_m=4, block=256))
    assert got.shape == (m,)
    want = np.asarray(jax.vmap(lambda x: ref.sqdist_ref(x, r))(X))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_sqdist_rows_matches_scalar_kernel():
    """Row i of the batched kernel == the single-model kernel on row i."""
    k = jax.random.PRNGKey(0)
    X = jax.random.normal(k, (6, 777))
    r = jax.random.normal(jax.random.fold_in(k, 1), (777,))
    rows = np.asarray(ops.sqdist_rows(X, r, block_m=2, block=128))
    for i in range(6):
        one = float(ops.sqdist(X[i], r, block=128))
        assert np.isclose(rows[i], one, rtol=1e-5), i


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 12), n=st.integers(1, 2048),
       seed=st.integers(0, 1000))
def test_sqdist_rows_property(m, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (m, n))
    r = jax.random.normal(k2, (n,))
    got = np.asarray(ops.sqdist_rows(X, r, block_m=8, block=512))
    want = np.asarray(jax.vmap(lambda x: ref.sqdist_ref(x, r))(X))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (3, 5, 128), (130, 32), (1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(sum(shape)))
    x = jax.random.normal(k1, shape, dtype)
    s = jax.random.normal(k2, (shape[-1],))
    got = np.asarray(ops.rmsnorm(x, s, block_rows=32), np.float32)
    want = np.asarray(ref.rmsnorm_ref(x, s), np.float32)
    np.testing.assert_allclose(got, want, **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Sq,Sk", [(64, 64), (100, 100), (32, 96), (1, 128)])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(Sq, Sk, window, dtype):
    k = jax.random.PRNGKey(Sq * 1000 + Sk + window)
    kq, kk, kv = jax.random.split(k, 3)
    B, d = 2, 32
    q = jax.random.normal(kq, (B, Sq, d), dtype)
    kk_ = jax.random.normal(kk, (B, Sk, d), dtype)
    v = jax.random.normal(kv, (B, Sk, d), dtype)
    got = np.asarray(ops.flash_attention(
        q, kk_, v, window=window, block_q=32, block_k=32), np.float32)
    want = np.asarray(ref.flash_attention_ref(
        q, kk_, v, window=window), np.float32)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_flash_gqa_matches_ref():
    k = jax.random.PRNGKey(0)
    B, S, H, Hkv, d = 2, 64, 8, 2, 16
    q = jax.random.normal(k, (B, S, H, d))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, Hkv, d))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, Hkv, d))
    got = ops.flash_attention_gqa(q, kk, v, block_q=32, block_k=32)
    # reference: expand kv heads and run per-head dense attention
    G = H // Hkv
    kfull = jnp.repeat(kk, G, axis=2)
    vfull = jnp.repeat(v, G, axis=2)
    outs = []
    for h in range(H):
        outs.append(ref.flash_attention_ref(
            q[:, :, h], kfull[:, :, h], vfull[:, :, h]))
    want = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(Sq=st.integers(2, 80), seed=st.integers(0, 100),
       window=st.sampled_from([0, 8, 33]))
def test_flash_property(Sq, seed, window):
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (1, Sq, 16))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (1, Sq, 16))
    v = jax.random.normal(jax.random.fold_in(k, 2), (1, Sq, 16))
    got = ops.flash_attention(q, kk, v, window=window, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, kk, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(64, 16), (96, 32), (100, 32), (8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(S, chunk, dtype):
    k = jax.random.PRNGKey(S + chunk)
    BH, P, N = 3, 8, 4
    x = jax.random.normal(k, (BH, S, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (BH, S)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (BH,)))
    b = jax.random.normal(jax.random.fold_in(k, 3), (BH, S, N))
    c = jax.random.normal(jax.random.fold_in(k, 4), (BH, S, N))
    y, h = ops.ssd_scan(x, dt.astype(dtype), a, b.astype(dtype),
                        c.astype(dtype), chunk=chunk)
    yr, hr = ref.ssd_scan_ref(x, dt.astype(dtype), a, b.astype(dtype),
                              c.astype(dtype))
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), **tol)


def test_ssd_matches_model_mamba_forward():
    """The kernel agrees with the model's chunked-jnp SSD implementation."""
    from repro.models.mamba import _ssd_chunked
    k = jax.random.PRNGKey(5)
    Bb, S, H, P, G, N = 2, 64, 4, 8, 1, 16
    xh = jax.random.normal(k, (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (Bb, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)))
    B_ = jax.random.normal(jax.random.fold_in(k, 3), (Bb, S, G, N))
    C_ = jax.random.normal(jax.random.fold_in(k, 4), (Bb, S, G, N))
    y_model, h_model = _ssd_chunked(xh, dt, A, B_, C_, chunk=16)
    # kernel layout: (B*H, S, P) etc., groups pre-repeated
    xk = xh.transpose(0, 2, 1, 3).reshape(Bb * H, S, P)
    dtk = dt.transpose(0, 2, 1).reshape(Bb * H, S)
    ak = jnp.tile(A, (Bb,))
    rep = H // G
    Bk = jnp.repeat(B_, rep, axis=2).transpose(0, 2, 1, 3).reshape(Bb * H, S, N)
    Ck = jnp.repeat(C_, rep, axis=2).transpose(0, 2, 1, 3).reshape(Bb * H, S, N)
    y_k, h_k = ops.ssd_scan(xk, dtk, ak, Bk, Ck, chunk=16)
    y_k = y_k.reshape(Bb, H, S, P).transpose(0, 2, 1, 3)
    h_k = h_k.reshape(Bb, H, P, N)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_model),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_model),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# banded sliding-window attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,w", [(64, 16), (128, 32), (32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_sweep(S, w, dtype):
    from repro.kernels.swa_attention import swa_attention
    k0 = jax.random.PRNGKey(S + w)
    B, d = 2, 16
    q = jax.random.normal(k0, (B, S, d), dtype)
    kk = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, d), dtype)
    got = np.asarray(swa_attention(q, kk, v, window=w), np.float32)
    want = np.asarray(ref.flash_attention_ref(q, kk, v, window=w),
                      np.float32)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_swa_attention_matches_flash_kernel():
    """Both kernels implement the same SWA math; the banded one simply
    never stages out-of-band k blocks."""
    from repro.kernels.swa_attention import swa_attention
    k0 = jax.random.PRNGKey(7)
    B, S, d, w = 1, 96, 32, 32
    q = jax.random.normal(k0, (B, S, d))
    kk = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, d))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, d))
    banded = swa_attention(q, kk, v, window=w)
    flash = ops.flash_attention(q, kk, v, window=w, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(flash),
                               rtol=2e-4, atol=2e-5)
