"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as the REDUCED variant of the
same family (2 layers, d_model <= 512, <= 4 experts) and runs one forward +
one train step on CPU, asserting output shapes and the absence of NaNs.
Decode correctness: running the cached decode step token-by-token must
reproduce the full-sequence forward logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_arch
from repro.configs import ASSIGNED_ARCHS
from repro.models.model import (
    AUDIO_CODEBOOKS, init_lm_cache, init_lm_params, lm_apply,
    lm_decode_step, lm_loss,
)
from repro.train.step import make_train_step

B, S = 2, 32


def _batch(cfg, key):
    if cfg.modality == "audio":
        toks = jax.random.randint(key, (B, S, AUDIO_CODEBOOKS), 0,
                                  cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.modality == "vision":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 7), (B, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_arch(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = lm_apply(cfg, params, batch["tokens"],
                           prefix_embeds=batch.get("prefix_embeds"))
    s_total = S + (8 if cfg.modality == "vision" else 0)
    if cfg.modality == "audio":
        assert logits.shape == (B, S, AUDIO_CODEBOOKS, cfg.vocab_size)
    else:
        assert logits.shape == (B, s_total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: lm_loss(cfg, p, b)
    init_state, step = make_train_step(
        loss_fn, TrainConfig(optimizer="adam", learning_rate=1e-3))
    state = init_state(params)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l0 = None
    for i in range(3):
        state, metrics = jax.jit(step)(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        l0 = l0 or loss
    assert float(metrics["loss"]) < l0     # same batch -> loss must drop


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token cached decode == full forward (last-token logits)."""
    cfg = get_arch(arch, smoke=True)
    if cfg.modality == "vision":
        pytest.skip("decode compares text-only paths")
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    T = 12
    if cfg.modality == "audio":
        toks = jax.random.randint(key, (B, T, AUDIO_CODEBOOKS), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full_logits, _ = lm_apply(cfg, params, toks)

    cache = init_lm_cache(cfg, B, max_seq=T)
    step = jax.jit(lambda p, c, t, pos: lm_decode_step(cfg, p, t, c, pos))
    for t in range(T):
        tok = toks[:, t]
        logits, cache = step(params, cache, tok, jnp.int32(t))
    last_full = full_logits[:, -1]
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(last_full, np.float32),
        rtol=2e-2, atol=2e-3)


def test_param_count_mnist_cnn():
    """The paper's Table 1 reports 1,199,882 weights for the MNIST CNN."""
    from repro.models.cnn import init_cnn_params
    cfg = get_arch("mnist_cnn")
    p = init_cnn_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(p))
    assert n == 1_199_882


def test_param_count_deepdrive_cnn():
    """Paper Table 5: 348,219 weights for the PilotNet driving CNN."""
    from repro.models.cnn import init_cnn_params
    cfg = get_arch("deepdrive_cnn")
    p = init_cnn_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(p))
    assert n == 348_219


@pytest.mark.parametrize("arch,family", [
    ("mixtral-8x22b", "moe"), ("deepseek-v2-236b", "moe"),
    ("mamba2-2.7b", "ssm"), ("hymba-1.5b", "hybrid"),
    ("internvl2-76b", "vlm"), ("musicgen-large", "audio"),
])
def test_family_tags(arch, family):
    assert get_arch(arch).family == family


@pytest.mark.parametrize("arch,expect_b", [
    ("llama3-405b", 405e9), ("llama3-8b", 8e9), ("qwen1.5-110b", 110e9),
    ("mixtral-8x22b", 141e9), ("minitron-4b", 4e9), ("mamba2-2.7b", 2.7e9),
    ("deepseek-v2-236b", 236e9), ("hymba-1.5b", 1.5e9),
    ("musicgen-large", 3.3e9),
])
def test_param_counts_near_nameplate(arch, expect_b):
    n = get_arch(arch).param_count()
    assert 0.6 * expect_b < n < 1.45 * expect_b, (arch, n)


def test_llama3_swa_variant_long_context_ready():
    """The sliding-window VARIANT of llama3-8b (dense-arch long_500k
    carve-out): bounded ring-buffer cache + forward/decode sanity."""
    import dataclasses
    from repro.models.model import init_lm_cache
    cfg = get_arch("llama3-8b-swa", smoke=True)
    assert cfg.attn_type == "sliding" and cfg.supports_long_context
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                              cfg.vocab_size)
    logits, _ = lm_apply(cfg, params, toks)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # cache is bounded by the window regardless of max_seq
    cache = init_lm_cache(cfg, 1, max_seq=10_000)
    assert jax.tree.leaves(cache)[0].shape[2] <= cfg.sliding_window + 1
    full = get_arch("llama3-8b-swa")
    assert full.sliding_window == 8192
