"""The fleet telemetry plane (ISSUE 7).

Four claims:

* **off is free** — a learner built WITHOUT telemetry reproduces the
  PR-2 goldens bitwise: the plane's existence changes nothing.
* **on is exact** — an instrumented run's integer counters equal the
  uninstrumented run's, every streamed record is schema-valid, and the
  stream's cumulative totals equal the engine's host counters exactly
  (floats bitwise — both sides accumulate the same float64 running sum).
* **the schema is a contract** — round records survive a JSON
  round-trip; a version-mismatched or mistyped record is REJECTED.
* **counters survive checkpoints** — ``counters_state`` →
  ``save_protocol_state`` → ``load_counters`` → ``restore_counters``
  continues the stream as ONE continuous record (rounds contiguous
  across the resume boundary, cumulatives monotone).
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint.io import (
    load_counters, load_protocol_state, save_protocol_state,
)
from repro.config import (
    NetworkConfig, ProtocolConfig, TelemetryConfig, TrainConfig, get_arch,
)
from repro.core.protocol import DecentralizedLearner
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.telemetry import TelemetrySink, get_logger, jsonl_handler
from repro.telemetry.observatory import frontier, load_run, summarize
from repro.telemetry.record import (
    SCHEMA_VERSION, RoundRecord, validate_record,
)

from golden_pr2_capture import CASES, M, ROUNDS, params_sha256
from hypothesis_compat import given, settings, st

with open(os.path.join(os.path.dirname(__file__),
                       "golden_pr2_engine.json")) as f:
    GOLDEN = json.load(f)
GOLDEN_JAX = GOLDEN.get("_meta", {}).get("jax_version")


def _learner(proto, network, telemetry=None, m=M, seed=0):
    cfg = get_arch("drift_mlp", smoke=True)
    streams = LearnerStreams(GraphicalModelStream(seed=0, drift_prob=0.0),
                             m, batch=10, seed=seed)
    dl = DecentralizedLearner(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k), m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        sample_weights=streams.weights, network=network,
        telemetry=telemetry)
    return dl, streams


# ---------------------------------------------------------------------------
# off is free: telemetry=None reproduces the PR-2 goldens bitwise
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    jax.__version__ != GOLDEN_JAX,
    reason=f"bitwise goldens captured under jax {GOLDEN_JAX}")
@pytest.mark.parametrize("name", ["dynamic_net", "periodic_ideal"])
def test_telemetry_disabled_is_bitwise_noop(name):
    proto, network = CASES[name]
    dl, streams = _learner(proto, network, telemetry=None)
    dl.run_chunk(streams.next_chunk(ROUNDS))
    want = GOLDEN[name]
    assert dl.comm_totals == want["comm_totals"]
    assert repr(dl.cumulative_loss) == want["cumulative_loss"]
    assert params_sha256(dl.params) == want["params_sha256"]
    assert dl.link_xfer_totals.tolist() == want["link_xfer_totals"]
    assert repr(dl.network_time) == want["network_time"]


# ---------------------------------------------------------------------------
# on is exact: counters match, records validate, stream == engine
# ---------------------------------------------------------------------------

def test_telemetry_enabled_stream_is_exact(tmp_path):
    proto, network = CASES["dynamic_net"]
    path = str(tmp_path / "run.jsonl")

    plain, streams = _learner(proto, network, telemetry=None)
    plain.run_chunk(streams.next_chunk(ROUNDS))

    telem = TelemetryConfig(path=path, per_link=True, profile=True)
    dl, streams = _learner(proto, network, telemetry=telem)
    dl.run_chunk(streams.next_chunk(ROUNDS))
    dl.recorder.close()

    # instrumentation must not perturb the protocol: params bitwise and
    # every integer counter identical. The float loss counter accumulates
    # differently BY DESIGN — the instrumented engine sums the per-round
    # float64 stream (so the last record equals the counter bitwise)
    # where the plain engine reads the device's float32 chunk total —
    # so it only agrees to float32 resolution.
    assert params_sha256(dl.params) == params_sha256(plain.params)
    assert dl.comm_totals == plain.comm_totals
    assert dl.link_xfer_totals.tolist() == plain.link_xfer_totals.tolist()
    np.testing.assert_allclose(dl.cumulative_loss, plain.cumulative_loss,
                               rtol=1e-6)

    # every line schema-valid; one meta + ROUNDS rounds + >=1 chunk
    with open(path) as f:
        recs = [validate_record(json.loads(line), i + 1)
                for i, line in enumerate(f)]
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "meta"
    rounds = [r for r in recs if r["kind"] == "round"]
    chunks = [r for r in recs if r["kind"] == "chunk"]
    assert len(rounds) == ROUNDS
    assert [r["round"] for r in rounds] == list(range(1, ROUNDS + 1))
    assert chunks and chunks[-1]["rounds_end"] == ROUNDS

    # the stream's cumulative totals ARE the engine's counters (exact:
    # ints by int64 arithmetic, floats by the shared float64 running sum)
    last = rounds[-1]
    assert last["cum_bytes"] == dl.comm_bytes()
    assert last["cum_syncs"] == dl.comm_totals["syncs"]
    assert last["cum_loss"] == dl.cumulative_loss
    assert last["cum_net_time"] == dl.network_time
    assert sum(r["messages"] for r in rounds) == dl.comm_totals["messages"]
    assert sum(r["cohort"] for r in rounds) == dl.comm_totals["model_up"]
    assert chunks[-1]["link_bytes_cum"] == [
        int(x) for x in dl.link_bytes_totals]
    # per-link rows sum to the ledger
    per_link = np.array([r["link_bytes"] for r in rounds], np.int64)
    assert per_link.sum(axis=0).tolist() == chunks[-1]["link_bytes_cum"]

    # the observatory reproduces the frontier from the file ALONE
    run = load_run(path)
    fr = frontier(run)
    assert fr[-1] == [ROUNDS, dl.comm_bytes(), dl.cumulative_loss]
    card = summarize(run)
    assert card["cum_bytes"] == dl.comm_bytes()
    assert card["cum_syncs"] == dl.comm_totals["syncs"]
    assert card["rounds"] == ROUNDS
    assert card["link_class_bytes"] is not None
    assert card["profile"] is not None        # profile=True


def test_telemetry_no_extra_device_fetches(tmp_path, monkeypatch):
    """The instrumented chunk path performs exactly ONE ``device_get`` —
    the same single fetch the uninstrumented fold already pays."""
    proto, network = CASES["dynamic_ideal"]
    telem = TelemetryConfig(path=str(tmp_path / "run.jsonl"))
    dl, streams = _learner(proto, network, telemetry=telem)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    dl.run_chunk(streams.next_chunk(8))
    dl.recorder.close()
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# the schema is a contract
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(rnd=st.integers(1, 10**9), msgs=st.integers(0, 10**6),
       cohort=st.integers(0, 512), sync=st.integers(0, 1),
       loss=st.floats(allow_nan=False, allow_infinity=False, width=64),
       nt=st.floats(min_value=0, max_value=1e12),
       link=st.one_of(st.none(), st.lists(
           st.integers(0, 2**50), min_size=1, max_size=8)))
def test_round_record_json_roundtrip(rnd, msgs, cohort, sync, loss, nt,
                                     link):
    rec = RoundRecord(
        round=rnd, loss=loss, cum_loss=loss, divergence=0.0,
        messages=msgs, cohort=cohort, sync=sync, full_sync=0,
        cum_syncs=sync, num_active=cohort, net_time=nt, cum_net_time=nt,
        round_bytes=cohort * 64, cum_bytes=cohort * 64,
        link_bytes=tuple(link) if link else None)
    back = RoundRecord.from_dict(json.loads(json.dumps(rec.to_dict())))
    assert back == rec


def test_round_record_async_fields_roundtrip_and_card(tmp_path):
    """The optional in-flight/age fields survive the JSON round-trip,
    and a state-carrying run's card (``summarize``) histograms the
    chunk-end trigger-state snapshot."""
    rec = RoundRecord(
        round=1, loss=1.0, cum_loss=1.0, divergence=0.0, messages=0,
        cohort=0, sync=0, full_sync=0, cum_syncs=0, num_active=4,
        net_time=0.0, cum_net_time=0.0, round_bytes=0, cum_bytes=0,
        inflight=3, max_age=7)
    back = RoundRecord.from_dict(json.loads(json.dumps(rec.to_dict())))
    assert back == rec
    with pytest.raises(ValueError, match="must be an integer"):
        RoundRecord.from_dict({**rec.to_dict(), "inflight": 1.5})

    from repro.core.sync import PROTOCOLS
    path = str(tmp_path / "stale.jsonl")
    dl, streams = _learner(PROTOCOLS["stale"].with_params(tau=3), None,
                           telemetry=TelemetryConfig(path=path))
    dl.run_chunk(streams.next_chunk(12))
    dl.recorder.close()
    card = summarize(load_run(path))
    ages = card["state_ages"]["staleness"]
    assert ages["min"] >= 0 and ages["max"] <= 3
    assert sum(ages["hist"].values()) == M       # one bucket per learner
    assert all(r["max_age"] is not None for r in load_run(path).rounds)


def test_round_record_rejects_bad_streams():
    base = RoundRecord(
        round=1, loss=1.0, cum_loss=1.0, divergence=0.0, messages=0,
        cohort=0, sync=0, full_sync=0, cum_syncs=0, num_active=4,
        net_time=0.0, cum_net_time=0.0, round_bytes=0,
        cum_bytes=0).to_dict()
    with pytest.raises(ValueError, match="version mismatch"):
        RoundRecord.from_dict({**base, "v": SCHEMA_VERSION + 1})
    with pytest.raises(ValueError, match="not a round record"):
        RoundRecord.from_dict({**base, "kind": "meta"})
    with pytest.raises(ValueError, match="missing fields"):
        RoundRecord.from_dict(
            {k: v for k, v in base.items() if k != "cum_bytes"})
    with pytest.raises(ValueError, match="must be an integer"):
        RoundRecord.from_dict({**base, "cum_bytes": 1.5})
    with pytest.raises(ValueError, match="unknown fields"):
        RoundRecord.from_dict({**base, "surprise": 1})
    with pytest.raises(ValueError, match="unknown record kind"):
        validate_record({"kind": "mystery", "v": SCHEMA_VERSION})


# ---------------------------------------------------------------------------
# counters survive checkpoints: one continuous stream across a resume
# ---------------------------------------------------------------------------

def test_counter_continuity_across_checkpoint_resume(tmp_path):
    proto, network = CASES["dynamic_net"]
    path = str(tmp_path / "run.jsonl")
    ckpt = str(tmp_path / "ckpt")

    telem = TelemetryConfig(path=path, per_link=True)
    dl, streams = _learner(proto, network, telemetry=telem)
    dl.run_chunk(streams.next_chunk(ROUNDS))
    dl.recorder.close()
    save_protocol_state(ckpt, dl.params, dl.opt_state, dl.sync_state,
                        protocol=proto, counters=dl.counters_state())
    mid = {"bytes": dl.comm_bytes(), "loss": dl.cumulative_loss,
           "syncs": dl.comm_totals["syncs"]}

    # resume: fresh process, restore state + counters, append the stream
    dl2, streams2 = _learner(
        proto, network,
        telemetry=TelemetryConfig(path=path, per_link=True, append=True))
    # params + sync state round-trip (the opt npz loses the OptState
    # container — its round trip is test_spec's subject, not ours)
    params, _, sync = load_protocol_state(ckpt)
    dl2.params, dl2.sync_state = params, sync
    saved = load_counters(ckpt)
    assert saved is not None and saved["rounds"] == ROUNDS
    dl2.restore_counters(saved)
    assert dl2.comm_totals == dl.comm_totals
    assert dl2.cumulative_loss == dl.cumulative_loss
    streams2.next_chunk(ROUNDS)                 # replay the consumed data
    dl2.run_chunk(streams2.next_chunk(ROUNDS))
    dl2.recorder.close()

    run = load_run(path)
    assert run.resumed                           # a second meta was written
    assert run.metas[-1]["resumed_rounds"] == ROUNDS
    got = [r["round"] for r in run.rounds]
    assert got == list(range(1, 2 * ROUNDS + 1))   # contiguous across resume
    last = run.rounds[-1]
    assert last["cum_bytes"] == dl2.comm_bytes() > mid["bytes"]
    assert last["cum_syncs"] == dl2.comm_totals["syncs"] >= mid["syncs"]
    assert last["cum_loss"] == dl2.cumulative_loss > mid["loss"]


def test_restore_counters_rejects_wrong_shape():
    proto, network = CASES["dynamic_ideal"]
    dl, _ = _learner(proto, network)
    good = dl.counters_state()
    with pytest.raises(ValueError):
        dl.restore_counters(
            {**good, "cumulative_loss_per_learner": [0.0] * (M + 1)})
    with pytest.raises(ValueError):
        dl.restore_counters(
            {**good, "comm_totals": {**good["comm_totals"], "bogus": 1}})


# ---------------------------------------------------------------------------
# the event logger and the lint rule that keeps library code on it
# ---------------------------------------------------------------------------

def test_event_logger_routes_to_jsonl(tmp_path):
    log = get_logger()
    assert not log.enabled                       # silent by default
    log.event("ignored", x=1)                    # no handlers: no-op
    with TelemetrySink(str(tmp_path / "ev.jsonl")) as sink:
        handler = log.add_handler(jsonl_handler(sink))
        try:
            log.event("train_step", step=3, loss=0.5)
        finally:
            log.remove_handler(handler)
    with open(tmp_path / "ev.jsonl") as f:
        rec = validate_record(json.loads(f.read()))
    assert rec["kind"] == "event" and rec["event"] == "train_step"
    assert rec["step"] == 3
    assert not log.enabled


def test_lint_print_outside_cli():
    from repro.analysis.lint import lint_source
    lib = "def f():\n    print('x')\n"
    assert [f.rule for f in lint_source(lib, "repro/core/foo.py")] == [
        "print-outside-cli"]
    # __main__.py IS the CLI
    assert lint_source(lib, "repro/telemetry/__main__.py") == []
    # launch modules: prints allowed only inside top-level main()
    entry = "def main():\n    print('ok')\n"
    assert lint_source(entry, "repro/launch/train.py") == []
    assert [f.rule for f in lint_source(lib, "repro/launch/train.py")] == [
        "print-outside-cli"]


# ---------------------------------------------------------------------------
# observatory CLI smoke (direct main() calls — no subprocess)
# ---------------------------------------------------------------------------

def test_observatory_cli_smoke(tmp_path, capsys):
    from repro.telemetry.__main__ import main
    path = str(tmp_path / "cli.jsonl")
    assert main(["record", "--out", path, "--rounds", "20", "--m", "4",
                 "--chunk", "16", "--per-link", "--profile"]) == 0
    capsys.readouterr()                          # drain the record banner
    assert main(["summarize", path]) == 0
    card = json.loads(capsys.readouterr().out)
    assert card["rounds"] == 20 and card["m"] == 4
    assert main(["frontier", path]) == 0
    fr = json.loads(capsys.readouterr().out)
    assert len(fr) >= 1 and fr[-1][0] == 20
    assert main(["tail", path, "-n", "3"]) == 0
    lines = capsys.readouterr().out.strip().splitlines()
    assert len(lines) == 3
    assert main(["prom", path]) == 0
    prom = capsys.readouterr().out
    assert "repro_comm_bytes_total" in prom
    assert "repro_rounds_total 20" in prom
    assert main(["costs", path]) == 0
    costs = json.loads(capsys.readouterr().out)
    assert costs["rounds"] == 20 and costs["est_total_flops"] > 0
