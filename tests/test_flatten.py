"""The flat fleet-plane (ISSUE 5): adapter round-trips, layout="flat"
equivalence against the default tree layout for every preset, and the
engine/hierarchy integration.

The equivalence contract under test is the acceptance criterion:
``layout="tree"`` stays bitwise (the golden regression in
test_sync_kernel.py covers that); ``layout="flat"`` must reproduce the
tree layout's communication EXACTLY (comm counters, per-link transfers,
cohort decisions — guaranteed whenever no distance sits within
float-reassociation error of the Delta threshold, which holds for every
deterministic fixture here) and its parameters to float-reassociation
tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.config import (
    HierarchyConfig, NetworkConfig, ProtocolConfig, TrainConfig, get_arch,
)
from repro.core import flatten
from repro.core import operators as ops
from repro.core.divergence import (
    per_learner_sq_distance, per_learner_sq_distance_flat, tree_mean,
)
from repro.core.protocol import DecentralizedLearner
from repro.core.sync import PROTOCOLS, stages
from repro.data.pipeline import LearnerStreams
from repro.data.synthetic import GraphicalModelStream
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.network import topology

from conftest import make_stacked


# ---------------------------------------------------------------------------
# adapter: ravel/unravel round trips
# ---------------------------------------------------------------------------

_FLOAT_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 6),
       nleaves=st.integers(1, 5), data=st.data())
def test_ravel_unravel_round_trip(seed, m, nleaves, data):
    """unravel(ravel(params)) == params, bitwise, over random model
    pytrees (mixed float dtypes, mixed ranks incl. scalars)."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for i in range(nleaves):
        rank = data.draw(st.integers(0, 3))
        shape = tuple(data.draw(st.integers(1, 5)) for _ in range(rank))
        dtype = data.draw(st.sampled_from(_FLOAT_DTYPES))
        key, sub = jax.random.split(key)
        tree[f"w{i}"] = jax.random.normal(sub, (m,) + shape, dtype)
    adapter = flatten.fleet_adapter(tree)
    X = adapter.ravel(tree)
    assert X.shape == (m, adapter.P)
    back = adapter.unravel(X)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
    # the single-model view round-trips too
    model = jax.tree.map(lambda x: x[0], tree)
    r = adapter.ravel_model(model)
    back1 = adapter.unravel_model(r)
    for a, b in zip(jax.tree.leaves(model), jax.tree.leaves(back1)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_adapter_is_cached_and_rejects_non_float():
    a = make_stacked(jax.random.PRNGKey(0), 4)
    b = make_stacked(jax.random.PRNGKey(1), 4)   # same structure
    assert flatten.fleet_adapter(a) is flatten.fleet_adapter(b)
    with pytest.raises(TypeError):
        flatten.fleet_adapter({"n": jnp.zeros((4, 3), jnp.int32)})
    with pytest.raises(ValueError):
        flatten.fleet_adapter({})


def test_flat_distances_match_tree_distances():
    stacked = make_stacked(jax.random.PRNGKey(2), 5)
    ref = tree_mean(stacked)
    adapter = flatten.fleet_adapter(stacked)
    want = per_learner_sq_distance(stacked, ref)
    got = per_learner_sq_distance_flat(adapter.ravel(stacked),
                                       adapter.ravel_model(ref))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # forcing the Pallas kernel (interpret mode on CPU) agrees too
    got_k = per_learner_sq_distance_flat(adapter.ravel(stacked),
                                         adapter.ravel_model(ref),
                                         use_kernel=True)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# operator-level equivalence: flat == tree (counters bitwise, params close)
# ---------------------------------------------------------------------------

ALL_KINDS = ["nosync", "periodic", "fedavg", "dynamic", "gossip"]


def _counters_equal(a, b):
    return all(int(getattr(a, f)) == int(getattr(b, f))
               for f in a._fields)


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(ALL_KINDS), m=st.integers(2, 8),
       seed=st.integers(0, 10_000), mask_bits=st.integers(0, 255),
       weighted=st.booleans())
def test_flat_operator_matches_tree_operator(kind, m, seed, mask_bits,
                                             weighted):
    """One staged round per layout from identical state: comm record and
    per-link counts bitwise, parameters to reassociation tolerance, and
    untouched learners bitwise."""
    stacked = make_stacked(jax.random.PRNGKey(seed), m)
    active = jnp.asarray([(mask_bits >> i) & 1 == 1 for i in range(m)])
    kw = dict(b=1)
    if kind == "dynamic":
        kw["delta"] = 0.05
    weights = jnp.arange(1.0, m + 1.0) if weighted else None
    adj = topology.ring(m) if kind == "gossip" else None
    res = {}
    for layout in ("tree", "flat"):
        cfg = ProtocolConfig(kind=kind, weighted=weighted, layout=layout,
                             **kw)
        res[layout] = ops.apply_staged(
            cfg, stacked, ops.init_state(tree_mean(stacked), seed),
            weights, active=active, adjacency=adj)
    t, f = res["tree"], res["flat"]
    assert _counters_equal(t.rec, f.rec)
    assert np.array_equal(np.asarray(t.xfers), np.asarray(f.xfers))
    assert np.array_equal(np.asarray(t.link_msgs), np.asarray(f.link_msgs))
    for a, b in zip(jax.tree.leaves(t.params), jax.tree.leaves(f.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    # learners the tree layout left untouched are BITWISE untouched on
    # the flat layout too (ravel/unravel is reshape-only, no arithmetic)
    untouched = np.flatnonzero(
        (np.asarray(t.xfers) == 0) & (np.asarray(t.link_msgs) == 0)
        & ~np.asarray(active))
    for i in untouched:
        for x, y in zip(jax.tree.leaves(stacked),
                        jax.tree.leaves(f.params)):
            assert np.array_equal(np.asarray(x[i]), np.asarray(y[i]))


def test_flat_spec_path_without_config_sugar():
    """layout is a spec param: preset.with_params(layout='flat') runs the
    plane without any ProtocolConfig involved."""
    m = 5
    stacked = make_stacked(jax.random.PRNGKey(7), m)
    spec = PROTOCOLS["dynamic"].with_params(b=1, delta=0.05, layout="flat")
    res = ops.apply_staged(spec, stacked,
                           ops.init_state(tree_mean(stacked)))
    ref = ops.apply_staged(
        PROTOCOLS["dynamic"].with_params(b=1, delta=0.05), stacked,
        ops.init_state(tree_mean(stacked)))
    assert _counters_equal(res.rec, ref.rec)
    # round-trips through JSON like any other param
    from repro.core.sync.spec import ProtocolSpec
    assert ProtocolSpec.from_json(spec.to_json()) == spec


def test_unknown_layout_rejected_at_construction():
    with pytest.raises(ValueError):
        ProtocolConfig(kind="periodic", layout="diagonal")
    with pytest.raises(ValueError):
        PROTOCOLS["periodic"].with_params(layout="diagonal")


def test_balanced_cohort_reuses_threaded_dists():
    """The dists computed by the divergence condition feed the balancing
    priority: passing them explicitly must not change the cohort."""
    m = 6
    stacked = jax.tree.map(lambda x: x * 3.0,
                           make_stacked(jax.random.PRNGKey(4), m))
    ref = tree_mean(stacked)
    dists = per_learner_sq_distance(stacked, ref)
    violated = dists > 0.5
    rng = jax.random.PRNGKey(0)
    a = stages.cohort_balanced(0.5, "max_distance", stacked, ref,
                               violated, rng)
    b = stages.cohort_balanced(0.5, "max_distance", stacked, ref,
                               violated, rng, dists=dists)
    assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))


# ---------------------------------------------------------------------------
# engine-level equivalence: every preset + stale, scanned, under masks
# ---------------------------------------------------------------------------

PRESETS = {
    "nosync": dict(kind="nosync"),
    "periodic": dict(kind="periodic", b=3),
    "continuous": dict(kind="continuous", b=1),
    "fedavg": dict(kind="fedavg", b=2, fedavg_c=0.5),
    "dynamic": dict(kind="dynamic", b=2, delta=0.5),
    "gossip": dict(kind="gossip", b=2),
    "stale": dict(kind="stale"),
}


def _run_engine(proto, rounds=30, m=6, seed=0):
    cfg = get_arch("drift_mlp", smoke=True)
    src = GraphicalModelStream(seed=0, drift_prob=0.0)
    streams = LearnerStreams(src, m, batch=10, seed=seed)
    dl = DecentralizedLearner(
        lambda p, b: cnn_loss(cfg, p, b),
        lambda k: init_cnn_params(cfg, k), m, proto,
        TrainConfig(optimizer="sgd", learning_rate=0.05),
        network=NetworkConfig(act_prob=0.6, topology="ring",
                              link_classes=("wifi", "lte")))
    dl.run_chunk(streams.next_chunk(rounds))
    return dl


@pytest.mark.parametrize("name", sorted(PRESETS))
def test_flat_engine_matches_tree_engine(name):
    """ISSUE-5 acceptance: 30 scanned rounds under availability masks,
    flat vs tree — comm counters and the per-link ledger bitwise,
    parameters to reassociation tolerance."""
    tree_dl = _run_engine(ProtocolConfig(layout="tree", **PRESETS[name]))
    flat_dl = _run_engine(ProtocolConfig(layout="flat", **PRESETS[name]))
    assert tree_dl.comm_totals == flat_dl.comm_totals, name
    assert np.array_equal(tree_dl.link_xfer_totals,
                          flat_dl.link_xfer_totals), name
    assert np.array_equal(tree_dl.link_bytes_totals,
                          flat_dl.link_bytes_totals), name
    assert tree_dl.network_time == flat_dl.network_time, name
    for a, b in zip(jax.tree.leaves(tree_dl.params),
                    jax.tree.leaves(flat_dl.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6, err_msg=name)


def test_flat_hierarchy_matches_tree_hierarchy():
    """The vmapped per-cluster intra path picks the plane up with no
    hierarchy edits: same counters, close params."""
    tiers = HierarchyConfig(num_clusters=3,
                            inter=ProtocolConfig(kind="periodic", b=6))
    out = {}
    for layout in ("tree", "flat"):
        out[layout] = _run_engine(
            ProtocolConfig(kind="dynamic", b=2, delta=0.5, layout=layout,
                           tiers=tiers))
    assert out["tree"].comm_totals == out["flat"].comm_totals
    assert np.array_equal(out["tree"].link_bytes_totals,
                          out["flat"].link_bytes_totals)
    for a, b in zip(jax.tree.leaves(out["tree"].params),
                    jax.tree.leaves(out["flat"].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
