"""The event-driven async network timeline (ISSUE 9).

Claims pinned here:

* **delay math** — flight times come from the ``repro.network.cost``
  link classes: an exchange flies ``k = ceil(round_trip/budget) - 1``
  whole rounds through the bounded arrival ring, and a ring too shallow
  for the slowest class is rejected at spec construction.
* **zero-delay reduction** — a round budget covering the slowest link's
  round trip makes EVERY async composition bitwise-equal to its
  synchronous original: comm counters, per-link ledger, simulated
  net-time and a params SHA-256, across all presets, both layouts, and
  random availability masks (the hypothesis property).
* **nonzero delays** — messages fly whole rounds (the engine's
  ``num_inflight``/``max_age`` metrics see them) and the int64 ledger
  stays exact.
* **aircomp** — the analog channel prices ONE shared-medium exchange in
  c(f) while the ledger bills each member's airtime; the noise draw is
  pure in ``(air_seed, t)`` and vanishes as snr_db grows.
* **determinism** — the whole timeline is pure in ``(seed, t)``: two
  identical telemetered runs stream byte-identical JSONL.
"""
import hashlib
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AsyncConfig, NetworkConfig, TelemetryConfig
from repro.core.protocol import DecentralizedLearner
from repro.core.sync import PROTOCOLS
from repro.core.sync.async_sync import asyncify
from repro.network import events
from repro.telemetry.observatory import load_run, summarize

from hypothesis_compat import given, settings, st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# tiny deterministic fleet: linear model, synthetic regression batches
# ---------------------------------------------------------------------------

def _loss(p, batch):
    x, y = batch
    return jnp.mean((x @ p["w"] - y) ** 2)


def _init(key):
    return {"w": jax.random.normal(key, (4,)) * 0.1}


def _batches(m, n, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (n, m, 8, 4))
    ys = jnp.sum(xs, axis=-1) * 0.5
    return (xs, ys)


def _digest(params) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _fingerprint(spec, *, network=None, async_net=None, m=4, rounds=8,
                 seed=0, telemetry=None):
    """Run a small fleet and return everything the bitwise claims cover."""
    dl = DecentralizedLearner(_loss, _init, m, spec, seed=seed,
                              network=network, async_net=async_net,
                              telemetry=telemetry)
    metrics = dl.run_chunk(_batches(m, rounds, seed))
    return dl, metrics, (dict(dl.comm_totals),
                         np.asarray(dl.link_bytes_totals).tolist(),
                         float(dl.network_time), _digest(dl.params))


# every synchronous preset, with the trigger thresholds the zero-delay
# property exercises (the raw presets would sync every round)
BASE_SPECS = {
    "periodic": PROTOCOLS["periodic"].with_params(b=2),
    "continuous": PROTOCOLS["continuous"],
    "fedavg": PROTOCOLS["fedavg"].with_params(b=2),
    "gossip": PROTOCOLS["gossip"].with_params(b=2),
    "dynamic": PROTOCOLS["dynamic"].with_params(b=1, delta=0.05),
    "nosync": PROTOCOLS["nosync"],
    "stale": PROTOCOLS["stale"].with_params(tau=3),
}

# budget >> the slowest round trip at this payload: every flight is k=0
ZERO_DELAY = AsyncConfig(round_budget=60.0)


# ---------------------------------------------------------------------------
# delay math: repro.network.events
# ---------------------------------------------------------------------------

def test_flight_rounds_from_link_classes():
    # at a 100 kB payload and a 1 s budget: lte's round trip is 0.14 s
    # (fits the budget -> synchronous), edge's is 2*(0.2 + 0.8) = 2 s
    # -> one whole round in flight
    assert events.class_flight_rounds("lte,edge", 100_000, 1.0) == {
        "lte": 0, "edge": 1}
    assert events.max_flight_rounds("lte,edge", 100_000, 1.0) == 1
    # budget covering the slowest round trip: everything synchronous
    assert events.class_flight_rounds("lte,edge", 100_000, 60.0) == {
        "lte": 0, "edge": 0}
    # per-learner assignment is round-robin like cost.link_profile
    k = events.flight_rounds("lte,edge", 5, 100_000, 1.0)
    assert np.asarray(k).tolist() == [0, 1, 0, 1, 0]
    assert events.class_flight_rounds("", 100_000, 1.0) == {}
    with pytest.raises(ValueError, match="warp-drive"):
        events.class_flight_rounds("warp-drive", 0, 1.0)


def test_round_trip_time_matches_cost_model():
    from repro.network.cost import LINK_CLASSES
    lc = LINK_CLASSES["edge"]
    want = 2.0 * (lc.latency + 100_000 / lc.bandwidth)
    assert events.round_trip_time("edge", 100_000) == pytest.approx(want)


def test_arrival_ring_mechanics():
    ring = events.empty_ring(3, 4)
    assert not bool(jnp.any(events.due_mask(ring, 0)))
    launch = jnp.asarray([True, False, True])
    k = jnp.asarray([2, 0, 1], jnp.int32)
    ring = events.ring_step(ring, 5, launch, k)      # t=5: clear slot 1
    # learner 2 lands at t=6 (slot 2), learner 0 at t=7 (slot 3)
    assert np.asarray(events.due_mask(ring, 6)).tolist() == [
        False, False, True]
    assert np.asarray(events.due_mask(ring, 7)).tolist() == [
        True, False, False]
    # consuming t=6's slot clears it for the next ring lap
    ring = events.ring_step(ring, 6, jnp.zeros(3, bool), k)
    assert not bool(jnp.any(events.due_mask(ring, 6)))
    assert np.asarray(events.due_mask(ring, 7)).tolist() == [
        True, False, False]


def test_ring_too_shallow_is_rejected():
    with pytest.raises(ValueError, match="max_delay"):
        PROTOCOLS["async_periodic"].with_params(payload_bytes=100_000_000)


# ---------------------------------------------------------------------------
# asyncify: the AsyncConfig -> spec rewrite
# ---------------------------------------------------------------------------

def test_asyncify_rewrites_triggers_and_keeps_params():
    net = NetworkConfig(link_classes=("lte", "edge"))
    sp = asyncify(PROTOCOLS["periodic"].with_params(b=3),
                  AsyncConfig(), net, model_bytes=100_000)
    assert sp.trigger == "events"
    p = dict(sp.params)
    assert p["base"] == "cadence" and p["b"] == 3
    assert p["link_classes"] == "lte,edge" and p["payload_bytes"] == 100_000
    sp = asyncify(PROTOCOLS["dynamic"].with_params(delta=0.2),
                  AsyncConfig(payload_bytes=64), net, model_bytes=100_000)
    assert sp.trigger == "events_divergence"
    assert dict(sp.params)["payload_bytes"] == 64    # explicit beats model
    assert dict(sp.params)["delta"] == 0.2
    sp = asyncify(PROTOCOLS["stale"].with_params(tau=3), AsyncConfig(), net,
                  model_bytes=8)
    assert sp.trigger == "events" and dict(sp.params)["base"] == "staleness"
    # "never" has no timeline to rewrite
    assert asyncify(PROTOCOLS["nosync"], AsyncConfig(), net,
                    model_bytes=8).trigger == "never"


def test_asyncify_aircomp_needs_mean_average():
    net = NetworkConfig()
    sp = asyncify(PROTOCOLS["periodic"], AsyncConfig(aircomp=True, snr_db=10),
                  net, model_bytes=8)
    assert sp.aggregate == "aircomp" and sp.commit == "aircomp"
    assert dict(sp.params)["snr_db"] == 10.0
    with pytest.raises(ValueError, match="over-the-air"):
        asyncify(PROTOCOLS["gossip"], AsyncConfig(aircomp=True), net,
                 model_bytes=8)


# ---------------------------------------------------------------------------
# the zero-delay reduction: bitwise equality with the synchronous engine
# ---------------------------------------------------------------------------

def test_zero_delay_matrix_bitwise():
    """Every preset x {tree, flat} under lossy availability: attaching a
    covering-budget AsyncConfig changes NOTHING — same counters, same
    per-link ledger, same simulated seconds, same parameter bytes."""
    net = NetworkConfig(link_classes=("wired", "wifi"), act_prob=0.8,
                        seed=3)
    for name, spec in BASE_SPECS.items():
        for layout in ("tree", "flat"):
            s = spec.with_params(layout=layout)
            _, _, sync_fp = _fingerprint(s, network=net)
            _, _, async_fp = _fingerprint(s, network=net,
                                          async_net=ZERO_DELAY)
            assert async_fp == sync_fp, (name, layout)


@settings(max_examples=6, deadline=None)
@given(name=st.sampled_from(sorted(BASE_SPECS)),
       layout=st.sampled_from(("tree", "flat")),
       act=st.floats(min_value=0.3, max_value=1.0),
       straggler=st.floats(min_value=0.0, max_value=0.5),
       avail_seed=st.integers(0, 2**16))
def test_zero_delay_random_availability_property(name, layout, act,
                                                 straggler, avail_seed):
    net = NetworkConfig(link_classes=("wired", "wifi"), act_prob=act,
                        straggler_frac=straggler, seed=avail_seed)
    s = BASE_SPECS[name].with_params(layout=layout)
    _, _, sync_fp = _fingerprint(s, network=net, rounds=6, m=3)
    _, _, async_fp = _fingerprint(s, network=net, async_net=ZERO_DELAY,
                                  rounds=6, m=3)
    assert async_fp == sync_fp


# ---------------------------------------------------------------------------
# nonzero delays: messages fly whole rounds
# ---------------------------------------------------------------------------

def test_inflight_alternates_on_edge_links():
    """async_periodic's lte/edge fleet at the 1 s budget: the edge
    learners' exchanges fly exactly one round, so after odd rounds both
    edge links are in flight and after even rounds both have landed."""
    dl, metrics, _ = _fingerprint(PROTOCOLS["async_periodic"], m=4,
                                  rounds=6)
    assert np.asarray(metrics.num_inflight).tolist() == [2, 0, 2, 0, 2, 0]
    # sigma_b's all-reachable cohort resets every age at each commit
    assert np.asarray(metrics.max_age).tolist() == [0] * 6
    assert dl.comm_totals["syncs"] == 6
    ex = dl._state_extra()
    assert sorted(ex) == ["age", "inflight", "lclock", "ring"]
    assert all(np.asarray(v).dtype == np.int32 for v in ex.values())


def test_quiet_timeline_ages_grow():
    """A divergence threshold nothing crosses: no learner ever fires, so
    the carried ages grow one per round and nothing is ever in flight."""
    spec = PROTOCOLS["async_dynamic"].with_params(delta=1e9)
    dl, metrics, _ = _fingerprint(spec, m=4, rounds=5)
    assert np.asarray(metrics.max_age).tolist() == [1, 2, 3, 4, 5]
    assert np.asarray(metrics.num_inflight).tolist() == [0] * 5
    assert dl.comm_totals["syncs"] == 0


def test_nonzero_delay_ledger_stays_exact():
    """Flights shift WHEN transfers happen, never how they are priced:
    the int64 ledger equals the per-round transfer counts times the
    payload, reconstructed host-side."""
    net = NetworkConfig(link_classes=("lte", "edge"))
    an = AsyncConfig(round_budget=1.0, payload_bytes=100_000)
    dl, metrics, _ = _fingerprint(PROTOCOLS["periodic"], network=net,
                                  async_net=an, m=4, rounds=10)
    xfer_counts = np.asarray(metrics.link_counts, np.int64)[..., 0]
    want = (xfer_counts * (dl.model_size * 4)).sum(axis=0)
    got = np.asarray(dl.link_bytes_totals) - np.asarray(
        metrics.link_counts, np.int64)[..., 1].sum(axis=0) * net.msg_bytes
    assert got.tolist() == want.tolist()


# ---------------------------------------------------------------------------
# aircomp: over-the-air aggregation physics + pricing
# ---------------------------------------------------------------------------

def test_aircomp_prices_one_shared_medium_exchange():
    dl, _, _ = _fingerprint(PROTOCOLS["aircomp"], m=4, rounds=5)
    # c(f): ONE exchange per sync regardless of cohort size
    assert dl.comm_totals == {"model_up": 5, "model_down": 5,
                              "messages": 0, "syncs": 5, "full_syncs": 5}
    model_bytes = dl.model_size * 4
    assert dl.comm_bytes() == 5 * 2 * model_bytes
    # the ledger bills each member's analog frame airtime — deliberately
    # NOT c(f), like gossip's both-endpoints occupancy
    assert dl.link_xfer_totals.tolist() == [5, 5, 5, 5]
    assert int(np.asarray(dl.link_bytes_totals).sum()) == \
        4 * 5 * model_bytes


def test_aircomp_noise_is_pure_and_vanishes_with_snr():
    _, _, (ct_a, lb_a, nt_a, d_a) = _fingerprint(PROTOCOLS["aircomp"])
    _, _, (ct_b, lb_b, nt_b, d_b) = _fingerprint(PROTOCOLS["aircomp"])
    assert d_a == d_b                       # pure in (air_seed, t)
    _, _, (_, _, _, d_seed) = _fingerprint(
        PROTOCOLS["aircomp"].with_params(air_seed=7))
    assert d_seed != d_a                    # the seed IS the noise stream

    clean, _, _ = _fingerprint(PROTOCOLS["periodic"])
    quiet, _, _ = _fingerprint(
        PROTOCOLS["aircomp"].with_params(snr_db=200.0))
    loud, _, _ = _fingerprint(PROTOCOLS["aircomp"].with_params(snr_db=0.0))

    def dist(a, b):
        return float(sum(jnp.sum((x - y) ** 2) for x, y in
                         zip(jax.tree.leaves(a.params),
                             jax.tree.leaves(b.params))))

    assert dist(quiet, clean) <= 1e-8       # 200 dB: the digital limit
    assert dist(loud, clean) > dist(quiet, clean)


# ---------------------------------------------------------------------------
# determinism + the telemetry plane's view of the timeline
# ---------------------------------------------------------------------------

def _telemetered_run(path):
    net = NetworkConfig(link_classes=("lte", "edge"))
    an = AsyncConfig(round_budget=1.0, payload_bytes=100_000)
    dl, _, _ = _fingerprint(
        PROTOCOLS["periodic"], network=net, async_net=an, m=4, rounds=12,
        telemetry=TelemetryConfig(path=path, per_link=True))
    dl.recorder.close()
    return dl


def test_identical_runs_stream_identical_jsonl(tmp_path):
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    _telemetered_run(a)
    _telemetered_run(b)
    with open(a, "rb") as fa, open(b, "rb") as fb:
        assert fa.read() == fb.read()


def test_telemetry_sees_inflight_and_ages(tmp_path):
    path = str(tmp_path / "run.jsonl")
    dl = _telemetered_run(path)
    run = load_run(path)
    inflight = [r["inflight"] for r in run.rounds]
    assert inflight == [2, 0] * 6           # the edge flights, per round
    assert all(r["max_age"] == 0 for r in run.rounds)
    # the chunk snapshot carries the full timeline state...
    snap = run.chunks[-1]["stale_age"]
    assert sorted(snap) == ["age", "inflight", "lclock", "ring"]
    # ...and the run card histograms the per-learner counters (the 2-D
    # arrival ring is bookkeeping, not a counter — skipped)
    card = summarize(run)
    assert sorted(card["state_ages"]) == ["age", "inflight", "lclock"]
    assert card["state_ages"]["inflight"]["max"] == 0   # chunk-end: landed
    assert card["inflight_last"] == 0 and card["max_age_last"] == 0
    assert card["inflight"][0][1] == 2
    assert dl.comm_totals["syncs"] == run.rounds[-1]["cum_syncs"]


# ---------------------------------------------------------------------------
# the example is runnable (subprocess; excluded from tier-1 via -m slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_async_fleet_example_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "async_fleet.py"),
         "--smoke"],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "async_fleet_done" in r.stdout
