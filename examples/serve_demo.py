"""Serving demo: batched prefill + decode against KV / SSM-state caches.

Loads a small llama-family model and a Mamba2 model, feeds a batch of
prompts, and generates continuations with greedy and temperature sampling —
the same serve_step the decode dry-run shapes lower.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.config import get_arch
from repro.models.model import init_lm_params
from repro.serve.engine import ServeEngine


def demo(arch: str, batch: int = 4, prompt_len: int = 16,
         gen_tokens: int = 32):
    cfg = get_arch(arch, smoke=True)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=prompt_len + gen_tokens + 1,
                      batch=batch)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    logits = eng.feed(prompts)
    t_prefill = time.time() - t0
    t0 = time.time()
    greedy = eng.generate(gen_tokens, first_logits=logits)
    t_decode = time.time() - t0
    print(f"{arch:18s} prefill {batch}x{prompt_len} in {t_prefill:.2f}s; "
          f"decoded {batch}x{gen_tokens} in {t_decode:.2f}s "
          f"({batch*gen_tokens/t_decode:.0f} tok/s)")
    print(f"  first continuation: {greedy[0].tolist()}")

    # temperature sampling from a fresh engine
    eng2 = ServeEngine(cfg, params, max_seq=prompt_len + gen_tokens + 1,
                       batch=batch)
    logits = eng2.feed(prompts)
    sampled = eng2.generate(gen_tokens, key=jax.random.PRNGKey(7),
                            temperature=0.8, first_logits=logits)
    print(f"  sampled (T=0.8):    {sampled[0].tolist()}")


def main():
    demo("llama3-8b")        # GQA KV cache
    demo("mamba2-2.7b")      # O(1) SSM state - the long_500k decode path
    demo("mixtral-8x22b")    # MoE + sliding-window ring buffer


if __name__ == "__main__":
    main()
